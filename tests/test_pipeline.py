"""Ingest pipeline fault tolerance: drain, straggler re-queue, elastic
workers, shard-count guidance."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import EventStore, web_proxy_schema
from repro.core.ingest import check_shard_guidance
from repro.pipeline import IngestWorkerPool, MasterIngestQueue, FileTask, SyntheticWebProxySource
from repro.pipeline.tokenizer import EventTokenizer


@pytest.fixture()
def staged_files(tmp_path):
    src = SyntheticWebProxySource(n_domains=100, seed=5)
    return src.write_files(str(tmp_path), n_files=6, lines_per_file=1500, t_start=0, t_stop=7200)


def test_pool_drains_all_files(staged_files):
    store = EventStore(web_proxy_schema(), n_shards=4)
    pool = IngestWorkerPool(store, n_workers=3)
    for p in staged_files:
        pool.submit_file(p)
    reports = pool.drain(timeout_s=120)
    assert store.total_rows == 6 * 1500
    assert sum(r.files for r in reports) == 6


def test_straggler_requeue(staged_files):
    """A worker that dies mid-lease must not lose its file: the lease
    expires and another worker re-ingests it."""
    store = EventStore(web_proxy_schema(), n_shards=4)
    # Timeout long enough that live workers always heartbeat in time (a
    # too-short lease would legitimately double-deliver: at-least-once).
    pool = IngestWorkerPool(store, n_workers=3, lease_timeout_s=2.0)
    pool.kill_worker(0)  # dies silently on its first claim
    for p in staged_files:
        pool.submit_file(p)
    pool.drain(timeout_s=120)
    assert store.total_rows == 6 * 1500  # nothing lost


def test_elastic_add_worker(staged_files):
    store = EventStore(web_proxy_schema(), n_shards=4)
    pool = IngestWorkerPool(store, n_workers=2)
    for p in staged_files:
        pool.submit_file(p)
    pool.add_worker()  # join mid-run
    pool.drain(timeout_s=120)
    assert store.total_rows == 6 * 1500


def test_lease_expiry_requeues():
    q = MasterIngestQueue(n_partitions=2, lease_timeout_s=0.05)
    q.submit(FileTask("/tmp/x", "web_proxy"))
    task = q.claim("w0", 0)
    assert task is not None and q.in_flight == 1
    import time

    time.sleep(0.1)
    assert q.expire_now() == 1
    assert q.pending == 1  # re-queued
    t2 = q.claim("w1", 1)  # work stealing across partitions
    assert t2 is not None and t2.attempts == 2


def test_shard_guidance_enforced():
    store = EventStore(web_proxy_schema(), n_shards=2)
    with pytest.raises(ValueError):
        IngestWorkerPool(store, n_workers=8)  # N=2 < 8/2
    assert check_shard_guidance(4, 8)
    assert not check_shard_guidance(3, 8)


def test_tokenizer_batches(staged_files):
    store = EventStore(web_proxy_schema(), n_shards=4)
    pool = IngestWorkerPool(store, n_workers=2)
    for p in staged_files:
        pool.submit_file(p)
    pool.drain(timeout_s=120)
    tok = EventTokenizer(store, vocab_size=8192)
    batch = next(tok.sequences(0, 7200, seq_len=64, batch=4))
    assert batch.shape == (4, 64)
    assert batch.dtype == np.int32
    assert batch.min() >= 0 and batch.max() < 8192
