"""Per-kernel validation: Pallas (interpret=True on CPU) and the jnp ref
vs pure-numpy oracles, with hypothesis sweeps over shapes/values."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import And, Eq, EventStore, Match, Not, Or, web_proxy_schema
from repro.core.filter import compile_tree, eval_tree_rows
from repro.kernels.aggregate_combine import combine_sorted_counts
from repro.kernels.filter_scan import filter_scan
from repro.kernels.merge_intersect import intersect_sorted, union_sorted


@pytest.fixture(scope="module")
def store():
    s = EventStore(web_proxy_schema(), n_shards=2)
    rng = np.random.default_rng(0)
    n = 4000
    vals = {
        "domain": rng.choice(["a.com", "ab.com", "b.com", "c.net"], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404", "500"], size=n).tolist(),
    }
    s.ingest(np.sort(rng.integers(0, 3600, n)), vals)
    return s


def _cols(store, rng, n):
    f = store.schema.n_fields
    cols = np.zeros((n, f), np.int32)
    for name in ["domain", "method", "status"]:
        fid = store.schema.field_id(name)
        cols[:, fid] = rng.integers(0, max(len(store.dictionaries[name]), 1), n)
    return cols


TREES = [
    Eq("domain", "a.com"),
    And(Eq("domain", "a.com"), Eq("method", "GET")),
    Or(Eq("domain", "b.com"), Eq("domain", "c.net"), Eq("domain", "a.com")),
    Not(Eq("status", "200")),
    And(Or(Eq("domain", "a.com"), Eq("domain", "ab.com")), Not(Eq("method", "POST")), Eq("status", "404")),
    Match("domain", "a"),
]


@pytest.mark.parametrize("tree", TREES)
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_filter_scan_vs_tree_oracle(store, tree, backend):
    rng = np.random.default_rng(7)
    cols = _cols(store, rng, 3000)
    prog = compile_tree(store, tree)
    got = filter_scan(cols, prog, backend=backend)
    want = eval_tree_rows(store, tree, cols)
    np.testing.assert_array_equal(got, want)


@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_filter_scan_shape_sweep(store, n, seed):
    rng = np.random.default_rng(seed)
    cols = _cols(store, rng, n)
    tree = And(Or(Eq("domain", "a.com"), Eq("domain", "b.com")), Not(Eq("status", "500")))
    prog = compile_tree(store, tree)
    for backend in ("ref", "pallas"):
        np.testing.assert_array_equal(
            filter_scan(cols, prog, backend=backend), eval_tree_rows(store, tree, cols)
        )


@given(
    na=st.integers(0, 3000),
    nb=st.integers(0, 3000),
    overlap=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=25, deadline=None)
def test_intersect_sweep(na, nb, overlap, seed):
    rng = np.random.default_rng(seed)
    a = np.unique(rng.integers(0, 1 << 52, na).astype(np.int64)) if na else np.empty(0, np.int64)
    take = int(min(len(a), nb) * overlap)
    extra = rng.integers(0, 1 << 52, max(nb - take, 0)).astype(np.int64)
    b = np.unique(np.concatenate([rng.choice(a, take, replace=False) if take else np.empty(0, np.int64), extra]))
    want = np.intersect1d(a, b)
    for backend in ("ref", "pallas"):
        got = intersect_sorted(a, b, backend=backend)
        np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(union_sorted(a, b), np.union1d(a, b))


def test_intersect_edge_keys():
    """Keys whose lo-lane bit patterns are negative int32 (the unsigned
    compare path)."""
    base = (1 << 32) - 2  # lo = 0xFFFFFFFE: negative as int32
    a = np.asarray([base - 1, base, base + 1, base + (1 << 33)], np.int64)
    b = np.asarray([base, base + (1 << 33)], np.int64)
    for backend in ("ref", "pallas"):
        np.testing.assert_array_equal(intersect_sorted(a, b, backend=backend), b)


@given(
    n=st.integers(1, 4000),
    nkeys=st.integers(1, 50),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=25, deadline=None)
def test_combine_sweep(n, nkeys, seed):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, nkeys, n).astype(np.int64))
    cnt = rng.integers(1, 10, n).astype(np.int32)
    uk, inv = np.unique(keys, return_inverse=True)
    want = np.bincount(inv, weights=cnt).astype(np.int32)
    for backend in ("ref", "pallas"):
        gk, gc = combine_sorted_counts(keys, cnt, backend=backend)
        np.testing.assert_array_equal(gk, uk)
        np.testing.assert_array_equal(gc, want)


def test_combine_boundary_straddling():
    """A single key spanning multiple Pallas tiles must merge across the
    tile-stitch epilogue."""
    from repro.kernels.aggregate_combine.aggregate_combine import BLOCK

    n = BLOCK * 3
    keys = np.full(n, 7, np.int64)
    cnt = np.ones(n, np.int32)
    gk, gc = combine_sorted_counts(keys, cnt, backend="pallas")
    assert list(gk) == [7]
    assert list(gc) == [n]
