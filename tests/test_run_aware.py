"""Run-aware distributed reads: publish() is a snapshot (never a fold),
every read primitive searches base + sorted runs + sealed memtable, the
ix family dedups postings at major, selective aggregates ride the index
path, and a publish racing live ingest never observes a torn state."""
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core import (
    AggregateSpec, And, Eq, EventStore, Not, Or, QueryProcessor,
    web_proxy_schema,
)
from repro.core import keypack
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor
from repro.core.query import QueryStats
from repro.launch.mesh import make_dev_mesh

T_SPAN = 4 * 3600
SCHEMES = ["scan", "batched_scan", "index", "batched_index"]


def _gen(seed, n):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(
            ["a.com", "b.com", "c.com", "rare.net"], p=[0.6, 0.25, 0.13, 0.02], size=n
        ).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    return ts, vals


@pytest.fixture(scope="module")
def live_runs():
    """The same events through the host store and a plane sized so that NO
    major compaction ever fires: at publish time the base is EMPTY and
    every row (and index posting, and aggregate count) lives in unfolded
    run slabs or the sealed memtable. Everything the dist path answers
    here, it answers from the non-base levels."""
    ts, vals = _gen(seed=19, n=10_000)
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=12_000, tablets_per_device=2,
        mem_rows=1024, max_runs=6, append_rows=512,
    )
    w = DistBatchWriter(store, plane, batch_rows=1500)
    step = 997  # misaligned with every internal batch size
    for off in range(0, len(ts), step):
        sl = slice(off, off + step)
        w.add(ts[sl], {k: v[sl] for k, v in vals.items()})
    w.close()
    tel = plane.telemetry()
    assert int(tel["major"].sum()) == 0  # the whole point of this fixture
    assert int(tel["base_n"].sum()) == 0
    assert int(tel["minor"].sum()) > 0  # rows really sit in run slabs
    dq = DistQueryProcessor(store, plane=plane)
    return store, plane, dq, ts, {k: np.array(v) for k, v in vals.items()}


TREES = [
    Eq("domain", "rare.net"),
    Eq("domain", "c.com"),
    And(Eq("domain", "c.com"), Eq("status", "404"), Eq("method", "POST")),
    And(Eq("domain", "c.com"), Not(Eq("method", "POST"))),
    Or(Eq("domain", "rare.net"), Eq("domain", "c.com")),
    Or(Eq("domain", "rare.net"), Eq("status", "404")),
    None,
]


# ------------------------------------------------- publish is merge-free
def test_publish_is_snapshot_not_fold(live_runs):
    """publish() must do NO run->base fold: compaction counters frozen,
    base/run state buffers untouched (the DistStore is a zero-copy view of
    them), only the sealed memtable arrays are fresh."""
    store, plane, dq, ts, vals = live_runs
    # Force a fresh publish even if another test left a cached one.
    with plane._lock:
        plane._dirty = True
    before = {
        k: plane.state[k]
        for k in ("ev_base_k", "ev_run_k", "ix_base_k", "ag_run_k", "n_runs")
    }
    tel0 = plane.telemetry()
    ds = plane.publish()
    tel1 = plane.telemetry()
    for c in ("minor", "major", "base_n", "n_runs"):
        np.testing.assert_array_equal(tel0[c], tel1[c], err_msg=c)
    for k, arr in before.items():
        assert plane.state[k] is arr, f"publish replaced {k}"
    # The published view aliases the live buffers (snapshot, not copy) ...
    assert ds.rev_ts is plane.state["ev_base_k"]
    assert ds.run_rev_ts is plane.state["ev_run_k"]
    assert ds.ix_run_k is plane.state["ix_run_k"]
    # ... except the sealed memtable, which is a fresh sorted copy.
    assert ds.mem_rev_ts is not plane.state["ev_mem_k"]
    mem = np.asarray(jax.device_get(ds.mem_rev_ts))
    mn = np.asarray(jax.device_get(ds.mem_counts))
    for t in range(ds.n_tablets):
        assert (np.diff(mem[t, : mn[t]]) >= 0).all()  # sealed level sorted


def test_publish_noop_when_clean(live_runs):
    _, plane, _, _, _ = live_runs
    assert plane.publish() is plane.publish()


# ------------------------------------------------- scheme agreement, no base
@pytest.mark.parametrize("tree", TREES)
@pytest.mark.parametrize("scheme", SCHEMES)
def test_schemes_agree_with_unfolded_runs(live_runs, tree, scheme):
    store, _, dq, ts, vals = live_runs
    qp = QueryProcessor(store)
    hs, ds = QueryStats(), QueryStats()
    t0, t1 = 900, 9000
    want = sum(b.n for b in qp.run_scheme(scheme, t0, t1, tree, stats=hs))
    got = sum(b.n for b in dq.run_scheme(scheme, t0, t1, tree, stats=ds))
    assert got == want
    assert hs.plan.mode == ds.plan.mode  # densities agree level-summed


@given(seed=st.integers(0, 2**31), span=st.integers(1, T_SPAN))
@settings(max_examples=12, deadline=None)
def test_randomized_ranges_agree_with_unfolded_runs(live_runs, seed, span):
    store, _, dq, ts, vals = live_runs
    rng = np.random.default_rng(seed)
    t0 = int(rng.integers(0, T_SPAN))
    t1 = min(t0 + span, T_SPAN)
    tree = TREES[int(rng.integers(0, len(TREES) - 1))]
    want = sum(b.n for b in QueryProcessor(store).run_scheme("batched_index", t0, t1, tree))
    got = sum(b.n for b in dq.run_scheme("batched_index", t0, t1, tree))
    assert got == want, (tree, t0, t1)


def test_density_reads_unfolded_levels(live_runs):
    """Planner densities come off run + sealed-mem aggregate entries (the
    base is empty here) and still match the host aggregate table."""
    store, _, dq, _, _ = live_runs
    for f, v in [("domain", "rare.net"), ("domain", "a.com"), ("status", "404")]:
        for t0, t1 in [(0, T_SPAN), (1800, 5400)]:
            assert dq.agg_count(f, v, t0, t1) == store.agg_count(f, v, t0, t1)


# -------------------------------------------------- aggregates over levels
AGG_SPECS = [
    AggregateSpec(group_by=("status",), time_bucket_s=3600),
    AggregateSpec(group_by=("domain", "method")),
    AggregateSpec(group_by=("domain",), op="min", value_field="status"),
]


def _as_map(res, store):
    return {
        tuple(sorted((k, v) for k, v in r.items() if k not in ("value", "count"))): (
            r["value"], r["count"],
        )
        for r in res.rows(store)
    }


@pytest.mark.parametrize("spec", AGG_SPECS)
@pytest.mark.parametrize("tree", [Eq("domain", "rare.net"), None])
def test_aggregates_agree_with_unfolded_runs(live_runs, spec, tree):
    store, _, dq, _, _ = live_runs
    host = QueryProcessor(store).aggregate(spec, 0, T_SPAN, tree)
    dist = dq.aggregate_range(spec, tree, 0, T_SPAN)
    assert _as_map(host, store) == _as_map(dist, store)


def test_aggregate_uses_index_path(live_runs):
    """Satellite bugfix: a selective aggregate must ride the batched-index
    candidate gather (plan mode 'index', postings actually expanded), not
    filter-scan the full tablets."""
    store, _, dq, _, _ = live_runs
    spec = AggregateSpec(group_by=("method",))
    stats = QueryStats()
    dist = dq.aggregate_range(spec, Eq("domain", "rare.net"), 0, T_SPAN, stats=stats)
    assert stats.plan.mode == "index"
    assert stats.index_keys_scanned > 0
    host = QueryProcessor(store).aggregate(spec, 0, T_SPAN, Eq("domain", "rare.net"))
    assert _as_map(host, store) == _as_map(dist, store)


def test_aggregate_index_truncation_falls_back_exact(live_runs):
    """Pathologically small slabs: the index-driven aggregation overflows,
    falls back to the exact scan-time aggregation, result unchanged."""
    store, plane, _, _, _ = live_runs
    dq = DistQueryProcessor(store, plane=plane, index_postings=8, index_rows=8)
    spec = AggregateSpec(group_by=("method",))
    tree = Eq("domain", "c.com")
    host = QueryProcessor(store).aggregate(spec, 0, T_SPAN, tree)
    dist = dq.aggregate_range(spec, tree, 0, T_SPAN)
    assert _as_map(host, store) == _as_map(dist, store)


def test_aggregate_empty_plan_skips_device(live_runs):
    store, _, dq, _, _ = live_runs
    stats = QueryStats()
    res = dq.aggregate_range(
        AggregateSpec(group_by=("method",)),
        And(Eq("domain", "rare.net"), Eq("domain", "never-seen.com")),
        0, T_SPAN, stats=stats,
    )
    assert stats.plan.mode == "empty" and res.n_groups == 0


# ------------------------------------------------------ fold still correct
def test_compact_preserves_results():
    """compact() (the batched background fold) only moves rows between
    levels: every scheme and aggregate answers identically before/after,
    the fold really happened (base now holds the rows), and an idle
    compact is a no-op that keeps the cached published view. Uses its own
    plane — live_runs stays unfolded for the level-read tests."""
    ts, vals = _gen(seed=23, n=4000)
    store = EventStore(web_proxy_schema(), n_shards=2)
    store.ingest(ts, vals)
    store.flush_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=5000, tablets_per_device=2,
        mem_rows=1024, max_runs=6, append_rows=512,
    )
    w = DistBatchWriter(store, plane, batch_rows=997)
    w.add(ts, vals)
    w.close()
    assert int(plane.telemetry()["major"].sum()) == 0  # nothing folded yet
    dq = DistQueryProcessor(store, plane=plane)
    tree = Or(Eq("domain", "rare.net"), Eq("status", "404"))
    spec = AggregateSpec(group_by=("domain",))
    before = {s: sum(b.n for b in dq.run_scheme(s, 900, 9000, tree)) for s in SCHEMES}
    agg_before = _as_map(dq.aggregate_range(spec, tree, 0, T_SPAN), store)
    plane.compact()
    tel = plane.telemetry()
    assert int(tel["major"].sum()) >= 1
    assert int(tel["base_n"].sum()) == len(ts)
    assert int(tel["mem_n"].sum()) == 0
    for s in SCHEMES:
        assert sum(b.n for b in dq.run_scheme(s, 900, 9000, tree)) == before[s]
    assert _as_map(dq.aggregate_range(spec, tree, 0, T_SPAN), store) == agg_before
    # Idle compact: nothing to fold -> no-op, published cache intact.
    view = plane.publish()
    plane.compact()
    assert plane.publish() is view
    assert int(plane.telemetry()["major"].sum()) == int(tel["major"].sum())


# ----------------------------------------------------------- ix dedup
def test_ix_dedup_at_major_postings_oracle():
    """Satellite bugfix: duplicate field|value|rev_ts postings (events
    sharing a timestamp and a value in one tablet) collapse at major —
    the ix base holds exactly the distinct-key count, stays sorted and
    unique, and index queries remain exact."""
    rng = np.random.default_rng(3)
    n = 3000
    ts = np.sort(rng.integers(0, 1200, n))  # dense ts -> heavy duplication
    vals = {
        "domain": rng.choice(["a.com", "b.com"], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": ["200"] * n,
    }
    store = EventStore(web_proxy_schema(), n_shards=2)
    store.ingest(ts, vals)
    store.flush_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=6000, tablets_per_device=1,
        mem_rows=512, max_runs=4, append_rows=256,
    )
    w = DistBatchWriter(store, plane, batch_rows=700, writer_id=0)
    w.add(ts, vals)
    w.close()
    plane.compact()
    assert int(plane.telemetry()["major"].sum()) >= 1
    ixk = np.asarray(jax.device_get(plane.state["ix_base_k"]))[0]
    ixn = int(np.asarray(jax.device_get(plane.state["ix_base_n"]))[0])
    live = ixk[:ixn]
    assert (np.diff(live) > 0).all()  # sorted AND strictly unique
    assert (ixk[ixn:] == np.iinfo(np.int64).max).all()  # sentinel tail
    # NumPy oracle: distinct (fid, code, rev_ts) triples over all rows.
    cols = store.encode_events(np.asarray(ts, np.int64), vals)
    rts = keypack.rev_ts(np.asarray(ts, np.int64))
    want = {
        int(keypack.pack_index_key(fid, int(c), int(r)))
        for fid in plane.indexed_fids
        for c, r in zip(cols[:, fid], rts)
    }
    assert ixn == len(want)
    assert set(live.tolist()) == want
    assert ixn < n * len(plane.indexed_fids)  # duplicates really collapsed
    # Idempotent: a second fold cycle must not shrink or grow the base.
    w2 = DistBatchWriter(store, plane, batch_rows=700, writer_id=1)
    w2.add(ts[:1], {k: v[:1] for k, v in vals.items()})
    w2.close()
    plane.compact()
    ixn2 = int(np.asarray(jax.device_get(plane.state["ix_base_n"]))[0])
    assert ixn2 == ixn  # re-ingested duplicate of an existing key
    dq = DistQueryProcessor(store, plane=plane)
    want_rows = int((np.array(vals["domain"]) == "a.com").sum())
    want_rows += int(vals["domain"][0] == "a.com")  # the re-ingested row
    got = sum(b.n for b in dq.run_scheme("batched_index", 0, 2000, Eq("domain", "a.com")))
    assert got == want_rows


def test_from_event_store_is_base_only():
    """A bulk replay is one-shot: from_event_store folds up front and
    snapshots only the base level, so the compiled read programs carry no
    empty run/mem slabs (the replay plane's are 8 x 8192 rows)."""
    from repro.core.dist_query import from_event_store

    ts, vals = _gen(seed=5, n=2000)
    store = EventStore(web_proxy_schema(), n_shards=2)
    store.ingest(ts, vals)
    store.flush_all()
    dist = from_event_store(store, make_dev_mesh(1, 1), tablets_per_device=2)
    assert not dist.has_runs and dist.has_index
    assert int(np.asarray(jax.device_get(dist.counts)).sum()) == len(ts)
    dq = DistQueryProcessor(store, dist)
    count, _, _ = dq.scan_range(Eq("domain", "c.com"), 0, T_SPAN)
    assert count == int((np.array(vals["domain"]) == "c.com").sum())


# -------------------------------------------------- freshness under ingest
def test_publish_freshness_under_concurrent_ingest():
    """Satellite bugfix: a publish racing a live writer takes the plane
    lock around the whole snapshot, so (a) every row whose ingest call
    returned before publish is visible, and (b) visibility moves in whole
    ingest-call units — never a torn chunk."""
    n, chunk = 4110, 137
    ts, vals = _gen(seed=41, n=n)
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=6000, tablets_per_device=2,
        mem_rows=512, max_runs=8, append_rows=256,
    )
    cols = store.encode_events(np.asarray(ts, np.int64), vals)
    rts = keypack.rev_ts(np.asarray(ts, np.int64)).astype(np.int32)
    tab = (keypack.short_hash(rts.astype(np.int64)) % plane.n_tablets).astype(np.int32)
    done = {"rows": 0}

    def writer():
        for off in range(0, n, chunk):
            sl = slice(off, off + chunk)
            plane.ingest(rts[sl], cols[sl], tab[sl])
            done["rows"] = off + len(rts[sl])  # acknowledged AFTER the call

    # Warm the compile paths before racing, so the timed window interleaves
    # real appends with real publishes instead of serializing on tracing.
    plane.ingest(rts[:1], cols[:1], tab[:1])
    dq = DistQueryProcessor(store, plane=plane)
    dq.scan_range(None, 0, T_SPAN)
    probe = DistQueryProcessor(store, dist=plane.publish())
    probe._step_cache = dq._step_cache  # reuse compiled steps, no plane sync
    base = 1  # the warm-up row

    t = threading.Thread(target=writer)
    t.start()
    observed = []
    while t.is_alive():
        lo = done["rows"]
        probe.dist = plane.publish()  # pinned snapshot: probe has no plane
        count, _, _ = probe.scan_range(None, 0, T_SPAN)
        hi = done["rows"]
        assert count >= lo + base, (count, lo)  # acknowledged rows visible
        assert count <= hi + base + chunk  # at most one in-flight chunk
        assert (count - base) % chunk == 0  # whole ingest calls only
        observed.append(count)
    t.join()
    probe.dist = plane.publish()
    count, _, _ = probe.scan_range(None, 0, T_SPAN)
    assert count == n + base
    assert observed == sorted(observed)  # visibility is monotone
