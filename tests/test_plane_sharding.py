"""Sharded ingest plane (TabletGroup ownership): W-writer concurrent
ingest over G groups must agree EXACTLY with the single-group oracle
(counts, all four aggregate ops, index hits), disjoint-group appends
must overlap (per-group lock wait ~0 while the single-lock baseline
measurably queues), and the facade invariants — composite snapshot
aliasing, per-tablet gauges, per-writer blocked-seconds summing to the
plane scalar — must hold across group splits."""
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AggregateSpec, And, Eq, EventStore, Not, Or, web_proxy_schema
from repro.core import keypack
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor, QueryRun
from repro.launch.mesh import make_dev_mesh

T_SPAN = 4 * 3600
TPD = 4  # tablets per device in every plane here (divisible by 1, 2, 4)


def _events(seed, n):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(["a.com", "b.com", "c.com"], p=[0.6, 0.3, 0.1], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n).tolist(),
        "bytes_out": rng.integers(10, 5000, size=n).astype(str).tolist(),
    }
    return ts, vals


def _encoded(store, seed, n, n_tablets):
    """One pre-encoded, pre-assigned stream: BOTH planes get the exact
    same (rts, cols, tab) rows, so per-GLOBAL-tablet contents must agree
    as multisets no matter how groups split the tablets."""
    ts, vals = _events(seed, n)
    cols = store.encode_events(np.asarray(ts, np.int64), vals)
    rts = keypack.rev_ts(np.asarray(ts, np.int64)).astype(np.int32)
    rng = np.random.default_rng(seed + 1)
    tab = rng.integers(0, n_tablets, n).astype(np.int32)
    return rts, cols, tab, ts, {k: np.array(v) for k, v in vals.items()}


def _plane(store, mesh, n_groups, capacity=20_000, mem_rows=256, max_runs=2):
    return DistIngestPlane.for_store(
        store, mesh, capacity=capacity, tablets_per_device=TPD,
        mem_rows=mem_rows, max_runs=max_runs, append_rows=128,
        n_groups=n_groups,
    )


def _threaded_ingest(plane, rts, cols, tab, n_writers):
    """W real threads, each appending an interleaved slice of the SAME
    stream — rows land on whatever groups their tablet ids map to, so
    writers contend (or not) exactly as the lock split dictates."""
    def work(i):
        sl = slice(i, None, n_writers)
        plane.ingest(rts[sl], cols[sl], tab[sl], writer_id=i)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


TREES = [
    (Eq("domain", "c.com"), lambda v: v["domain"] == "c.com"),
    (
        And(Eq("domain", "b.com"), Not(Eq("method", "POST"))),
        lambda v: (v["domain"] == "b.com") & (v["method"] != "POST"),
    ),
    (
        Or(Eq("status", "404"), Eq("domain", "c.com")),
        lambda v: (v["status"] == "404") | (v["domain"] == "c.com"),
    ),
]

AGG_SPECS = [
    AggregateSpec(group_by=("status",), time_bucket_s=3600),
    AggregateSpec(group_by=("domain", "method")),
    AggregateSpec(group_by=("domain",), op="sum", value_field="bytes_out"),
    AggregateSpec(group_by=("status",), op="min", value_field="bytes_out"),
    AggregateSpec(group_by=("status",), op="max", value_field="bytes_out"),
]


def _agg_map(store, res):
    return {
        tuple(sorted((k, v) for k, v in r.items() if k not in ("value", "count"))): (
            r["value"], r["count"],
        )
        for r in res.rows(store)
    }


# --------------------------------------------------- W x G oracle agreement
@given(
    seed=st.integers(0, 2**31),
    n_groups=st.sampled_from([2, 4]),
    n_writers=st.integers(2, 4),
)
@settings(max_examples=3, deadline=None)
def test_sharded_plane_matches_single_group_oracle(seed, n_groups, n_writers):
    """THE exactness property: W concurrent writers over G groups produce
    the same database as one serial writer over one group — every scan
    count, all four aggregate ops, and the index path's hits agree
    exactly, with flush/fold thresholds deliberately tiny so the sharded
    run exercises minors and blocking majors mid-stream."""
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    oracle = _plane(store, mesh, n_groups=1)
    sharded = _plane(store, mesh, n_groups=n_groups)
    assert sharded.n_tablets == oracle.n_tablets == TPD
    rts, cols, tab, ts, varr = _encoded(store, seed, 1200, TPD)

    oracle.ingest(rts, cols, tab, writer_id=0)
    _threaded_ingest(sharded, rts, cols, tab, n_writers)

    tel_o, tel_s = oracle.telemetry(), sharded.telemetry()
    assert int(tel_s["rows"].sum()) == int(tel_o["rows"].sum()) == len(rts)
    assert int(tel_s["overflow"].sum()) == 0
    # Same stream -> same per-GLOBAL-tablet row counts, whatever group
    # owns the tablet (telemetry concatenates groups in tablet order).
    np.testing.assert_array_equal(tel_s["rows"], tel_o["rows"])

    dq_o = DistQueryProcessor(store, plane=oracle)
    dq_s = DistQueryProcessor(store, plane=sharded)
    assert dq_s._sync().is_composite and not dq_o._sync().is_composite

    for tree, mask in TREES:
        for t0, t1 in [(0, T_SPAN), (1800, 5400)]:
            c_o, _, _ = dq_o.scan_range(tree, t0, t1)
            c_s, top_ts, _ = dq_s.scan_range(tree, t0, t1)
            assert c_s == c_o == int((mask(varr) & (ts >= t0) & (ts <= t1)).sum())
            assert ((top_ts >= t0) & (top_ts <= t1)).all()

    for spec in AGG_SPECS:
        a_o = dq_o.aggregate_range(spec, Eq("domain", "a.com"), 0, T_SPAN)
        a_s = dq_s.aggregate_range(spec, Eq("domain", "a.com"), 0, T_SPAN)
        assert _agg_map(store, a_s) == _agg_map(store, a_o)

    # Index hits: plan once on the oracle, execute the same index-mode
    # plan against both planes — counts and candidate expansions agree
    # (same rows, level layout differences notwithstanding).
    run = QueryRun(dq_o, Eq("domain", "c.com"), 0, T_SPAN, batched=False)
    if run.plan.mode == "index":
        c_o, _, _, tr_o, ca_o = dq_o.scan_index_range(run.plan, run.tree, 0, T_SPAN)
        c_s, _, _, tr_s, ca_s = dq_s.scan_index_range(run.plan, run.tree, 0, T_SPAN)
        assert (c_s, tr_s, ca_s) == (c_o, tr_o, ca_o)
        assert tr_o == 0


# ----------------------------------------------------- contention overlap
def test_disjoint_group_writers_do_not_contend():
    """Writers pinned to DISJOINT groups: each group lock has exactly one
    acquirer, so its acquire-wait books stay ~zero — while the same
    workload through a single-lock (G=1) plane queues every writer
    behind one lock and books real wait. This is the lock-split's whole
    point, asserted from the occupancy books (obs wait accounting)."""
    store = EventStore(web_proxy_schema(), n_shards=2)
    ts, vals = _events(7, 4000)
    cols = store.encode_events(np.asarray(ts, np.int64), vals)
    rts = keypack.rev_ts(np.asarray(ts, np.int64)).astype(np.int32)
    mesh = make_dev_mesh(1, 1)
    n_w = TPD  # one writer per group; tablets_per_group == 1 on 1 device

    def run(n_groups):
        # 4000 rows per writer into ONE tablet: mem_rows=1024 x max_runs=8
        # leaves minors only — no blocking major muddies the wait books.
        plane = _plane(store, mesh, n_groups=n_groups, mem_rows=1024, max_runs=8)
        for g in plane.groups:
            g.lock.reset()
        chunks = 20
        per = len(rts) // chunks

        def work(i):
            # Writer i only ever touches global tablet i -> group i when
            # G == TPD; all writers hit group 0's lock when G == 1.
            tab = np.full(per, i, np.int32)
            for c in range(chunks):
                sl = slice(c * per, (c + 1) * per)
                plane.ingest(rts[sl], cols[sl], tab, writer_id=i)

        threads = [threading.Thread(target=work, args=(i,)) for i in range(n_w)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert int(plane.telemetry()["rows"].sum()) == per * chunks * n_w
        assert plane.blocked_seconds == 0.0  # roomy max_runs: no majors
        return [g.lock.snapshot() for g in plane.groups]

    sharded = run(TPD)
    baseline = run(1)
    sharded_wait = sum(s["total_wait_s"] for s in sharded)
    baseline_wait = baseline[0]["total_wait_s"]
    # Every sharded group lock had a single acquirer: waits are the
    # microseconds of uncontended acquire, never queueing.
    assert all(s["total_wait_s"] < 0.05 for s in sharded), sharded
    # The single lock serialized 4 writers x 20 appends: it must have
    # booked MORE wait than all the uncontended group locks combined.
    assert baseline_wait > sharded_wait, (baseline_wait, sharded_wait)
    # Every group really did its appends (overlap, not starvation).
    assert all(s["by_owner_s"].get("ingest_append", 0) > 0 for s in sharded)


# ------------------------------------------------- facade + snapshot seams
def test_n_groups_must_divide_tablets():
    store = EventStore(web_proxy_schema(), n_shards=1)
    mesh = make_dev_mesh(1, 1)
    with pytest.raises(ValueError, match="divide"):
        _plane(store, mesh, n_groups=3)
    with pytest.raises(ValueError, match=">= 1"):
        DistIngestPlane(mesh, 4, capacity=64, n_groups=0)


def test_composite_publish_aliases_untouched_groups():
    """publish() composes per-group snapshots: a group untouched since
    its last seal ALIASES its previous sub-snapshot (no device work), a
    re-publish with nothing new anywhere returns the cached composite,
    and per-group gens surface under gens['g<i>']."""
    store = EventStore(web_proxy_schema(), n_shards=1)
    mesh = make_dev_mesh(1, 1)
    plane = _plane(store, mesh, n_groups=2)
    rts, cols, tab, _, _ = _encoded(store, 3, 600, TPD)
    plane.ingest(rts, cols, tab)
    ds1 = plane.publish()
    assert ds1.is_composite and len(ds1.groups) == 2
    assert set(ds1.gens) == {"g0", "g1"}
    assert plane.publish() is ds1  # clean plane: cached composite
    # Touch ONLY group 0's tablets (globals [0, 2) on the 2-group split).
    g0_tab = (tab % plane.tablets_per_group).astype(np.int32)
    plane.ingest(rts[:100], cols[:100], g0_tab[:100])
    ds2 = plane.publish()
    assert ds2 is not ds1
    assert ds2.groups[1] is ds1.groups[1]  # untouched group: aliased
    assert ds2.groups[0] is not ds1.groups[0]
    assert ds2.gens["g1"] == ds1.gens["g1"]
    # Composite reads see exactly the extra rows.
    dq = DistQueryProcessor(store, dist=ds2)
    count, _, _ = dq.scan_range(None, 0, T_SPAN)
    assert count == 700


def test_per_tablet_gauges_snapshot_host_mirrors():
    """The plane{n} registry gauges carry the exact per-tablet
    rows/minor/major mirrors after any publish()/telemetry() boundary,
    labeled by GLOBAL tablet id — and agree with the device counters."""
    store = EventStore(web_proxy_schema(), n_shards=1)
    mesh = make_dev_mesh(1, 1)
    plane = _plane(store, mesh, n_groups=2, mem_rows=128)
    rts, cols, tab, _, _ = _encoded(store, 5, 900, TPD)
    plane.ingest(rts, cols, tab)
    tel = plane.telemetry()
    rows_g = plane.metrics.gauge("plane_tablet_rows")
    minor_g = plane.metrics.gauge("plane_tablet_minor")
    major_g = plane.metrics.gauge("plane_tablet_major")
    for t in range(plane.n_tablets):
        assert rows_g.value(tablet=t) == float(tel["rows"][t])
        assert minor_g.value(tablet=t) == float(tel["minor"][t])
        assert major_g.value(tablet=t) == float(tel["major"][t])
    assert sum(rows_g.value(tablet=t) for t in range(TPD)) == 900


def test_blocked_per_writer_sums_to_scalar_across_groups():
    """Satellite bugfix guard: when one writer's blocking majors split
    across several groups, the per-writer cells still sum EXACTLY to the
    plane scalar (shared counter, one cell per writer), and tiny planes
    actually block."""
    store = EventStore(web_proxy_schema(), n_shards=1)
    mesh = make_dev_mesh(1, 1)
    plane = _plane(store, mesh, n_groups=4, capacity=20_000, mem_rows=64, max_runs=2)
    rts, cols, tab, _, _ = _encoded(store, 9, 3000, TPD)
    _threaded_ingest(plane, rts, cols, tab, n_writers=3)
    tel = plane.telemetry()
    per_writer = tel["blocked_seconds_per_writer"]
    assert int(tel["major"].sum()) >= 1  # tiny slabs: majors really fired
    assert plane.blocked_seconds > 0
    assert set(per_writer) <= {0, 1, 2}
    assert abs(sum(per_writer.values()) - float(tel["blocked_seconds"])) < 1e-9


def test_writer_routing_spreads_over_groups():
    """DistBatchWriter's row hash reaches every group (uniform tablet
    choice), and the full write-read loop stays exact on a sharded
    plane."""
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    plane = _plane(store, mesh, n_groups=4)
    ts, vals = _events(13, 2000)
    w = DistBatchWriter(store, plane, batch_rows=500)
    w.add(ts, vals)
    w.close()
    tel = plane.telemetry()
    per_group = tel["rows"].reshape(plane.n_groups, -1).sum(axis=1)
    assert (per_group > 0).all()  # hash routing reached every group
    dq = DistQueryProcessor(store, plane=plane)
    count, _, _ = dq.scan_range(None, 0, T_SPAN)
    assert count == 2000
