"""End-to-end store correctness: all four execution schemes must return
exactly the rows a numpy oracle selects, for randomized filter trees and
time ranges."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    And,
    Cmp,
    Eq,
    EventStore,
    Match,
    Not,
    Or,
    QueryProcessor,
    QueryStats,
    web_proxy_schema,
)
from repro.core.filter import TrueNode
from repro.core.planner import plan_query

N = 12_000
T_STOP = 2 * 3600


@pytest.fixture(scope="module")
def populated():
    rng = np.random.default_rng(42)
    store = EventStore(
        web_proxy_schema(), n_shards=4, flush_rows=2048, max_runs=4,
        agg_bucket_seconds=600,  # fine buckets: density sub-range test
    )
    ts = np.sort(rng.integers(0, T_STOP, N))
    data = {
        "domain": rng.choice(
            ["alpha.com", "beta.org", "gamma.net", "delta.io", "eps.gov"],
            p=[0.5, 0.3, 0.1, 0.07, 0.03],
            size=N,
        ),
        "method": rng.choice(["GET", "POST", "PUT"], size=N),
        "status": rng.choice(["200", "404", "500"], size=N, p=[0.7, 0.2, 0.1]),
        "bytes_out": rng.integers(100, 5000, N).astype(str),
    }
    vals = {k: v.tolist() for k, v in data.items()}
    for i in range(0, N, 3000):
        sl = slice(i, i + 3000)
        store.ingest(ts[sl], {k: v[sl] for k, v in vals.items()})
    store.flush_all()
    store.compact_all()
    return store, ts, data


def oracle_mask(data, ts, tree, t0, t1):
    import numpy as np

    time_m = (ts >= t0) & (ts <= t1)

    def ev(node):
        if isinstance(node, Eq):
            return data[node.field] == node.value
        if isinstance(node, Match):
            return np.char.startswith(data[node.field].astype(str), node.prefix)
        if isinstance(node, Cmp):
            x = data[node.field].astype(float)
            return {"<": x < node.value, "<=": x <= node.value, ">": x > node.value, ">=": x >= node.value}[node.op]
        if isinstance(node, Not):
            return ~ev(node.child)
        if isinstance(node, And):
            m = ev(node.children[0])
            for c in node.children[1:]:
                m &= ev(c)
            return m
        if isinstance(node, Or):
            m = ev(node.children[0])
            for c in node.children[1:]:
                m |= ev(c)
            return m
        raise TypeError(node)

    return time_m & (ev(tree) if tree is not None else np.ones(len(ts), bool))


TREES = [
    Eq("domain", "gamma.net"),
    Eq("domain", "never-seen.com"),
    And(Eq("domain", "alpha.com"), Eq("status", "404")),
    And(Eq("domain", "eps.gov"), Eq("method", "GET"), Eq("status", "200")),
    Or(Eq("domain", "delta.io"), Eq("domain", "eps.gov")),
    And(Eq("domain", "beta.org"), Not(Eq("method", "PUT"))),
    Not(Eq("status", "200")),
    Match("domain", "a"),
    And(Eq("method", "POST"), Cmp("bytes_out", "<", 1000)),
    Or(And(Eq("domain", "alpha.com"), Eq("status", "500")), Eq("domain", "gamma.net")),
    None,
]


@pytest.mark.parametrize("tree", TREES)
@pytest.mark.parametrize("scheme", ["scan", "batched_scan", "index", "batched_index"])
def test_schemes_match_oracle(populated, tree, scheme):
    store, ts, data = populated
    qp = QueryProcessor(store)
    t0, t1 = 1000, 6000
    got = sum(b.n for b in qp.run_scheme(scheme, t0, t1, tree))
    assert got == int(oracle_mask(data, ts, tree, t0, t1).sum())


@given(t0=st.integers(0, T_STOP), span=st.integers(0, T_STOP))
@settings(max_examples=20, deadline=None)
def test_random_time_ranges(populated, t0, span):
    store, ts, data = populated
    qp = QueryProcessor(store)
    t1 = min(t0 + span, T_STOP)
    tree = Eq("status", "404")
    got = sum(b.n for b in qp.run_scheme("batched_index", t0, t1, tree))
    assert got == int(oracle_mask(data, ts, tree, t0, t1).sum())


def test_planner_heuristics(populated):
    store, ts, data = populated
    # H1: root Eq -> index.
    p = plan_query(store, Eq("domain", "alpha.com"), 0, T_STOP)
    assert p.mode == "index" and len(p.index_conds) == 1
    # H2: OR of all-Eq -> union.
    p = plan_query(store, Or(Eq("domain", "alpha.com"), Eq("domain", "beta.org")), 0, T_STOP)
    assert p.mode == "index" and p.combine == "union" and len(p.index_conds) == 2
    # H3: AND selects rare children (d_i < w * d_min): eps.gov rare vs
    # alpha.com common -> only the rare one indexed with default w=10 when
    # densities differ >10x.
    p = plan_query(store, And(Eq("domain", "eps.gov"), Eq("domain", "alpha.com")), 0, T_STOP)
    assert p.mode == "index"
    fields = [(c.field, c.value) for c in p.index_conds]
    assert ("domain", "eps.gov") in fields
    assert ("domain", "alpha.com") not in fields  # too dense to intersect
    # H4: non-Eq root -> filter mode.
    p = plan_query(store, Not(Eq("status", "200")), 0, T_STOP)
    assert p.mode == "filter"
    # OR with a non-Eq child -> filter mode.
    p = plan_query(store, Or(Eq("domain", "alpha.com"), Not(Eq("status", "200"))), 0, T_STOP)
    assert p.mode == "filter"


def test_aggregate_density_estimates(populated):
    store, ts, data = populated
    got = store.agg_count("domain", "eps.gov", 0, T_STOP)
    assert got == int((data["domain"] == "eps.gov").sum())
    # Sub-range estimate: bucketed, so approximately proportional.
    half = store.agg_count("domain", "alpha.com", 0, T_STOP // 2)
    full = store.agg_count("domain", "alpha.com", 0, T_STOP)
    assert 0.3 < half / full < 0.7


def test_batched_stats_record_batches(populated):
    store, ts, data = populated
    qp = QueryProcessor(store)
    stats = QueryStats()
    rows = sum(b.n for b in qp.run_scheme("batched_index", 0, T_STOP, Eq("domain", "alpha.com"), stats=stats))
    assert stats.batches > 1
    assert stats.rows == rows
    assert stats.plan is not None and stats.plan.mode == "index"


def test_results_newest_first_within_shard(populated):
    store, ts, data = populated
    qp = QueryProcessor(store)
    for blk in qp.run_scheme("scan", 0, T_STOP, Eq("domain", "beta.org")):
        t = blk.ts()
        assert (np.diff(t) <= 0).all()  # reversed timestamps: newest first
        break


def test_backpressure_counters(populated):
    store, _, _ = populated
    bp = store.backpressure_stats()
    assert bp["rows"] == N
    assert bp["minor_compactions"] > 0
