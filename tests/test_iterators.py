"""Server-side iterator stack: every iterator vs a pure-numpy oracle, the
fused combine_scan kernel on both backends, stacked composition, and
host-vs-distributed agreement on aggregation results."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AggregateSpec,
    And,
    CombinerIterator,
    Eq,
    EventStore,
    FilterIterator,
    IteratorStack,
    Not,
    Or,
    ProjectingIterator,
    QueryProcessor,
    QueryStats,
    VersioningIterator,
    web_proxy_schema,
)
from repro.core.filter import compile_tree, eval_tree_rows
from repro.core.iterators import resolve_grouping
from repro.core.scan import RowBlock, scan_events
from repro.kernels.combine_scan import combine_scan

T_STOP = 4 * 3600
N = 18_000


@pytest.fixture(scope="module")
def populated():
    rng = np.random.default_rng(11)
    store = EventStore(web_proxy_schema(), n_shards=4, flush_rows=4096)
    ts = np.sort(rng.integers(0, T_STOP, N))
    data = {
        "domain": rng.choice(
            ["alpha.com", "beta.org", "gamma.net", "delta.io"],
            p=[0.5, 0.3, 0.15, 0.05],
            size=N,
        ),
        "method": rng.choice(["GET", "POST", "PUT"], size=N),
        "status": rng.choice(["200", "404", "500"], size=N, p=[0.7, 0.2, 0.1]),
        "bytes_out": rng.integers(100, 5000, N).astype(str),
    }
    store.ingest(ts, {k: v.tolist() for k, v in data.items()})
    store.flush_all()
    store.compact_all()
    return store, ts, data


# ------------------------------------------------------------- aggregation
def agg_oracle(store, ts, data, spec, tree, t0, t1):
    """Pure-numpy client-side aggregation oracle."""
    m = (ts >= t0) & (ts <= t1)
    if tree is not None:
        cols = store.encode_events(ts, {k: v.tolist() for k, v in data.items()})
        m &= eval_tree_rows(store, tree, cols)
    vals = (
        data[spec.value_field].astype(int)
        if spec.value_field is not None
        else np.ones(len(ts), int)
    )
    groups = {}
    idx = np.flatnonzero(m)
    for i in idx:
        key = tuple(data[f][i] for f in spec.group_by)
        if spec.time_bucket_s is not None:
            key = key + (int(ts[i]) // spec.time_bucket_s * spec.time_bucket_s,)
        agg, cnt = groups.get(key, (None, 0))
        v = int(vals[i])
        if agg is None:
            agg = v if spec.op != "count" else 1
        elif spec.op in ("count",):
            agg += 1
        elif spec.op == "sum":
            agg += v
        elif spec.op == "min":
            agg = min(agg, v)
        else:
            agg = max(agg, v)
        groups[key] = (agg, cnt + 1)
    return groups


def result_to_dict(store, spec, res):
    out = {}
    for row in res.rows(store):
        key = tuple(row[f] for f in spec.group_by)
        if spec.time_bucket_s is not None:
            key = key + (row["bucket_ts"],)
        out[key] = (row["value"], row["count"])
    return out


SPECS = [
    AggregateSpec(group_by=("method",), op="count"),
    AggregateSpec(group_by=("status",), op="count", time_bucket_s=3600),
    AggregateSpec(group_by=("status", "method"), op="count"),
    AggregateSpec(group_by=("method",), op="sum", value_field="bytes_out"),
    AggregateSpec(group_by=("method",), op="min", value_field="bytes_out"),
    AggregateSpec(group_by=("status",), op="max", value_field="bytes_out", time_bucket_s=1800),
]

TREES = [
    None,
    Eq("domain", "alpha.com"),
    And(Eq("domain", "beta.org"), Not(Eq("status", "500"))),
    Or(Eq("domain", "gamma.net"), Eq("status", "404")),
]


@pytest.mark.parametrize("spec", SPECS)
def test_combiner_matches_oracle(populated, spec):
    store, ts, data = populated
    qp = QueryProcessor(store)
    tree = And(Eq("domain", "alpha.com"), Not(Eq("status", "500")))
    res = qp.aggregate(spec, 1000, T_STOP - 1000, tree)
    want = agg_oracle(store, ts, data, spec, tree, 1000, T_STOP - 1000)
    assert result_to_dict(store, spec, res) == want


@pytest.mark.parametrize("tree", TREES)
def test_combiner_trees_and_schemes(populated, tree):
    store, ts, data = populated
    spec = AggregateSpec(group_by=("method",), op="count", time_bucket_s=3600)
    want = agg_oracle(store, ts, data, spec, tree, 0, T_STOP)
    for use_index, batched in [(False, True), (False, False), (True, True)]:
        qp = QueryProcessor(store)
        res = qp.aggregate(spec, 0, T_STOP, tree, use_index=use_index, batched=batched)
        assert result_to_dict(store, spec, res) == want, (use_index, batched)


def test_combine_scan_scheme_streams_aggregate_blocks(populated):
    store, ts, data = populated
    qp = QueryProcessor(store)
    spec = AggregateSpec(group_by=("method",), op="count")
    stats = QueryStats()
    blocks = list(
        qp.run_scheme(
            "combine_scan", 0, T_STOP, Eq("domain", "alpha.com"),
            aggregate=spec, stats=stats,
        )
    )
    assert stats.batches > 1  # adaptive batching drove the combine scan
    total = sum(b.matched for b in blocks)
    assert total == int((data["domain"] == "alpha.com").sum())
    # aggregate partials are tiny compared to the rows they summarize
    assert sum(b.nbytes for b in blocks) < total * 8


def test_combine_scan_scheme_requires_spec(populated):
    store, _, _ = populated
    with pytest.raises(ValueError):
        next(iter(QueryProcessor(store).run_scheme("combine_scan", 0, T_STOP)))


@given(seed=st.integers(0, 2**31), n=st.integers(1, 3000))
@settings(max_examples=10, deadline=None)
def test_combine_scan_kernel_backends_agree(populated, seed, n):
    store, _, _ = populated
    rng = np.random.default_rng(seed)
    f = store.schema.n_fields
    cols = np.zeros((n, f), np.int32)
    for name in ["domain", "method", "status"]:
        fid = store.schema.field_id(name)
        cols[:, fid] = rng.integers(0, max(len(store.dictionaries[name]), 1), n)
    gids = np.sort(rng.integers(0, 50, n).astype(np.int64))
    vals = rng.integers(1, 1000, n).astype(np.int32)
    tree = Or(Eq("domain", "alpha.com"), Eq("status", "404"))
    prog = compile_tree(store, tree)
    mask = eval_tree_rows(store, tree, cols)
    for op in ["count", "sum", "min", "max"]:
        ref = combine_scan(gids, vals, cols, prog, op=op, backend="ref")
        pal = combine_scan(gids, vals, cols, prog, op=op, backend="pallas")
        for a, b in zip(ref, pal):
            np.testing.assert_array_equal(a, b)
        # numpy oracle
        uk, aggs, cnts = ref
        live = np.unique(gids[mask])
        np.testing.assert_array_equal(uk, live)
        for i, g in enumerate(uk):
            sel = vals[(gids == g) & mask]
            want = {"count": len(sel), "sum": sel.sum(), "min": sel.min(), "max": sel.max()}[op]
            assert aggs[i] == want, (op, g)
            assert cnts[i] == len(sel)


def test_combine_scan_tile_straddle(populated):
    """One group spanning several Pallas tiles must stitch across the
    epilogue, including with a filter that kills part of the group."""
    from repro.kernels.combine_scan.combine_scan import BLOCK

    store, _, _ = populated
    n = BLOCK * 3
    f = store.schema.n_fields
    cols = np.zeros((n, f), np.int32)
    sfid = store.schema.field_id("status")
    code_200 = store.dictionaries["status"].lookup("200")
    code_404 = store.dictionaries["status"].lookup("404")
    cols[:, sfid] = code_404
    cols[::2, sfid] = code_200  # half the rows filtered out
    gids = np.zeros(n, np.int64)
    vals = np.arange(1, n + 1, dtype=np.int32)
    prog = compile_tree(store, Eq("status", "200"))
    uk, aggs, cnts = combine_scan(gids, vals, cols, prog, op="sum", backend="pallas")
    assert list(uk) == [0]
    assert int(cnts[0]) == n // 2
    assert int(aggs[0]) == int(vals[::2].sum())


# ------------------------------------------------------------- versioning
def _block_with_dups(rng, n_keys, max_dup):
    keys = np.sort(rng.choice(np.arange(n_keys) * 7 + 3, size=n_keys * max_dup))
    cols = rng.integers(0, 100, (len(keys), 3)).astype(np.int32)
    return RowBlock(0, keys.astype(np.int64), cols)


@given(seed=st.integers(0, 2**31), max_versions=st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_versioning_matches_oracle(seed, max_versions):
    rng = np.random.default_rng(seed)
    blk = _block_with_dups(rng, 50, 5)
    out = VersioningIterator(max_versions).apply(blk)
    # oracle: first max_versions rows per unique key, in order
    seen = {}
    keep = []
    for i, k in enumerate(blk.keys):
        seen[k] = seen.get(k, 0) + 1
        if seen[k] <= max_versions:
            keep.append(i)
    np.testing.assert_array_equal(out.keys, blk.keys[keep])
    np.testing.assert_array_equal(out.cols, blk.cols[keep])


def test_versioning_newest_wins(populated):
    """max_versions=1 keeps exactly one entry per key and it is the FIRST
    occurrence — which, under the rev_ts key layout, is the newest."""
    rng = np.random.default_rng(0)
    blk = _block_with_dups(rng, 30, 3)
    out = VersioningIterator(1).apply(blk)
    uk, first_idx = np.unique(blk.keys, return_index=True)
    np.testing.assert_array_equal(out.keys, uk)
    np.testing.assert_array_equal(out.cols, blk.cols[first_idx])


# ------------------------------------------------------- stack composition
def test_stack_composition_matches_oracle(populated):
    store, ts, data = populated
    tree = Eq("domain", "beta.org")
    stack = IteratorStack(
        [
            VersioningIterator(1),
            FilterIterator(store, tree),
            ProjectingIterator(store, ["domain", "status"]),
        ]
    )
    got_rows = 0
    for blk in scan_events(store, 1000, 8000, iterators=stack):
        assert blk.cols.shape[1] == 2  # projected
        assert blk.field_ids is not None
        dom_codes = blk.cols[:, 0]
        assert (dom_codes == store.dictionaries["domain"].lookup("beta.org")).all()
        got_rows += blk.n
    want = int(
        ((data["domain"] == "beta.org") & (ts >= 1000) & (ts <= 8000)).sum()
    )
    assert got_rows == want  # event keys are unique: versioning drops nothing


def test_stack_projection_shrinks_bytes(populated):
    store, _, _ = populated
    full = sum(b.nbytes for b in scan_events(store, 0, 6000))
    stack = IteratorStack([ProjectingIterator(store, ["domain"])])
    proj = sum(b.nbytes for b in scan_events(store, 0, 6000, iterators=stack))
    assert proj < full / 3  # 1 of 12 columns + keys


def test_stack_ordering_validation(populated):
    store, _, _ = populated
    grouping = resolve_grouping(
        store, AggregateSpec(group_by=("method",), op="count"), 0, T_STOP
    )
    comb = CombinerIterator(grouping)
    with pytest.raises(ValueError):
        IteratorStack([comb, VersioningIterator()])  # combiner must be last
    with pytest.raises(ValueError):
        IteratorStack([ProjectingIterator(store, ["domain"]), FilterIterator(store, Eq("domain", "x"))])
    # valid: versioning -> filter -> combiner
    IteratorStack([VersioningIterator(), FilterIterator(store, Eq("domain", "alpha.com")), comb])


def test_stack_terminal_combiner_in_scan(populated):
    store, ts, data = populated
    spec = AggregateSpec(group_by=("method",), op="count")
    grouping = resolve_grouping(store, spec, 0, T_STOP)
    prog = compile_tree(store, Eq("domain", "alpha.com"))
    stack = IteratorStack([CombinerIterator(grouping, prog=prog)])
    from repro.core import merge_aggregate_blocks

    res = merge_aggregate_blocks(grouping, list(scan_events(store, 0, T_STOP, iterators=stack)))
    want = agg_oracle(store, ts, data, spec, Eq("domain", "alpha.com"), 0, T_STOP)
    assert result_to_dict(store, spec, res) == want


# ------------------------------------------------- host vs dist agreement
@pytest.fixture(scope="module")
def dist_setup(populated):
    from repro.core.dist_query import DistQueryProcessor, from_event_store
    from repro.launch.mesh import make_dev_mesh

    store, ts, data = populated
    mesh = make_dev_mesh(1, 1)
    dist = from_event_store(store, mesh)
    return DistQueryProcessor(store, dist)


@pytest.mark.parametrize("spec", SPECS)
def test_host_vs_dist_aggregation(populated, dist_setup, spec):
    store, ts, data = populated
    tree = And(Eq("domain", "alpha.com"), Not(Eq("status", "500")))
    host = QueryProcessor(store).aggregate(spec, 1000, T_STOP - 1000, tree)
    dist = dist_setup.aggregate_range(spec, tree, 1000, T_STOP - 1000)
    np.testing.assert_array_equal(host.gids, dist.gids)
    np.testing.assert_array_equal(host.values, dist.values)
    np.testing.assert_array_equal(host.counts, dist.counts)


@given(t0=st.integers(0, T_STOP), span=st.integers(600, T_STOP))
@settings(max_examples=8, deadline=None)
def test_host_vs_dist_random_ranges(populated, dist_setup, t0, span):
    store, ts, data = populated
    t1 = min(t0 + span, T_STOP)
    spec = AggregateSpec(group_by=("status",), op="count", time_bucket_s=900)
    tree = Eq("method", "GET")
    host = QueryProcessor(store).aggregate(spec, t0, t1, tree)
    dist = dist_setup.aggregate_range(spec, tree, t0, t1)
    np.testing.assert_array_equal(host.gids, dist.gids)
    np.testing.assert_array_equal(host.values, dist.values)
    np.testing.assert_array_equal(host.counts, dist.counts)
