"""reprolint (repro.analysis): per-rule good/bad snippet corpus, inline
suppression + baseline ratchet semantics, CLI exit codes, the self-clean
gate (the checked-in tree must lint clean against the checked-in
baseline), and a mutation test proving guarded-by catches a removed lock
wrapper in a scratch copy of the real ingest plane."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    load_baseline,
    render_json,
    render_text,
    run_analysis,
)
from repro.analysis.engine import Baseline, BaselineEntry, default_baseline_path
from repro.analysis.rules import REGISTRY
from repro.analysis.rules.guarded_by import GuardedByRule
from repro.analysis.rules.hot_path import HotPathSyncRule
from repro.analysis.rules.jit_purity import JitPurityRule
from repro.analysis.rules.kernel_contract import KernelContractRule
from repro.analysis.rules.no_donate import NoDonateInPlaneRule

REPO = Path(__file__).resolve().parents[1]


def lint(tmp_path, source, name="mod.py", rules=None):
    """Write one snippet and run the given rules over it (no baseline)."""
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return run_analysis([str(p)], rules=rules)


# ----------------------------------------------------------------- guarded-by
GUARDED_SRC = """
    import threading

    class Plane:
        def __init__(self):
            self._lock = threading.Lock()
            self._fill = 0  # guarded-by: _lock

        def bad(self):
            return self._fill + 1

        def good_with(self):
            with self._lock:
                return self._fill

        def good_hold(self):
            with self._lock.hold("x"):
                self._fill += 1

        def good_holds(self):  # holds: _lock
            return self._fill

        def good_suppressed(self):
            return self._fill  # reprolint: disable=guarded-by
"""


def test_guarded_by_flags_only_unlocked_access(tmp_path):
    res = lint(tmp_path, GUARDED_SRC, rules=[GuardedByRule()])
    assert [f.rule for f in res.fresh] == ["guarded-by"]
    assert "self._fill + 1" in res.fresh[0].snippet
    assert "_lock" in res.fresh[0].message


def test_guarded_by_decorator_annotation_and_dotted_lock(tmp_path):
    src = """
        import threading

        def deco(f):
            return f

        class P:
            def __init__(self, sched):
                self.sched = sched
                self._q = []  # guarded-by: sched._cv

            # holds: sched._cv
            @deco
            def annotated_above(self):
                return len(self._q)

            def locked(self):
                with self.sched._cv:
                    return list(self._q)

            def bad(self):
                return self._q
    """
    res = lint(tmp_path, src, rules=[GuardedByRule()])
    assert [f.snippet for f in res.fresh] == ["return self._q"]


# ------------------------------------------------------- no-sync-in-hot-path
HOT_SRC = """
    import numpy as np
    import jax

    # reprolint: hot-path
    def hot(step, sp, x):
        a = x.item()
        jax.block_until_ready(x)
        b = np.asarray(x)
        c = float(step(x))
        d = np.asarray(sp.fence(x))
        e = int(sp.fence(step(x)))
        f = int(a)
        return a, b, c, d, e, f

    def cold(step, x):
        return float(step(x.item()))
"""


def test_hot_path_sync_corpus(tmp_path):
    res = lint(tmp_path, HOT_SRC, rules=[HotPathSyncRule()])
    assert all(f.rule == "no-sync-in-hot-path" for f in res.fresh)
    snippets = [f.snippet for f in res.fresh]
    # Exactly the four syncs in hot(); the fenced forms, the Name
    # coercion, and everything in the untagged cold() stay clean.
    assert snippets == [
        "a = x.item()",
        "jax.block_until_ready(x)",
        "b = np.asarray(x)",
        "c = float(step(x))",
    ]


def test_hot_path_nested_def_inherits_tag(tmp_path):
    src = """
        # reprolint: hot-path
        def outer(x):
            def inner():
                return x.item()
            return inner()
    """
    res = lint(tmp_path, src, rules=[HotPathSyncRule()])
    assert len(res.fresh) == 1 and ".item()" in res.fresh[0].message


# ----------------------------------------------------------------- jit-purity
JIT_BAD_SRC = """
    import time
    import jax
    import jax.numpy as jnp

    events = []
    cache = {}

    class Thing:
        def build(self):
            def step(x):
                self.seen = x          # self-mutation at trace time
                events.append(1)       # closed-over container
                cache["k"] = x         # closed-over subscript store
                t = time.time()        # host nondeterminism
                y = jnp.sum(x)         # fine: imported module
                zs = []
                zs.append(y)           # fine: local
                return y + t
            return jax.jit(step)
"""


def test_jit_purity_flags_impure_traced_fn(tmp_path):
    res = lint(tmp_path, JIT_BAD_SRC, rules=[JitPurityRule()])
    msgs = " | ".join(f.message for f in res.fresh)
    assert len(res.fresh) == 4
    assert "self.seen" in msgs
    assert "'events." in msgs
    assert "'cache'" in msgs
    assert "time.time" in msgs


def test_jit_purity_decorator_and_clean_fn(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp
        from functools import partial

        @jax.jit
        def pure(x):
            acc = {}
            acc["k"] = jnp.sum(x)
            return acc["k"]

        def helper(x):
            out = []
            out.append(x)
            return out[0]

        stepped = jax.jit(partial(helper))
    """
    res = lint(tmp_path, src, rules=[JitPurityRule()])
    assert res.fresh == []


def test_jit_purity_only_checks_traced_functions(tmp_path):
    src = """
        import time

        def untraced():
            return time.time()  # ordinary host code: not the rule's business
    """
    res = lint(tmp_path, src, rules=[JitPurityRule()])
    assert res.fresh == []


# ---------------------------------------------------------- no-donate-in-plane
DONATE_SRC = """
    import jax

    def build(fn):
        return jax.jit(fn, donate_argnums=(0,))
"""


def test_no_donate_fires_only_in_plane_files(tmp_path):
    bad = lint(
        tmp_path, DONATE_SRC, name="src/repro/core/dist_ingest.py",
        rules=[NoDonateInPlaneRule()],
    )
    assert [f.rule for f in bad.fresh] == ["no-donate-in-plane"]
    ok = lint(
        tmp_path, DONATE_SRC, name="src/repro/core/elsewhere.py",
        rules=[NoDonateInPlaneRule()],
    )
    assert ok.fresh == []


def test_no_donate_inline_suppression(tmp_path):
    src = DONATE_SRC.replace(
        "donate_argnums=(0,))",
        "donate_argnums=(0,))  # reprolint: disable=no-donate-in-plane",
    )
    res = lint(
        tmp_path, src, name="src/repro/core/dist_query.py",
        rules=[NoDonateInPlaneRule()],
    )
    assert res.fresh == []


# ------------------------------------------------------------- kernel-contract
def _write(root: Path, rel: str, body: str) -> None:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))


def test_kernel_contract_good_package(tmp_path):
    _write(tmp_path, "kernels/common.py", "def pow2(n):\n    return 1 << (n - 1).bit_length()\n")
    _write(tmp_path, "kernels/goodpkg/__init__.py",
           "from .ops import scan\nfrom .ref import scan_ref\nfrom .goodpkg import scan_pallas\n")
    _write(tmp_path, "kernels/goodpkg/ops.py", "def scan(x, n):\n    return x\n")
    _write(tmp_path, "kernels/goodpkg/ref.py", "def scan_ref(x, n):\n    return x\n")
    _write(tmp_path, "kernels/goodpkg/goodpkg.py", "def scan_pallas(x, n):\n    return x\n")
    res = run_analysis([str(tmp_path / "kernels")], rules=[KernelContractRule()])
    assert res.fresh == []


def test_kernel_contract_bad_package(tmp_path):
    _write(tmp_path, "kernels/common.py", "def pow2(n):\n    return 1 << (n - 1).bit_length()\n")
    _write(tmp_path, "kernels/badpkg/__init__.py", "from .ops import broken_pallas\n")
    _write(tmp_path, "kernels/badpkg/ops.py",
           "def broken_pallas(x, n):\n    return x\n"
           "def other_pallas(x):\n    return x\n"
           "def _pow2(n):\n    return 1\n")
    _write(tmp_path, "kernels/badpkg/ref.py", "def broken_ref(x, m):\n    return x\n")
    res = run_analysis([str(tmp_path / "kernels")], rules=[KernelContractRule()])
    msgs = [f.message for f in res.fresh]
    assert len(msgs) == 4
    assert any("does not re-export from .ref" in m for m in msgs)
    assert any("no 'other_ref'" in m for m in msgs)
    assert any("!= 'broken_ref' params" in m for m in msgs)
    assert any("re-implements shared kernel helper 'pow2'" in m for m in msgs)


def test_kernel_contract_missing_ref_file(tmp_path):
    _write(tmp_path, "kernels/noref/__init__.py", "")
    _write(tmp_path, "kernels/noref/ops.py", "def f_pallas(x):\n    return x\n")
    res = run_analysis([str(tmp_path / "kernels")], rules=[KernelContractRule()])
    assert len(res.fresh) == 1 and "missing ref.py" in res.fresh[0].message


# ------------------------------------------------- suppression + baseline
def test_disable_all_suppresses_every_rule(tmp_path):
    src = GUARDED_SRC.replace(
        "return self._fill + 1",
        "return self._fill + 1  # reprolint: disable=all",
    )
    res = lint(tmp_path, src, rules=[GuardedByRule()])
    assert res.fresh == []


def test_baseline_match_and_ratchet(tmp_path):
    res = lint(tmp_path, GUARDED_SRC, rules=[GuardedByRule()])
    (f,) = res.fresh
    entry = BaselineEntry(
        rule=f.rule, file=f.path, snippet=f.snippet, justification="known"
    )
    stale_entry = BaselineEntry(
        rule=f.rule, file=f.path, snippet="gone_line()", justification="old"
    )
    # Matching entry: finding moves to `baselined`, run passes.
    ok = run_analysis(
        [str(tmp_path / "mod.py")], rules=[GuardedByRule()],
        baseline=Baseline(None, [entry]),
    )
    assert ok.fresh == [] and len(ok.baselined) == 1 and not ok.failed
    # A stale entry is itself a failure: the baseline only shrinks.
    stale = run_analysis(
        [str(tmp_path / "mod.py")], rules=[GuardedByRule()],
        baseline=Baseline(None, [entry, stale_entry]),
    )
    assert stale.stale_baseline == [stale_entry] and stale.failed


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({
        "version": 1,
        "entries": [{"rule": "r", "file": "f.py", "snippet": "x", "justification": "  "}],
    }))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(str(p))


def test_reporters_render(tmp_path):
    res = lint(tmp_path, GUARDED_SRC, rules=[GuardedByRule()])
    text = render_text(res)
    assert "[guarded-by]" in text and "1 finding(s)" in text
    doc = json.loads(render_json(res))
    assert doc["failed"] and doc["counts"]["fresh"] == 1
    assert doc["findings"][0]["rule"] == "guarded-by"


def test_parse_error_fails_run(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    res = run_analysis([str(tmp_path / "broken.py")], rules=[GuardedByRule()])
    assert res.parse_errors and res.failed


# --------------------------------------------------------------- CLI contract
def _run_cli(*argv, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=cwd or str(REPO),
    )


def test_cli_exit_codes_and_json(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(GUARDED_SRC))
    proc = _run_cli(str(bad), "--no-baseline", "--format=json")
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["failed"] and doc["counts"]["fresh"] == 1
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    proc = _run_cli(str(good), "--no-baseline")
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ----------------------------------------------------------- self-clean gates
def test_repo_tree_is_reprolint_clean():
    """The CI gate in library form: the checked-in tree has zero fresh
    findings against the checked-in baseline, and no baseline entry is
    stale (the ratchet)."""
    baseline = load_baseline(default_baseline_path())
    assert baseline.entries, "expected at least the documented busy() entry"
    res = run_analysis([str(REPO / "src")], baseline=baseline)
    assert res.parse_errors == []
    assert res.fresh == [], render_text(res)
    assert res.stale_baseline == []


def test_guarded_by_catches_removed_lock_in_dist_ingest_copy(tmp_path):
    """Mutation test on the real plane: strip ONE lock wrapper from a
    scratch copy of core/dist_ingest.py and guarded-by must fire on the
    now-unprotected shared state; the unmodified copy stays clean."""
    src = (REPO / "src/repro/core/dist_ingest.py").read_text()
    clean = lint(tmp_path, src, name="clean/dist_ingest.py", rules=[GuardedByRule()])
    assert clean.fresh == []

    marker = 'with self._meta_lock.hold("bookkeeping"):'
    i = src.index("def telemetry(")
    j = src.index(marker, i)
    mutated = src[:j] + "if True:" + src[j + len(marker):]
    res = lint(tmp_path, mutated, name="mut/dist_ingest.py", rules=[GuardedByRule()])
    assert res.fresh, "removing the telemetry lock hold must trip guarded-by"
    attrs = " ".join(f.message for f in res.fresh)
    assert "session_stats" in attrs or "'self.state'" in attrs


def test_registry_covers_all_five_rules():
    names = {cls.name for cls in REGISTRY}
    assert names == {
        "guarded-by",
        "no-sync-in-hot-path",
        "jit-purity",
        "no-donate-in-plane",
        "kernel-contract",
    }
