"""Distributed (shard_map) query executor vs the host-store oracle."""
import numpy as np
import pytest

from repro.core import And, Eq, EventStore, Not, Or, web_proxy_schema
from repro.core.dist_query import DistQueryProcessor, from_event_store
from repro.core.query import QueryStats
from repro.launch.mesh import make_dev_mesh


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(3)
    store = EventStore(web_proxy_schema(), n_shards=4)
    n = 15000
    ts = np.sort(rng.integers(0, 4 * 3600, n))
    vals = {
        "domain": rng.choice(["a.com", "b.com", "c.com"], p=[0.6, 0.3, 0.1], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n).tolist(),
    }
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    dist = from_event_store(store, mesh)
    return store, dist, ts, {k: np.array(v) for k, v in vals.items()}


TREES = [
    (Eq("domain", "c.com"), lambda v: v["domain"] == "c.com"),
    (
        And(Eq("domain", "b.com"), Not(Eq("method", "POST"))),
        lambda v: (v["domain"] == "b.com") & (v["method"] != "POST"),
    ),
    (
        Or(Eq("status", "404"), Eq("domain", "c.com")),
        lambda v: (v["status"] == "404") | (v["domain"] == "c.com"),
    ),
]


@pytest.mark.parametrize("tree,mask_fn", TREES)
@pytest.mark.parametrize("t_range", [(0, 4 * 3600), (1800, 5400)])
def test_dist_count_matches_oracle(setup, tree, mask_fn, t_range):
    store, dist, ts, vals = setup
    dq = DistQueryProcessor(store, dist)
    t0, t1 = t_range
    count, top_ts, top_cols = dq.scan_range(tree, t0, t1)
    expect = int((mask_fn(vals) & (ts >= t0) & (ts <= t1)).sum())
    assert count == expect
    # top-k rows really match the filter + range.
    assert (top_ts >= t0).all() and (top_ts <= t1).all()
    dom_fid = store.schema.field_id("domain")
    if isinstance(tree, Eq):
        code = store.dictionaries["domain"].lookup(tree.value)
        assert (top_cols[:, dom_fid] == code).all()


def test_dist_batched_driver(setup):
    store, dist, ts, vals = setup
    dq = DistQueryProcessor(store, dist)
    stats = QueryStats()
    res = dq.execute_batched(Eq("domain", "c.com"), 0, 4 * 3600, stats=stats)
    total = sum(c for c, _, _ in res)
    assert total == int((vals["domain"] == "c.com").sum())
    assert stats.batches > 1  # adaptive batching actually batched


def test_store_cell_shapes():
    from repro.core.dist_query import dist_store_shapes

    mesh = make_dev_mesh(1, 1)
    shapes = dist_store_shapes(mesh, 1000, 12)
    assert shapes["cols"].shape == (1, 1000, 12)
