"""Key packing: roundtrips and the sorted-key range-scan property that the
whole store depends on."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import keypack


@given(
    shard=st.integers(0, keypack.MAX_SHARDS - 1),
    rts=st.integers(0, keypack.TS_MAX),
    h=st.integers(0, keypack.HASH_MAX),
)
def test_event_key_roundtrip(shard, rts, h):
    key = keypack.pack_event_key(shard, rts, h)
    s, r, hh = keypack.unpack_event_key(key)
    assert (int(s), int(r), int(hh)) == (shard, rts, h)
    assert int(key) >= 0  # positive int64: sorts correctly


@given(
    field=st.integers(0, keypack.MAX_FIELDS - 1),
    value=st.integers(0, keypack.MAX_VALUES - 1),
    rts=st.integers(0, keypack.TS_MAX),
)
def test_index_key_roundtrip(field, value, rts):
    f, v, r = keypack.unpack_index_key(keypack.pack_index_key(field, value, rts))
    assert (int(f), int(v), int(r)) == (field, value, rts)


@given(st.data())
@settings(max_examples=50)
def test_key_order_matches_tuple_order(data):
    """Packed int64 order == lexicographic (shard, rev_ts, hash) order."""
    tups = data.draw(
        st.lists(
            st.tuples(
                st.integers(0, keypack.MAX_SHARDS - 1),
                st.integers(0, keypack.TS_MAX),
                st.integers(0, keypack.HASH_MAX),
            ),
            min_size=2,
            max_size=50,
        )
    )
    keys = [int(keypack.pack_event_key(*t)) for t in tups]
    assert sorted(range(len(tups)), key=lambda i: keys[i]) == sorted(
        range(len(tups)), key=lambda i: tups[i]
    )


@given(
    shard=st.integers(0, keypack.MAX_SHARDS - 1),
    t0=st.integers(0, keypack.TS_MAX - 1),
    span=st.integers(0, 10_000),
    ts=st.integers(0, keypack.TS_MAX),
    h=st.integers(0, keypack.HASH_MAX),
)
def test_event_range_covers_exactly_its_timestamps(shard, t0, span, ts, h):
    """A key falls in event_key_range(shard, t0, t1) iff t0 <= ts <= t1 —
    the paper's 'restrict by timestamp with essentially zero cost'."""
    t1 = min(t0 + span, keypack.TS_MAX)
    lo, hi = keypack.event_key_range(shard, t0, t1)
    key = keypack.pack_event_key(shard, keypack.rev_ts(ts), h)
    assert (int(lo) <= int(key) < int(hi)) == (t0 <= ts <= t1)


def test_short_hash_spread():
    rng = np.random.default_rng(0)
    cols = rng.integers(0, 100, (20_000, 3))
    h = keypack.short_hash(*(cols[:, i] for i in range(3)), np.arange(20_000))
    # Should occupy most of the 16-bit space.
    assert len(np.unique(h)) > 15_000


def test_shard_assignment_uniform():
    rng = np.random.default_rng(1)
    s = keypack.assign_shards(100_000, 8, rng)
    counts = np.bincount(s, minlength=8)
    assert counts.min() > 100_000 / 8 * 0.9
