"""Optimizer + training loop: convergence, compression parity, and an
actual loss-goes-down run on a tiny LM over real store-fed tokens."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_config, init_params
from repro.models.model import forward_train
from repro.training.optimizer import OptConfig, adamw_init, adamw_update


def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16,)), jnp.float32)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    return {"w": jnp.zeros((16,), jnp.float32)}, loss_fn, target


def _run(opt_cfg, steps=300):
    params, loss_fn, target = _quadratic_problem()
    state = adamw_init(params, opt_cfg)
    for _ in range(steps):
        grads = jax.grad(loss_fn)(params)
        params, state, _ = adamw_update(params, grads, state, opt_cfg)
    return float(loss_fn(params))


def test_adamw_converges():
    cfg = OptConfig(lr=5e-2, weight_decay=0.0, warmup_steps=10, total_steps=300)
    assert _run(cfg) < 1e-3


def test_compressed_grads_convergence_parity():
    """Error-feedback bf16 compression must not materially hurt
    convergence (paper-beyond distributed-optimization feature)."""
    base = OptConfig(lr=5e-2, weight_decay=0.0, warmup_steps=10, total_steps=300)
    comp = OptConfig(lr=5e-2, weight_decay=0.0, warmup_steps=10, total_steps=300, compress_grads=True)
    l0, l1 = _run(base), _run(comp)
    assert l1 < max(10 * l0, 1e-2)


def test_grad_clipping_bounds_update():
    cfg = OptConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0, warmup_steps=0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4,), 1e6, jnp.float32)}
    _, _, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # measured pre-clip


def test_tiny_lm_loss_decreases():
    cfg = get_config("llcysa-analytics-100m", smoke=True).replace(vocab_size=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60, weight_decay=0.0)
    state = adamw_init(params, opt_cfg)
    rng = np.random.default_rng(0)
    # Learnable structure: fixed repeating token pattern + noise.
    base = rng.integers(0, 256, 32)
    step = jax.jit(
        lambda p, s, b: _train_step(p, s, b, cfg, opt_cfg)
    )
    losses = []
    for i in range(40):
        seq = np.tile(base, 3)[:64]
        toks = jnp.asarray(np.stack([seq, np.roll(seq, i % 3)]), jnp.int32)
        batch = {"inputs": toks, "targets": jnp.roll(toks, -1, axis=1)}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[::8]


def _train_step(params, state, batch, cfg, opt_cfg):
    (loss, _), grads = jax.value_and_grad(
        lambda p: forward_train(p, cfg, batch, remat=False), has_aux=True
    )(params)
    params, state, _ = adamw_update(params, grads, state, opt_cfg)
    return params, state, loss
