"""SSD/Mamba2: the chunked dual form vs a sequential recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.ssm import (
    SSMSpec,
    init_ssm_params,
    init_ssm_state,
    ssd_chunked,
    ssm_decode_step,
    ssm_forward,
)


def ssd_sequential(x, dt, A, B, C):
    """Token-by-token recurrence: s = s*exp(dt*A) + dt * B ⊗ x; y = C·s."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st_ = np.zeros((b, h, n, p), np.float64)
    x, dt, A, B, C = map(np.asarray, (x, dt, A, B, C))
    ys = np.zeros((b, s, h, p), np.float64)
    for t in range(s):
        da = np.exp(dt[:, t] * A[None, :])  # (b,h)
        st_ = st_ * da[..., None, None] + np.einsum(
            "bn,bh,bhp->bhnp", B[:, t], dt[:, t], x[:, t]
        )
        ys[:, t] = np.einsum("bn,bhnp->bhp", C[:, t], st_)
    return ys, st_


@given(
    s=st.integers(1, 70),
    chunk=st.sampled_from([4, 8, 16, 64]),
    seed=st.integers(0, 50),
)
@settings(max_examples=20, deadline=None)
def test_ssd_chunked_matches_recurrence(s, chunk, seed):
    rng = np.random.default_rng(seed)
    b, h, p, n = 2, 3, 4, 5
    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, size=(b, s, h)).astype(np.float32)
    A = -rng.uniform(0.1, 1.0, size=(h,)).astype(np.float32)
    B = rng.normal(size=(b, s, n)).astype(np.float32)
    C = rng.normal(size=(b, s, n)).astype(np.float32)
    y, s_fin = ssd_chunked(*map(jnp.asarray, (x, dt, A, B, C)), chunk)
    y_ref, s_ref = ssd_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=2e-4, atol=2e-4)


def test_forward_then_decode_continues_state():
    spec = SSMSpec(d_model=16, d_inner=32, n_heads=2, head_dim=16, d_state=8, d_conv=4, chunk=8)
    params = init_ssm_params(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 21, 16), jnp.float32)
    # full forward over 21 tokens
    full = ssm_forward(params, x, spec)
    # prefill 20 then decode token 20
    out20, state = ssm_forward(params, x[:, :20], spec, return_state=True)
    out_d, _ = ssm_decode_step(params, x[:, 20:21], state, spec)
    np.testing.assert_allclose(
        np.asarray(out_d[:, 0]), np.asarray(full[:, 20]), rtol=2e-4, atol=2e-4
    )


def test_chunked_prefill_continuation():
    """ssm_forward over [0:12] + state-threaded [12:20] == one pass."""
    spec = SSMSpec(d_model=8, d_inner=16, n_heads=2, head_dim=8, d_state=4, d_conv=4, chunk=4)
    params = init_ssm_params(jax.random.PRNGKey(2), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 20, 8), jnp.float32)
    full = ssm_forward(params, x, spec)
    o1, st1 = ssm_forward(params, x[:, :12], spec, return_state=True)
    o2 = ssm_forward(params, x[:, 12:], spec, initial_state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([o1, o2], 1)), np.asarray(full), rtol=2e-4, atol=2e-4
    )


def test_decode_state_shapes():
    spec = SSMSpec(d_model=8, d_inner=16, n_heads=2, head_dim=8, d_state=4, d_conv=4, chunk=4)
    s0 = init_ssm_state(3, spec)
    assert s0[0].shape == (3, 2, 4, 8)
    assert s0[1].shape == (3, 3, 16 + 8)
