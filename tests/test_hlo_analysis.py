"""HLO parsing: type byte counts and collective-bytes extraction."""
from repro.launch.hlo_analysis import collective_stats, roofline_terms, type_bytes


def test_type_bytes():
    assert type_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert type_bytes("bf16[2,3,4]") == 48
    assert type_bytes("s64[]") == 8
    assert type_bytes("(f32[2,2], s32[4])") == 32
    assert type_bytes("pred[7]") == 7
    assert type_bytes("token[]") == 0


HLO = """
HloModule test
ENTRY main {
  %p0 = f32[64,64]{1,0} parameter(0)
  %p1 = f32[64,64]{1,0} parameter(1)
  %ar = f32[64,64]{1,0} all-reduce(%p0), replica_groups={}
  %ag-start = (f32[64,64], f32[128,64]) all-gather-start(%p1), dimensions={0}
  %ag-done = f32[128,64]{1,0} all-gather-done(%ag-start)
  %rs = f32[32,64]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[64,64]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = f32[64,64]{1,0} all-to-all(%p1), dimensions={0}
  ROOT %out = f32[32,64]{1,0} add(%rs, %rs)
}
"""


def test_collective_stats_counts_each_kind_once():
    st = collective_stats(HLO)
    assert st.count_by_op == {
        "all-reduce": 1,
        "all-gather": 1,
        "reduce-scatter": 1,
        "collective-permute": 1,
        "all-to-all": 1,
    }
    sz = 64 * 64 * 4
    assert st.bytes_by_op["all-reduce"] == sz
    assert st.bytes_by_op["all-gather"] == sz  # operand, not result
    assert st.bytes_by_op["collective-permute"] == sz
    assert st.total_count == 5


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 100e9, 1e9)  # 1s compute, ~0.12s mem, 0.02s coll
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["bottleneck"] == "compute_s"
    t = roofline_terms(1e9, 819e9, 0)
    assert t["bottleneck"] == "memory_s"
