"""Serving: adaptive request batcher law + the continuous-batching engine."""
import jax
import numpy as np

from repro.models import get_config, init_params
from repro.serving import AdaptiveRequestBatcher, ServeEngine


def test_batcher_grows_when_fast():
    b = AdaptiveRequestBatcher(k0=1, c=1.5, t_min=0.05, t_max=0.5, max_batch=64)
    for _ in range(10):
        n = b.admit(waiting=100, free_slots=64)
        b.update(runtime=0.001 * max(n, 1), served=n)  # very fast rounds
    assert b.k > 8  # grew geometrically


def test_batcher_shrinks_when_hot():
    b = AdaptiveRequestBatcher(k0=32, c=1.5, t_min=0.05, t_max=0.5, max_batch=64)
    for _ in range(6):
        n = b.admit(waiting=100, free_slots=64)
        b.update(runtime=0.2 * max(n, 1), served=n)  # 0.2 s per request!
    # Steady state: k ~ t_max * rate = 0.5 / 0.2 = 2.5 requests.
    assert b.k < 5


def test_batcher_respects_slots_and_queue():
    b = AdaptiveRequestBatcher(k0=50, max_batch=8)
    assert b.admit(waiting=3, free_slots=8) == 3
    assert b.admit(waiting=100, free_slots=2) == 2
    assert b.admit(waiting=0, free_slots=8) == 0


def test_engine_serves_all_requests():
    cfg = get_config("llcysa-analytics-100m", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=48)
    rng = np.random.default_rng(0)
    n_req = 7
    for _ in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(3, 12))), max_new_tokens=5)
    done = eng.run()
    assert len(done) == n_req
    assert all(len(r.output) == 5 for r in done)
    assert all(r.ttft is not None and r.finished_at is not None for r in done)


def test_engine_interleaves_requests():
    """Continuous batching: later requests finish without waiting for the
    whole first wave (slot reuse)."""
    cfg = get_config("llcysa-analytics-100m", smoke=True)
    params = init_params(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, 4), max_new_tokens=3 + i)
    done = eng.run()
    assert len(done) == 6
    assert max(len(r.output) for r in done) == 8
