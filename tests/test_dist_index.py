"""Distributed index plane: host-vs-dist exact agreement for the four
schemes on randomized workloads, mesh-read planner densities, the
device-merge backend selection, and per-writer backpressure telemetry."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core import And, Eq, EventStore, Not, Or, QueryProcessor, web_proxy_schema
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor, from_event_store
from repro.core.ingest import IngestMetrics
from repro.core.planner import plan_query
from repro.core.query import QueryStats
from repro.launch.mesh import make_dev_mesh

T_SPAN = 4 * 3600
SCHEMES = ["scan", "batched_scan", "index", "batched_index"]


def _gen(seed, n):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(
            ["a.com", "b.com", "c.com", "rare.net"], p=[0.6, 0.25, 0.13, 0.02], size=n
        ).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    return ts, vals


@pytest.fixture(scope="module")
def planes():
    """The same randomized events through BOTH paths: host EventStore and
    a DistBatchWriter feeding an index-maintaining plane (for_store)."""
    ts, vals = _gen(seed=7, n=10_000)
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=12_000, tablets_per_device=2,
        mem_rows=2048, max_runs=3, append_rows=512,
    )
    w = DistBatchWriter(store, plane, batch_rows=1500)
    step = 997  # misaligned with every internal batch size
    for off in range(0, len(ts), step):
        sl = slice(off, off + step)
        w.add(ts[sl], {k: v[sl] for k, v in vals.items()})
    w.close()
    dq = DistQueryProcessor(store, plane=plane)
    return store, plane, dq, ts, {k: np.array(v) for k, v in vals.items()}


TREES = [
    Eq("domain", "rare.net"),
    Eq("domain", "c.com"),
    Eq("domain", "never-seen.com"),
    And(Eq("domain", "rare.net"), Eq("method", "GET")),
    And(Eq("domain", "c.com"), Eq("status", "404"), Eq("method", "POST")),
    And(Eq("domain", "c.com"), Not(Eq("method", "POST"))),
    Or(Eq("domain", "rare.net"), Eq("domain", "c.com")),
    Or(Eq("domain", "rare.net"), Eq("status", "404")),
    And(Eq("domain", "rare.net"), Eq("domain", "never-seen.com")),
    None,
]


# ----------------------------------------------------- scheme agreement
@pytest.mark.parametrize("tree", TREES)
@pytest.mark.parametrize("scheme", SCHEMES)
def test_schemes_agree_host_vs_dist(planes, tree, scheme):
    store, _, dq, ts, vals = planes
    qp = QueryProcessor(store)
    hs, ds = QueryStats(), QueryStats()
    t0, t1 = 900, 9000
    want = sum(b.n for b in qp.run_scheme(scheme, t0, t1, tree, stats=hs))
    got = sum(b.n for b in dq.run_scheme(scheme, t0, t1, tree, stats=ds))
    assert got == want
    # Same access path chosen on both sides (densities agree exactly).
    assert hs.plan.mode == ds.plan.mode


@given(seed=st.integers(0, 2**31), span=st.integers(1, T_SPAN))
@settings(max_examples=15, deadline=None)
def test_randomized_ranges_batched_index_agree(planes, seed, span):
    store, _, dq, ts, vals = planes
    rng = np.random.default_rng(seed)
    t0 = int(rng.integers(0, T_SPAN))
    t1 = min(t0 + span, T_SPAN)
    tree = TREES[int(rng.integers(0, len(TREES) - 1))]
    want = sum(b.n for b in QueryProcessor(store).run_scheme("batched_index", t0, t1, tree))
    got = sum(b.n for b in dq.run_scheme("batched_index", t0, t1, tree))
    assert got == want, (tree, t0, t1)


def test_index_path_actually_used(planes):
    store, _, dq, ts, vals = planes
    stats = QueryStats()
    got = sum(
        b.n for b in dq.run_scheme("batched_index", 0, T_SPAN, Eq("domain", "rare.net"), stats=stats)
    )
    assert got == int((vals["domain"] == "rare.net").sum())
    assert stats.plan.mode == "index"
    assert stats.index_keys_scanned > 0  # postings really expanded on device
    # Top-k rows carry real matching rows.
    blocks = list(dq.run_scheme("index", 0, T_SPAN, Eq("domain", "rare.net")))
    code = store.dictionaries["domain"].lookup("rare.net")
    fid = store.schema.field_id("domain")
    for blk in blocks:
        assert (blk.cols[:, fid] == code).all()


def test_truncation_falls_back_exact(planes):
    """Pathologically small posting/row slabs must degrade to the exact
    filter-scan answer, never a truncated count."""
    store, plane, _, ts, vals = planes
    dq = DistQueryProcessor(store, plane=plane, index_postings=8, index_rows=8)
    tree = Eq("domain", "c.com")
    want = sum(b.n for b in QueryProcessor(store).run_scheme("batched_index", 0, T_SPAN, tree))
    got = sum(b.n for b in dq.run_scheme("batched_index", 0, T_SPAN, tree))
    assert got == want


# ------------------------------------------------------ planner densities
def test_plan_reads_mesh_densities(planes):
    store, _, dq, ts, vals = planes
    for f, v in [("domain", "rare.net"), ("domain", "a.com"), ("status", "404"), ("domain", "no")]:
        for t0, t1 in [(0, T_SPAN), (1800, 5400)]:
            assert dq.agg_count(f, v, t0, t1) == store.agg_count(f, v, t0, t1)
    for tree in TREES[:-1]:
        ph = plan_query(store, tree, 0, T_SPAN)
        pd = plan_query(dq, tree, 0, T_SPAN)
        assert ph.mode == pd.mode
        assert [(c.field, c.value, c.density) for c in ph.index_conds] == [
            (c.field, c.value, c.density) for c in pd.index_conds
        ]


def test_zero_density_empty_plan_no_device_work(planes):
    store, _, dq, ts, vals = planes
    stats = QueryStats()
    got = sum(
        b.n
        for b in dq.run_scheme(
            "batched_index", 0, T_SPAN,
            And(Eq("domain", "rare.net"), Eq("domain", "never-seen.com")),
            stats=stats,
        )
    )
    assert got == 0 and stats.plan.mode == "empty" and stats.batches == 0


# ----------------------------------------------------- live index updates
def test_live_index_visibility(planes):
    """Index postings and densities update with ingest — no rebuild: rows
    written after a publish are found by the NEXT index-mode query."""
    store, plane, dq, ts, vals = planes
    tree = Eq("domain", "rare.net")
    before = sum(b.n for b in dq.run_scheme("batched_index", 0, T_SPAN, tree))
    d_before = dq.agg_count("domain", "rare.net", 0, T_SPAN)
    w = DistBatchWriter(store, plane, batch_rows=2, writer_id=9)
    w.add(
        np.array([50, 60, 70]),
        {"domain": ["rare.net"] * 3, "method": ["GET"] * 3, "status": ["200"] * 3},
    )
    w.close()
    stats = QueryStats()
    after = sum(b.n for b in dq.run_scheme("batched_index", 0, T_SPAN, tree, stats=stats))
    assert stats.plan.mode == "index"
    assert after == before + 3
    assert dq.agg_count("domain", "rare.net", 0, T_SPAN) == d_before + 3


def test_index_less_plane_falls_back_to_filter(planes):
    """A plane built without indexed fields still answers every scheme —
    through filter-scan."""
    store, *_ = planes
    ts, vals = _gen(seed=3, n=2000)
    store2 = EventStore(web_proxy_schema(), n_shards=2)
    store2.ingest(ts, vals)
    store2.flush_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(mesh, store2.schema.n_fields, capacity=4000)
    w = DistBatchWriter(store2, plane, batch_rows=512)
    w.add(ts, vals)
    w.close()
    dq = DistQueryProcessor(store2, plane=plane)
    assert not dq.dist.has_index
    stats = QueryStats()
    got = sum(b.n for b in dq.run_scheme("batched_index", 0, T_SPAN, Eq("domain", "c.com"), stats=stats))
    varr = np.array(vals["domain"])
    assert got == int((varr == "c.com").sum())
    assert stats.plan.mode == "filter"


# ------------------------------------------------- merge kernel backends
def test_device_major_backend_exact_agreement():
    """Satellite bugfix: the shard_map major compaction must produce
    bit-identical tablet state through the jnp reference AND the Pallas
    rank kernel (interpret mode on CPU) — all three families."""
    ts, vals = _gen(seed=11, n=1500)
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    states = {}
    for backend in ("ref", "pallas"):
        plane = DistIngestPlane.for_store(
            store, mesh, capacity=2000, tablets_per_device=2,
            mem_rows=128, max_runs=2, append_rows=64, kernel_backend=backend,
        )
        # Same writer_id both passes: the id salts the row hash, and the
        # comparison needs identical tablet assignments.
        w = DistBatchWriter(store, plane, batch_rows=300, writer_id=0)
        w.add(ts, vals)
        w.close()
        plane.publish()
        tel = plane.telemetry()
        assert int(tel["major"].sum()) >= 1  # majors really ran this backend
        states[backend] = {
            k: np.asarray(jax.device_get(v))
            for k, v in plane.state.items()
            if k.endswith(("_base_k", "_base_c", "_base_n"))
        }
    assert states["ref"].keys() == states["pallas"].keys()
    for k in states["ref"]:
        np.testing.assert_array_equal(states["ref"][k], states["pallas"][k], err_msg=k)


# ------------------------------------------------- per-writer backpressure
def test_per_writer_blocked_seconds():
    """Satellite bugfix: telemetry surfaces blocked time PER WRITER (the
    paper's §IV-A per-client curve), the plane scalar is their sum, and
    each writer's IngestMetrics matches its plane-side attribution."""
    ts, vals = _gen(seed=17, n=6000)
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(
        mesh, store.schema.n_fields, capacity=8000,
        tablets_per_device=2, mem_rows=512, max_runs=2, append_rows=256,
    )
    metrics = {i: IngestMetrics() for i in range(2)}
    writers = {
        i: DistBatchWriter(store, plane, batch_rows=400, metrics=metrics[i], writer_id=i)
        for i in range(2)
    }
    half = len(ts) // 2
    for i, sl in enumerate((slice(0, half), slice(half, None))):
        writers[i].add(ts[sl], {k: v[sl] for k, v in vals.items()})
        writers[i].close()
    tel = plane.telemetry()
    per = tel["blocked_seconds_per_writer"]
    assert set(per) == {0, 1}
    assert all(v >= 0 for v in per.values())
    assert np.isclose(sum(per.values()), float(tel["blocked_seconds"]))
    for i in range(2):
        assert np.isclose(metrics[i].blocked_seconds, per[i])
    # Tiny memtables + tiny max_runs: majors fired, so someone blocked.
    assert int(tel["major"].sum()) >= 1
    assert float(tel["blocked_seconds"]) > 0
