"""Algorithm 1/2 property tests: the paper's adaptive batching."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.batching import AdaptiveBatcher, HitRateTracker, run_batched_query


def collect(t_start, t_stop, b0, query, **kw):
    batcher = run_batched_query(t_start, t_stop, b0, query, **kw)
    return batcher.history


@given(
    t_stop=st.integers(0, 100_000),
    b0=st.floats(1.0, 5000.0),
    rate=st.floats(0.001, 50.0),
    runtime=st.floats(1e-4, 5.0),
)
@settings(max_examples=60, deadline=None)
def test_batches_tile_range_exactly(t_stop, b0, rate, runtime):
    """Batch intervals [p, p+b] are disjoint, eps-separated, ordered, and
    cover [t_start, t_stop]."""
    def query(lo, hi):
        return runtime, int((hi - lo) * rate)

    hist = collect(0, t_stop, b0, query)
    assert hist, "at least one batch"
    assert hist[0].p == 0
    prev_end = None
    for rec in hist:
        assert rec.b >= 0
        if prev_end is not None:
            assert rec.p == prev_end + 1  # eps = 1: no gap, no overlap
        prev_end = rec.p + rec.b
    assert prev_end >= t_stop  # full coverage


def test_growth_factor_c():
    """With plentiful results and mid-band runtimes, k grows by c."""
    b = AdaptiveBatcher(t_start=0, t_stop=10**9, b0=100.0, t_min=0.0, t_max=1e9)
    ks = [b._k]
    for _ in range(5):
        b.update(runtime=1.0, rows=int(b._k))  # hit exactly k rows
        ks.append(b._k)
    for a, bb in zip(ks, ks[1:]):
        assert abs(bb / a - 1.5) < 1e-6


def test_clamp_too_large():
    """Estimated runtime above T_max shrinks k to T_max * rate."""
    b = AdaptiveBatcher(t_start=0, t_stop=10**9, b0=100.0, t_min=1.0, t_max=30.0)
    b.update(runtime=25.0, rows=10)  # rate = 0.4 rows/s; c*k = 15 -> 37.5s > 30
    assert abs(b._k - 30.0 * (10 / 25.0)) < 1e-6


def test_clamp_too_small():
    """Estimated runtime below T_min grows k to T_min * rate."""
    b = AdaptiveBatcher(t_start=0, t_stop=10**9, b0=100.0, t_min=1.0, t_max=30.0)
    b.update(runtime=0.001, rows=10)  # c*k estimated at 0.0015s < 1s
    assert abs(b._k - 1.0 * (10 / 0.001)) < 1e-3


def test_empty_batches_grow_geometrically():
    b = AdaptiveBatcher(t_start=0, t_stop=10**9, b0=64.0)
    sizes = [b._b]
    for _ in range(4):
        b.update(runtime=0.01, rows=0)
        sizes.append(b._b)
    for a, bb in zip(sizes, sizes[1:]):
        assert bb >= a  # monotone growth on empty results


def test_paper_defaults():
    b = AdaptiveBatcher(t_start=0, t_stop=100, b0=10)
    assert b.k0 == 10.0 and b.c == 1.5 and b.t_max == 30.0 and b.t_min == 1.0


def test_zero_width_range_runs_once():
    hist = collect(5, 5, 10.0, lambda lo, hi: (0.01, 1))
    assert len(hist) == 1
    assert hist[0].p == 5


def test_hit_rate_tracker_seeds_b0():
    t = HitRateTracker(default_rate=2.0)
    assert abs(t.initial_b(10.0) - 5.0) < 1e-9
    for _ in range(50):
        t.observe(rows=100, b=10.0)  # 10 rows/unit
    assert abs(t.initial_b(10.0) - 1.0) < 0.5  # converged toward k0/rate
