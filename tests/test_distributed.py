"""Distributed execution on an 8-device host mesh (subprocess: the device
count must be set before jax initializes, and the main test process keeps
1 device)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.models import get_config, init_params
    from repro.launch.steps import build_step
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import param_specs, zero1_specs
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}

    # 1. Real multi-device train step: loss finite, params updated,
    #    shardings as specified.
    cfg = get_config("gemma2-9b", smoke=True).replace(
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512
    )
    shape = ShapeConfig("t", 64, 4, "train")
    built = build_step(cfg, mesh, shape, zero1=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.training.optimizer import OptConfig, adamw_init
    opt = adamw_init(params, OptConfig())
    key = jax.random.PRNGKey(1)
    batch = {
        "inputs": jax.random.randint(key, (4, 64), 0, 512).astype(jnp.int32),
        "targets": jax.random.randint(key, (4, 64), 0, 512).astype(jnp.int32),
    }
    p2, o2, metrics = built.fn(params, opt, batch)
    out["loss"] = float(metrics["loss"])
    out["grad_norm"] = float(metrics["grad_norm"])
    delta = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    out["max_param_delta"] = max(jax.tree_util.tree_leaves(delta))

    # Sharding checks: embed sharded over model on vocab axis.
    emb_shard = p2["embed"].sharding.spec
    out["embed_spec"] = str(emb_shard)
    # ZeRO: m leaves sharded over data somewhere.
    m_specs = [str(x.sharding.spec) for x in jax.tree_util.tree_leaves(o2["m"])]
    out["any_zero1"] = any("data" in s for s in m_specs)

    # 2. Second step from sharded outputs (steady-state path works).
    p3, o3, metrics2 = built.fn(p2, o2, batch)
    out["loss2"] = float(metrics2["loss"])

    # 3. Decode step on the mesh.
    shape_d = ShapeConfig("d", 64, 8, "decode")
    built_d = build_step(cfg, mesh, shape_d)
    lowered = built_d.fn.lower(*built_d.abstract_args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0]
    out["decode_flops"] = ca.get("flops", 0.0)

    print("RESULT " + json.dumps(out))
    """
)


def test_multi_device_train_and_decode():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env, timeout=900
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    import numpy as np

    assert np.isfinite(out["loss"]) and out["loss"] > 0
    assert out["max_param_delta"] > 0  # optimizer actually stepped
    assert "model" in out["embed_spec"]
    assert out["any_zero1"]
    assert np.isfinite(out["loss2"])
    assert out["decode_flops"] > 0


def test_sharding_specs_divisibility_fallbacks():
    """qwen1.5 (20 heads) on a 16-way model axis must fall back to
    replicated attention weights; FFN/vocab still shard."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import param_specs
    from repro.models import get_config

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    specs = param_specs(get_config("qwen1.5-4b"), FakeMesh())
    g0 = specs["groups"][0]
    assert g0["wq"] == P(None, None, None)  # (group, d, heads*hd) replicated
    assert g0["wi_gate"] == P(None, None, "model")  # ff divides
    assert specs["embed"] == P("model", None)
    # mamba2 vocab 50280 does not divide 16 -> replicated embed.
    specs2 = param_specs(get_config("mamba2-780m"), FakeMesh())
    assert specs2["embed"] == P(None, None)
