"""Planner heuristics 1-4 against a numpy density oracle.

The store's densities are controlled exactly: a custom 4-field schema
whose values occur a known number of times inside the query range, plus
one value that occurs ONLY outside it (zero density inside — the
provably-empty short-circuit case). The oracle recomputes every density
from raw numpy over the bucket-superset range the aggregate table counts.
"""
import numpy as np
import pytest

from repro.core import And, Cmp, Eq, EventStore, Not, Or, QueryProcessor, QueryStats
from repro.core.filter import TrueNode
from repro.core.planner import plan_query
from repro.core.schema import EventSchema, FieldSpec

BUCKET = 100
T_RANGE = 1000  # first batch lives in [0, 1000)
T_FAR = 2000  # second batch (the zero-density-in-range values) in [2000, 3000)

# (field, value) -> occurrences inside [0, T_RANGE). Chosen to pin the
# heuristic-3 threshold arithmetic: with w=10 and d_min=2 the cutoff is
# exactly 20 (strict <), and with d_min=1 it is exactly 10.
COUNTS = {
    ("fa", "x1"): 1,
    ("fa", "x2"): 2,
    ("fb", "y9"): 9,
    ("fb", "y19"): 19,
    ("fc", "z10"): 10,
    ("fc", "z20"): 20,
    ("fd", "w200"): 200,
}


def _schema():
    return EventSchema(
        "planner_test",
        [FieldSpec("fa"), FieldSpec("fb"), FieldSpec("fc"), FieldSpec("fd"),
         FieldSpec("raw", indexed=False)],
    )


@pytest.fixture(scope="module")
def controlled():
    rng = np.random.default_rng(0)
    n = 400
    ts = np.sort(rng.integers(0, T_RANGE, n))
    fields = {f: ["o"] * n for f in ("fa", "fb", "fc", "fd")}
    fields["raw"] = [str(i % 7) for i in range(n)]
    pool = list(range(n))
    rng.shuffle(pool)
    taken = 0
    placed = {}
    for (f, v), c in COUNTS.items():
        idxs = pool[taken : taken + c]
        taken += c
        for i in idxs:
            fields[f][i] = v
        placed[(f, v)] = np.asarray(sorted(idxs))
    store = EventStore(_schema(), n_shards=2, agg_bucket_seconds=BUCKET)
    store.ingest(ts, fields)
    # Second batch far outside [0, T_RANGE): gives "gone" a dictionary
    # code (so Eq compiles) but ZERO density inside the query range.
    ts2 = np.sort(rng.integers(T_FAR, T_FAR + 1000, 50))
    store.ingest(ts2, {
        "fa": ["gone"] * 50, "fb": ["o"] * 50, "fc": ["o"] * 50,
        "fd": ["o"] * 50, "raw": ["0"] * 50,
    })
    store.flush_all()
    store.compact_all()
    data = {f: np.asarray(v[:n]) for f, v in fields.items()}
    return store, ts, data


def oracle_density(ts, data, field, value, t0, t1):
    """What the aggregate table reports: occurrences over the BUCKET
    superset of [t0, t1] (the planner's d_i)."""
    b_lo = (t0 // BUCKET) * BUCKET
    b_hi = (t1 // BUCKET + 1) * BUCKET
    return int(((data[field] == value) & (ts >= b_lo) & (ts < b_hi)).sum())


# ------------------------------------------------------------ heuristic 1
@pytest.mark.parametrize("fv", sorted(COUNTS))
@pytest.mark.parametrize("t_range", [(0, T_RANGE), (150, 620)])
def test_h1_density_matches_oracle(controlled, fv, t_range):
    store, ts, data = controlled
    f, v = fv
    t0, t1 = t_range
    d = oracle_density(ts, data, f, v, t0, t1)
    p = plan_query(store, Eq(f, v), t0, t1)
    if d == 0:
        assert p.mode == "empty"
    else:
        assert p.mode == "index" and p.combine == "intersect"
        assert len(p.index_conds) == 1
        assert p.index_conds[0].density == d
        assert isinstance(p.residual, TrueNode)


def test_h1_zero_density_short_circuits(controlled):
    store, ts, data = controlled
    # Known value, zero occurrences inside the range.
    p = plan_query(store, Eq("fa", "gone"), 0, T_RANGE)
    assert p.mode == "empty"
    # Never-ingested value: density 0 the same way.
    p = plan_query(store, Eq("fa", "never-seen"), 0, T_RANGE)
    assert p.mode == "empty"
    # The executor must do NO work: zero batches even in batched mode.
    qp = QueryProcessor(store)
    stats = QueryStats()
    rows = sum(b.n for b in qp.run_scheme("batched_index", 0, T_RANGE, Eq("fa", "gone"), stats=stats))
    assert rows == 0 and stats.batches == 0
    # But the same value IS found where it lives.
    p = plan_query(store, Eq("fa", "gone"), T_FAR, T_FAR + 1000)
    assert p.mode == "index" and p.index_conds[0].density == 50


def test_h1_unindexed_field_filters(controlled):
    store, _, _ = controlled
    p = plan_query(store, Eq("raw", "3"), 0, T_RANGE)
    assert p.mode == "filter"


# ------------------------------------------------------------ heuristic 2
def test_h2_or_of_eq_unions(controlled):
    store, ts, data = controlled
    tree = Or(Eq("fa", "x2"), Eq("fb", "y19"), Eq("fd", "w200"))
    p = plan_query(store, tree, 0, T_RANGE)
    assert p.mode == "index" and p.combine == "union"
    dens = {(c.field, c.value): c.density for c in p.index_conds}
    assert dens == {
        ("fa", "x2"): oracle_density(ts, data, "fa", "x2", 0, T_RANGE),
        ("fb", "y19"): oracle_density(ts, data, "fb", "y19", 0, T_RANGE),
        ("fd", "w200"): oracle_density(ts, data, "fd", "w200", 0, T_RANGE),
    }
    # A zero-density child does NOT empty a union — the plan stays an
    # index union and execution returns the other children's rows.
    tree = Or(Eq("fa", "gone"), Eq("fa", "x2"))
    p = plan_query(store, tree, 0, T_RANGE)
    assert p.mode == "index" and p.combine == "union"
    qp = QueryProcessor(store)
    rows = sum(b.n for b in qp.run_scheme("batched_index", 0, T_RANGE, tree))
    assert rows == int((data["fa"] == "x2").sum())
    # OR with any non-Eq child falls through to filtering (heuristic 4).
    p = plan_query(store, Or(Eq("fa", "x2"), Not(Eq("fb", "y9"))), 0, T_RANGE)
    assert p.mode == "filter"


# ------------------------------------------------------------ heuristic 3
def _selected(plan):
    return {(c.field, c.value) for c in plan.index_conds}


def test_h3_w_threshold_boundary(controlled):
    store, _, _ = controlled
    # d_min = 2 (fa=x2), w = 10 -> cutoff exactly 20, strict '<':
    # y19 (d=19) selected, z20 (d=20) excluded, w200 excluded.
    tree = And(Eq("fa", "x2"), Eq("fb", "y19"), Eq("fc", "z20"), Eq("fd", "w200"))
    p = plan_query(store, tree, 0, T_RANGE, w=10.0)
    assert p.mode == "index" and p.combine == "intersect"
    assert _selected(p) == {("fa", "x2"), ("fb", "y19")}
    # The excluded conditions become the residual filter.
    assert isinstance(p.residual, And)
    resid = {(c.field, c.value) for c in p.residual.children}
    assert resid == {("fc", "z20"), ("fd", "w200")}
    # Raising w past the boundary pulls z20 in (20 < 2 * 10.001).
    p = plan_query(store, tree, 0, T_RANGE, w=10.001)
    assert ("fc", "z20") in _selected(p)


def test_h3_dmin_floor_tie_break(controlled):
    store, _, _ = controlled
    # d_min = 1 (fa=x1): the max(d_min, 1.0) floor makes the cutoff
    # w * 1 = 10 — y9 (d=9) in, z10 (d=10) out (strict '<').
    tree = And(Eq("fa", "x1"), Eq("fb", "y9"), Eq("fc", "z10"))
    p = plan_query(store, tree, 0, T_RANGE, w=10.0)
    assert _selected(p) == {("fa", "x1"), ("fb", "y9")}
    # Densities are integers, so d_min in (0, 1) cannot occur and d_min=0
    # now short-circuits to an empty plan — the floor's only remaining
    # live case is exactly d_min == 1, asserted above. Zero-density
    # dominance over selection:
    tree = And(Eq("fa", "gone"), Eq("fd", "w200"))
    p = plan_query(store, tree, 0, T_RANGE)
    assert p.mode == "empty"
    assert _selected(p) == {("fa", "gone")}  # the proving condition
    qp = QueryProcessor(store)
    stats = QueryStats()
    rows = sum(b.n for b in qp.run_scheme("batched_index", 0, T_RANGE, tree, stats=stats))
    assert rows == 0 and stats.batches == 0


def test_h3_no_eq_child_selected_falls_back(controlled):
    store, _, _ = controlled
    # All children too dense relative to d_min under a tiny w: nothing
    # selected -> heuristic 4 filter mode.
    tree = And(Eq("fc", "z20"), Eq("fd", "w200"))
    p = plan_query(store, tree, 0, T_RANGE, w=0.1)
    assert p.mode == "filter" and p.residual is tree
    # AND whose only indexable children ride with non-Eq siblings still
    # indexes the rare ones and keeps the rest as residual.
    tree = And(Eq("fa", "x2"), Not(Eq("fb", "y9")))
    p = plan_query(store, tree, 0, T_RANGE)
    assert p.mode == "index" and _selected(p) == {("fa", "x2")}


# ------------------------------------------------------------ heuristic 4
@pytest.mark.parametrize(
    "tree",
    [
        Not(Eq("fa", "x2")),
        Cmp("raw", "<", 4),
        Or(Eq("fa", "x2"), Cmp("raw", "<", 4)),
    ],
)
def test_h4_everything_else_filters(controlled, tree):
    store, _, _ = controlled
    p = plan_query(store, tree, 0, T_RANGE)
    assert p.mode == "filter" and p.residual is tree


def test_use_index_false_always_filters(controlled):
    store, _, _ = controlled
    p = plan_query(store, Eq("fa", "x2"), 0, T_RANGE, use_index=False)
    assert p.mode == "filter"
