"""Observability plane: registry semantics vs numpy oracles, span
nesting/parent integrity under the concurrent serve harness, occupancy
attribution summing to lock-held time, the disabled-mode overhead gate,
and Chrome-trace schema validation."""
import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import EventStore, Eq, web_proxy_schema
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor
from repro.core.ingest import BatchWriter, IngestMetrics, rate_series
from repro.launch.mesh import make_dev_mesh
from repro.obs.registry import MetricsRegistry
from repro.serve_db import QueryService

T_SPAN = 2 * 3600


# ---------------------------------------------------------------- registry
def test_counter_label_semantics():
    reg = MetricsRegistry("t_counter")
    c = reg.counter("rows")
    rng = np.random.default_rng(0)
    per = {}
    for _ in range(500):
        w = int(rng.integers(0, 5))
        v = float(rng.integers(1, 100))
        c.inc(v, writer=w)
        per[w] = per.get(w, 0.0) + v
    for w, total in per.items():
        assert c.value(writer=w) == total
    assert c.total() == pytest.approx(sum(per.values()))
    # reset of one label leaves the others
    c.reset(writer=0)
    assert c.value(writer=0) == 0.0
    assert c.value(writer=1) == per.get(1, 0.0)


def test_counter_threaded_total():
    reg = MetricsRegistry("t_threads")
    c = reg.counter("hits")

    def work(tid):
        for _ in range(2000):
            c.inc(1, thread=tid)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.total() == 8000


def test_histogram_vs_numpy_oracle():
    reg = MetricsRegistry("t_hist")
    edges = [0.001, 0.01, 0.1, 1.0]
    h = reg.histogram("lat", edges=edges)
    rng = np.random.default_rng(7)
    vals = rng.lognormal(mean=-4, sigma=2.0, size=2000)
    for v in vals:
        h.observe(float(v))
    snap = h.snapshot()
    # Oracle: np.histogram over (-inf, e0], (e0, e1], ..., (e_last, inf)
    oracle, _ = np.histogram(vals, bins=[-np.inf] + edges + [np.inf])
    assert snap["buckets"] == oracle.tolist()
    assert snap["count"] == len(vals)
    assert snap["sum"] == pytest.approx(vals.sum(), rel=1e-9)
    assert snap["min"] == pytest.approx(vals.min())
    assert snap["max"] == pytest.approx(vals.max())


def test_histogram_bucket_edge_exact():
    """A value exactly on an edge lands in the bucket that edge closes
    (half-open on the left), deterministically."""
    reg = MetricsRegistry("t_edge")
    h = reg.histogram("x", edges=[1.0, 2.0])
    for _ in range(10):
        h.observe(1.0)
    snap = h.snapshot()
    assert snap["buckets"] == [10, 0, 0]
    assert snap["count"] == 10


def test_registry_disabled_is_noop():
    reg = MetricsRegistry("t_disabled", enabled=False)
    c = reg.counter("n")
    h = reg.histogram("h")
    c.inc(5)
    h.observe(1.0)
    assert c.total() == 0.0
    assert h.count() == 0


def test_metric_kind_collision_raises():
    reg = MetricsRegistry("t_kind")
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


# ------------------------------------------------------------- IngestMetrics
def test_ingest_metrics_is_registry_view():
    m = IngestMetrics()
    m.rows += 100
    m.rows += 50
    m.blocked_seconds += 0.25
    assert m.rows == 150
    assert m.blocked_seconds == pytest.approx(0.25)
    # The same cells are visible on the default registry, per-writer.
    reg = obs.get_registry()
    c = reg.get("ingest_rows_total")
    assert c is not None and c.value(writer=m._label) == 150
    # Independent instances never share cells.
    m2 = IngestMetrics()
    assert m2.rows == 0
    m2.rows = 7
    assert m.rows == 150 and m2.rows == 7


# --------------------------------------------------------------- rate_series
def test_rate_series_conserves_rows():
    m = IngestMetrics()
    rng = np.random.default_rng(3)
    t0 = 1000.0
    for i in range(200):
        m.samples.append((t0 + float(rng.uniform(0, 10)), int(rng.integers(1, 500))))
    m.samples.sort()
    for bucket in (0.25, 0.5, 1.0):
        xs, rate = rate_series([m], bucket_s=bucket)
        total = sum(s[1] for s in m.samples)
        assert rate.sum() * bucket == pytest.approx(total)
        assert len(xs) == len(rate)


def test_rate_series_boundary_not_double_counted():
    """Events exactly on bucket boundaries land in exactly one bucket:
    totals conserve and the bucket assignment is the half-open one."""
    m = IngestMetrics()
    t0 = 50.0
    bucket = 0.25
    # Samples exactly on edges 0, 1, 2, ... of the bucket grid.
    for i in range(8):
        m.samples.append((t0 + i * bucket, 100))
    xs, rate = rate_series([m], bucket_s=bucket)
    assert rate.sum() * bucket == pytest.approx(800)
    # Each on-edge event opens its own bucket: one event per bucket.
    assert np.allclose(rate[: len(rate) - 1], 100 / bucket) or rate.max() * bucket == 100


def test_rate_series_empty():
    xs, rate = rate_series([IngestMetrics()])
    assert len(xs) == 0 and len(rate) == 0


# ----------------------------------------------------------------- OwnedLock
def test_owned_lock_partitions_held_time():
    lk = obs.OwnedLock("t_lock")
    with lk.hold("a"):
        time.sleep(0.02)
        with lk.reowner("b"):
            time.sleep(0.03)
        time.sleep(0.01)
    with lk.hold("c"):
        time.sleep(0.01)
    snap = lk.snapshot()
    by = snap["by_owner_s"]
    assert set(by) == {"a", "b", "c"}
    # Books balance exactly: per-owner segments partition each hold.
    assert sum(by.values()) == pytest.approx(snap["total_held_s"], rel=1e-9)
    assert by["b"] >= 0.025  # the re-owned stretch is charged to b
    assert snap["acquisitions"] == 2


def test_owned_lock_plain_with_is_unknown():
    lk = obs.OwnedLock("t_lock_plain")
    with lk:
        pass
    assert "unknown" in lk.snapshot()["by_owner_s"]


def test_owned_lock_nonblocking_contention():
    lk = obs.OwnedLock("t_lock_nb")
    assert lk.acquire(blocking=False, owner="x")
    assert not lk.acquire(blocking=False, owner="y")
    lk.release()
    snap = lk.snapshot()
    assert snap["acquisitions"] == 1
    assert "y" not in snap["by_owner_s"]


# ------------------------------------------------------------------- tracing
def test_span_nesting_and_parent_linkage():
    obs.enable()
    obs.clear()
    try:
        with obs.span("outer", cat="t") as so:
            with obs.span("inner", cat="t") as si:
                pass
        with obs.span("sibling", cat="t"):
            pass
    finally:
        obs.disable()
    recs = {r["name"]: r for r in obs.get_tracer().records}
    assert recs["inner"]["parent"] == recs["outer"]["sid"]
    assert recs["sibling"]["parent"] == 0
    assert recs["outer"]["parent"] == 0
    # Parent interval contains the child (same thread, same clock).
    o, i = recs["outer"], recs["inner"]
    assert o["t0"] <= i["t0"] and i["t0"] + i["dur"] <= o["t0"] + o["dur"] + 1e-6
    assert o["tid"] == i["tid"]


def test_traced_decorator_and_args():
    obs.enable()
    obs.clear()
    try:

        @obs.traced("deco.fn", cat="t")
        def fn(x):
            return x + 1

        assert fn(1) == 2
        with obs.span("with_args", cat="t", k=3) as sp:
            sp.set(result=9)
    finally:
        obs.disable()
    recs = {r["name"]: r for r in obs.get_tracer().records}
    assert "deco.fn" in recs
    assert recs["with_args"]["args"] == {"k": 3, "result": 9}


def test_chrome_trace_schema():
    obs.enable()
    obs.clear()
    try:
        with obs.span("a", cat="t"):
            with obs.span("b", cat="t"):
                pass
    finally:
        obs.disable()
    doc = obs.chrome_trace()
    # Round-trips through JSON and passes the shared validator.
    doc2 = json.loads(json.dumps(doc))
    assert obs.validate_chrome_trace(doc2) == []
    xs = [e for e in doc2["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"a", "b"}
    b = next(e for e in xs if e["name"] == "b")
    a = next(e for e in xs if e["name"] == "a")
    assert b["args"]["parent"] == a["args"]["sid"]


def test_chrome_trace_validator_catches_problems():
    assert obs.validate_chrome_trace({}) != []
    assert obs.validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [{"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0.0, "dur": -1.0}]}
    assert any("negative" in p for p in obs.validate_chrome_trace(bad))
    orphan = {
        "traceEvents": [
            {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0.0, "dur": 1.0,
             "args": {"sid": 1, "parent": 99}}
        ]
    }
    assert any("parent" in p for p in obs.validate_chrome_trace(orphan))


def test_metrics_snapshot_and_summary():
    reg = MetricsRegistry("t_snapshot")
    reg.counter("snap_rows").inc(42, writer="w")
    reg.histogram("snap_lat").observe(0.005)
    snap = obs.metrics_snapshot()
    assert snap["schema_version"] == 1
    assert "t_snapshot" in snap["registries"]
    cells = snap["registries"]["t_snapshot"]["snap_rows"]["cells"]
    assert cells == {"writer=w": 42.0}
    json.dumps(snap)  # JSON-serializable end to end
    text = obs.summary()
    assert "snap_rows" in text and "snap_lat" in text


# ------------------------------------------- serve harness: spans + occupancy
def _serve_fixture(n=4_000):
    rng = np.random.default_rng(11)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(
            ["a.com", "b.com", "c.com", "rare.net"], p=[0.6, 0.25, 0.13, 0.02], size=n
        ).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    plane = DistIngestPlane.for_store(
        store, make_dev_mesh(1, 1), capacity=2 * n, tablets_per_device=2,
        mem_rows=512, max_runs=4, append_rows=256,
    )
    w = DistBatchWriter(store, plane, batch_rows=1024)
    w.add(ts, {k: list(v) for k, v in vals.items()})
    w.close()
    return store, plane


def test_serve_spans_and_occupancy_under_4_sessions():
    store, plane = _serve_fixture()
    obs.enable()
    obs.clear()
    try:
        with QueryService(store, plane, compaction_interval=0.01) as svc:
            sessions = [svc.session(name=f"s{i}") for i in range(4)]
            streams = []
            for i, s in enumerate(sessions):
                tree = Eq("domain", ["a.com", "b.com", "c.com", "rare.net"][i])
                streams.append(s.submit("batched_index", 0, T_SPAN, tree))
                streams.append(s.submit("batched_scan", 0, T_SPAN, None))
            for sq in streams:
                for _ in sq.results():
                    pass
            occ = svc._device_lock.snapshot()
    finally:
        obs.disable()

    # --- span integrity ---------------------------------------------------
    recs = list(obs.get_tracer().records)
    by_sid = {r["sid"]: r for r in recs}
    names = {r["name"] for r in recs}
    assert "serve.turn" in names and "query.step" in names and "query.plan" in names
    for r in recs:
        if r["parent"]:
            assert r["parent"] in by_sid, f"orphan parent for {r['name']}"
            p = by_sid[r["parent"]]
            assert p["tid"] == r["tid"]
            # Parent interval contains the child (small epsilon: both
            # timestamps come from the same perf_counter clock).
            assert p["t0"] - 1e-6 <= r["t0"]
            assert r["t0"] + r["dur"] <= p["t0"] + p["dur"] + 1e-6
    # Every query.step under serving hangs off a serve.turn ancestor.
    steps = [r for r in recs if r["name"] == "query.step"]
    assert steps

    def has_turn_ancestor(r):
        while r["parent"]:
            r = by_sid[r["parent"]]
            if r["name"] == "serve.turn":
                return True
        return False

    assert all(has_turn_ancestor(r) for r in steps)

    # --- occupancy --------------------------------------------------------
    by = occ["by_owner_s"]
    assert "unknown" not in by
    assert "session_turn" in by and "density_read" in by
    assert set(by) <= {"session_turn", "density_read", "fold_increment"}
    assert sum(by.values()) == pytest.approx(occ["total_held_s"], rel=1e-6)
    # Plane lock: fully attributed too (appends, publishes, folds...).
    pocc = plane._lock.snapshot()
    assert "unknown" not in pocc["by_owner_s"]
    assert sum(pocc["by_owner_s"].values()) == pytest.approx(
        pocc["total_held_s"], rel=1e-6
    )
    # Trace exports cleanly after the run.
    assert obs.validate_chrome_trace(obs.chrome_trace()) == []


def test_fold_attribution_still_exact():
    """The registry migration must not change fold_events semantics: the
    query path never folds, sources are the known set."""
    store, plane = _serve_fixture(n=2_000)
    plane.compact(source="explicit")
    dq = DistQueryProcessor(store, plane=plane)
    dq.scan_range(None, 0, T_SPAN)
    fe = plane.telemetry()["fold_events"]
    assert set(fe) <= {"ingest", "background", "explicit"}
    assert fe.get("explicit", 0) >= 1


# -------------------------------------------------------- overhead gate (<2%)
def test_disabled_tracing_overhead_under_2pct():
    """The acceptance gate: with tracing disabled, the per-span cost on
    the query path must be < 2% of a scan microbench step. Measured
    directly: (disabled span cost x spans-per-scan) vs median scan
    time."""
    store, plane = _serve_fixture(n=2_000)
    dq = DistQueryProcessor(store, plane=plane)
    assert not obs.enabled()
    dq.scan_range(None, 0, T_SPAN)  # warm compiles
    scan_times = []
    for _ in range(10):
        t0 = time.perf_counter()
        dq.scan_range(None, 0, T_SPAN)
        scan_times.append(time.perf_counter() - t0)
    scan_s = float(np.median(scan_times))

    n_iter = 100_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        with obs.span("x", cat="t"):
            pass
    span_s = (time.perf_counter() - t0) / n_iter
    # A scan_range call opens O(1) spans; allow ten for headroom.
    overhead = 10 * span_s / scan_s
    assert overhead < 0.02, f"disabled-span overhead {overhead:.4%} of a scan"


# ------------------------------------------------------------- span sampling
def test_span_sampling_keeps_every_nth_root_with_children():
    obs.clear()
    obs.enable(sample=1 / 3)
    try:
        for i in range(9):
            with obs.span(f"root{i}", cat="t"):
                with obs.span(f"child{i}", cat="t"):
                    pass
    finally:
        obs.disable()
        assert obs.get_tracer().sample_n == 1  # disable resets the knob
    names = [r["name"] for r in obs.get_tracer().records]
    # Roots 1, 4, 7 (1-based counter % 3 == 1) survive, each with its
    # child; children exit first so they precede their root on record.
    assert names == ["child0", "root0", "child3", "root3", "child6", "root6"]
    recs = {r["name"]: r for r in obs.get_tracer().records}
    for i in (0, 3, 6):
        assert recs[f"child{i}"]["parent"] == recs[f"root{i}"]["sid"]
    obs.clear()


def test_span_sampling_dropped_root_children_follow():
    """A child under a dropped root is dropped even if the tree is deep,
    and a dropped span's fence/set are pass-through no-ops."""
    obs.clear()
    obs.enable(sample=1 / 2)  # keeps roots 1, 3, ... drops 2, 4, ...
    try:
        with obs.span("kept", cat="t"):
            pass
        with obs.span("dropped", cat="t") as sp:
            assert sp.fence(41) == 41
            sp.set(ignored=True)
            with obs.span("d.child", cat="t"):
                with obs.span("d.grandchild", cat="t"):
                    pass
        # After the dropped tree closes, sampling resumes normally.
        with obs.span("kept2", cat="t"):
            pass
    finally:
        obs.disable()
    names = [r["name"] for r in obs.get_tracer().records]
    assert names == ["kept", "kept2"]
    obs.clear()


def test_span_sampling_full_rate_unchanged():
    """enable(sample=1.0) and plain enable() keep every span (the default
    path stays byte-identical in behavior)."""
    for kwargs in ({}, {"sample": 1.0}, {"sample": None}):
        obs.clear()
        obs.enable(**kwargs)
        try:
            with obs.span("a", cat="t"):
                with obs.span("b", cat="t"):
                    pass
        finally:
            obs.disable()
        assert {r["name"] for r in obs.get_tracer().records} == {"a", "b"}
    with pytest.raises(ValueError):
        obs.enable(sample=-0.5)
    obs.disable()
    obs.clear()


def test_sampled_out_span_overhead_gate():
    """The sampling companion to the disabled gate: a sampled-OUT span
    must stay within the same cheap-singleton cost class — no record
    append, no sid allocation, just a thread-local depth touch."""
    obs.clear()
    obs.enable(sample=1 / 100_000)
    try:
        n_iter = 50_000
        t0 = time.perf_counter()
        for _ in range(n_iter):
            with obs.span("x", cat="t"):
                pass
        per_span = (time.perf_counter() - t0) / n_iter
    finally:
        obs.disable()
    # Only the first root of the period was kept.
    assert len(obs.get_tracer().records) == 1
    assert per_span < 50e-6, f"sampled-out span cost {per_span * 1e6:.1f}us"
    obs.clear()


# ----------------------------------------------------- Prometheus exposition
def _parse_prom(text):
    """Tiny exposition-format parser: name -> {"type": ..., "samples":
    {(sample_name, frozenset(labels.items())): value}}."""
    import re

    out = {}
    types = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            types[name] = kind
            out.setdefault(name, {"type": kind, "samples": {}})
            continue
        if line.startswith("#"):
            continue
        m = re.match(r"^([a-zA-Z0-9_:]+)(\{(.*)\})? (\S+)$", line)
        assert m, f"unparseable sample line: {line!r}"
        sname, _, labelstr, val = m.groups()
        labels = {}
        if labelstr:
            for part in re.findall(r'(\w+)="((?:[^"\\]|\\.)*)"', labelstr):
                labels[part[0]] = part[1].replace('\\"', '"').replace("\\\\", "\\")
        family = next((t for t in types if sname.startswith(t)), sname)
        out.setdefault(family, {"type": types.get(family), "samples": {}})
        fval = float("inf") if val == "+Inf" else float(val)
        out[family]["samples"][(sname, frozenset(labels.items()))] = fval
    return out


def test_prometheus_text_roundtrip():
    reg = MetricsRegistry("t_prom")
    c = reg.counter("prom_rows_total", "rows ingested")
    c.inc(5, writer="3")
    c.inc(2.5, writer="7")
    g = reg.gauge("prom_fill", "memtable fill fraction")
    g.set(0.5)
    h = reg.histogram("prom_lat_seconds", "latency", edges=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0, 0.05):
        h.observe(v, op="scan")

    doc = _parse_prom(obs.to_prometheus_text(reg))

    assert doc["prom_rows_total"]["type"] == "counter"
    s = doc["prom_rows_total"]["samples"]
    assert s[("prom_rows_total", frozenset({("writer", "3")}))] == 5.0
    assert s[("prom_rows_total", frozenset({("writer", "7")}))] == 2.5

    assert doc["prom_fill"]["type"] == "gauge"
    assert doc["prom_fill"]["samples"][("prom_fill", frozenset())] == 0.5

    assert doc["prom_lat_seconds"]["type"] == "histogram"
    hs = doc["prom_lat_seconds"]["samples"]

    def bucket(le):
        return hs[("prom_lat_seconds_bucket", frozenset({("op", "scan"), ("le", le)}))]

    # Cumulative buckets, exact against the observations above.
    assert bucket("0.01") == 1
    assert bucket("0.1") == 3
    assert bucket("1") == 4
    assert bucket("+Inf") == 5
    assert hs[("prom_lat_seconds_count", frozenset({("op", "scan")}))] == 5
    assert hs[("prom_lat_seconds_sum", frozenset({("op", "scan")}))] == pytest.approx(
        5.605
    )


def test_prometheus_text_escaping_and_empty():
    reg = MetricsRegistry("t_prom_esc")
    assert obs.to_prometheus_text(reg) == ""
    c = reg.counter("esc_total", 'help with "quotes"')
    c.inc(1, path='a"b\\c')
    text = obs.to_prometheus_text(reg)
    assert '# HELP esc_total help with \\"quotes\\"' in text
    doc = _parse_prom(text)
    assert doc["esc_total"]["samples"][
        ("esc_total", frozenset({("path", 'a"b\\c')}))
    ] == 1.0


def test_prometheus_text_all_registries_dedupes_names():
    a = MetricsRegistry("t_prom_a")
    b = MetricsRegistry("t_prom_b")
    a.counter("dup_total").inc(1)
    b.counter("dup_total").inc(100)
    text = obs.to_prometheus_text()
    assert text.count("# TYPE dup_total counter") == 1


# ----------------------------------------------------------------- exporters
def test_write_exporters_roundtrip(tmp_path):
    obs.enable()
    obs.clear()
    try:
        with obs.span("io", cat="t"):
            pass
    finally:
        obs.disable()
    tpath = tmp_path / "trace.json"
    mpath = tmp_path / "metrics.json"
    obs.write_chrome_trace(str(tpath))
    obs.write_metrics_json(str(mpath))
    tdoc = json.loads(tpath.read_text())
    mdoc = json.loads(mpath.read_text())
    assert obs.validate_chrome_trace(tdoc) == []
    assert mdoc["schema_version"] == 1
    assert "lock_occupancy" in mdoc


# ----------------------------------------------------- Prometheus endpoint
def test_serve_prometheus_start_scrape_stop():
    """The pull endpoint serves the exposition text at /metrics on an
    ephemeral port, 404s other paths, and stops cleanly (twice over:
    explicit stop and context manager)."""
    from urllib.error import HTTPError
    from urllib.request import urlopen

    reg = MetricsRegistry("t_prom_http")
    reg.counter("scrapes_total", "scrapes").inc(3, path="/metrics")
    ep = obs.serve_prometheus(reg)
    try:
        assert ep.port > 0
        body = urlopen(ep.url, timeout=5).read().decode()
        assert body == obs.to_prometheus_text(reg)
        assert 'scrapes_total{path="/metrics"} 3' in body
        with pytest.raises(HTTPError) as exc:
            urlopen(f"http://{ep.host}:{ep.port}/other", timeout=5)
        assert exc.value.code == 404
    finally:
        ep.stop()
    with pytest.raises(OSError):
        urlopen(f"http://{ep.host}:{ep.port}/metrics", timeout=1)
    with obs.serve_prometheus(reg) as ep2:
        assert urlopen(ep2.url, timeout=5).status == 200


# ------------------------------------------------------- lock wait accounting
def test_owned_lock_books_acquire_wait():
    """total_wait_s/wait_by_owner_s accumulate the time a would-be holder
    spent inside acquire(): a sole acquirer books ~zero wait, a thread
    blocked behind a deliberate hold books at least the hold time."""
    lk = obs.OwnedLock("t_wait_lock")
    with lk.hold("solo"):
        pass
    solo = lk.snapshot()
    assert solo["total_wait_s"] < 0.05  # uncontended: microseconds
    hold_s = 0.15
    started = threading.Event()

    def holder():
        with lk.hold("hog"):
            started.set()
            time.sleep(hold_s)

    t = threading.Thread(target=holder)
    t.start()
    started.wait()
    with lk.hold("waiter"):
        pass
    t.join()
    snap = lk.snapshot()
    assert snap["wait_by_owner_s"]["waiter"] > hold_s / 2
    assert abs(
        sum(snap["wait_by_owner_s"].values()) - snap["total_wait_s"]
    ) < 1e-9
    # Merged report carries the same keys; reset clears them.
    merged = obs.occupancy_snapshot()["t_wait_lock"]
    assert merged["total_wait_s"] == snap["total_wait_s"]
    lk.reset()
    assert lk.snapshot()["total_wait_s"] == 0.0


# ------------------------------------------------------------ flight recorder
def test_flight_ring_wraparound_evicts_oldest():
    """A private recorder with an 8-slot ring keeps exactly the 8 newest
    spans; older records are overwritten in place and sids never repeat."""
    fr = obs.FlightRecorder(per_thread=8)
    for i in range(20):
        with fr.span(f"s{i}", cat="t"):
            pass
    recs = fr.records()
    assert [r["name"] for r in recs] == [f"s{i}" for i in range(12, 20)]
    assert len({r["sid"] for r in recs}) == 8
    # Flight sids live in their own namespace, far above tracer sids.
    assert all(r["sid"] >= (1 << 40) for r in recs)


def test_flight_dump_roundtrips_validator_with_evicted_parents():
    """dump() must validate even when the ring evicted (or has not yet
    recorded) a kept child's parent: the dangling parent ref is cleared.
    Once the parent record lands, kept children link to it again."""
    fr = obs.FlightRecorder(per_thread=4)
    with fr.span("root", cat="t"):
        for i in range(6):
            with fr.span(f"c{i}", cat="t"):
                pass
        # Root is still open -> not recorded -> every kept child's parent
        # points outside the dump. The dump must clear those refs.
        mid = fr.dump(window_s=60.0)
        assert obs.validate_chrome_trace(mid) == []
        xs = [e for e in mid["traceEvents"] if e.get("ph") == "X"]
        assert [e["name"] for e in xs] == ["c2", "c3", "c4", "c5"]
        assert all("parent" not in e["args"] for e in xs)
    doc = fr.dump(window_s=60.0)
    assert obs.validate_chrome_trace(doc) == []
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    # records() orders by span START, so the long-open root sorts first
    assert [e["name"] for e in xs] == ["root", "c3", "c4", "c5"]
    root_sid = next(e["args"]["sid"] for e in xs if e["name"] == "root")
    for e in xs:
        if e["name"] != "root":
            assert e["args"]["parent"] == root_sid
    # Thread metadata rides along for Perfetto lane names.
    assert any(e.get("ph") == "M" for e in doc["traceEvents"])


def test_flight_captures_serve_plane_with_tracing_disabled():
    """The acceptance shape: tracing OFF for the whole run, flight ON —
    the dump still covers ingest, fold, and serve spans and validates."""
    assert not obs.enabled()
    obs.flight_clear()
    obs.flight_enable()
    try:
        store, plane = _serve_fixture(n=2_000)
        plane.compact(source="explicit")
        with QueryService(store, plane, compaction_interval=0.01) as svc:
            s = svc.session("flight0")
            s.submit("batched_index", 0, T_SPAN, Eq("domain", "a.com")).drain(
                timeout=120.0
            )
        doc = obs.flight_dump(window_s=600.0)
    finally:
        obs.flight_disable()
        obs.flight_clear()
    assert obs.validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"}
    assert "serve.turn" in names
    assert any(n.startswith("ingest.") for n in names)
    assert "ingest.compact" in names  # the fold path
    assert any(n.startswith("query.") for n in names)


def test_flight_enabled_overhead_under_2pct():
    """Same budget as the disabled-tracing gate: with the flight recorder
    armed (tracing still off), per-span cost stays < 2% of a scan step."""
    store, plane = _serve_fixture(n=2_000)
    dq = DistQueryProcessor(store, plane=plane)
    assert not obs.enabled()
    dq.scan_range(None, 0, T_SPAN)  # warm compiles
    scan_times = []
    for _ in range(10):
        t0 = time.perf_counter()
        dq.scan_range(None, 0, T_SPAN)
        scan_times.append(time.perf_counter() - t0)
    scan_s = float(np.median(scan_times))

    obs.flight_clear()
    obs.flight_enable()
    try:
        n_iter = 100_000
        t0 = time.perf_counter()
        for _ in range(n_iter):
            with obs.span("x", cat="t"):
                pass
        span_s = (time.perf_counter() - t0) / n_iter
    finally:
        obs.flight_disable()
        obs.flight_clear()
    overhead = 10 * span_s / scan_s
    assert overhead < 0.02, f"flight-span overhead {overhead:.4%} of a scan"


def test_flight_captures_sampled_out_spans():
    """With tracing sampling at 1/3, the tracer keeps every 3rd root but
    the flight window keeps ALL of them — its bound is time, not rate."""
    obs.flight_clear()
    obs.flight_enable()
    obs.clear()
    obs.enable(sample=1 / 3)
    try:
        for i in range(9):
            with obs.span(f"fr{i}", cat="t"):
                with obs.span(f"fk{i}", cat="t"):
                    pass
    finally:
        obs.disable()
    fnames = {r["name"] for r in obs.get_flight().records()}
    obs.flight_disable()
    obs.flight_clear()
    assert {f"fr{i}" for i in range(9)} <= fnames
    assert {f"fk{i}" for i in range(9)} <= fnames
    troots = [r for r in obs.get_tracer().records if r["name"].startswith("fr")]
    assert len(troots) == 3  # the sampler's view is still 1-in-3


# ----------------------------------------------------------------- watchdog
def test_watchdog_tick_writes_incident_bundle(tmp_path):
    """Synchronous tick(): below threshold -> nothing; p99 breach ->
    exactly one bundle (incident.json + validating trace.json +
    parseable metrics.json); cooldown suppresses the repeat."""
    reg = MetricsRegistry("t_wd_bundle")
    pending = []

    def probe():
        out = list(pending)
        pending.clear()
        return out

    rule = obs.WatchRule(
        "ttfr_p99", probe, 0.5, window_s=30.0, agg="p99", cooldown_s=3600.0
    )
    wd = obs.Watchdog(
        [rule], incident_dir=str(tmp_path / "inc"), registry=reg,
        flight_window_s=60.0,
    )
    obs.flight_clear()
    obs.flight_enable()
    try:
        with obs.span("incident_context", cat="t"):
            pass
        wd.tick()  # no events yet: no breach
        assert wd.incidents() == []
        pending.append((time.perf_counter(), 1.25))
        wd.tick()
    finally:
        obs.flight_disable()
        obs.flight_clear()
    incs = wd.incidents()
    assert len(incs) == 1 and incs[0]["kind"] == "incident"
    assert incs[0]["rule"] == "ttfr_p99"
    assert incs[0]["value"] == pytest.approx(1.25)

    bundle = incs[0]["bundle"]
    rec = json.loads(open(f"{bundle}/incident.json").read())
    assert rec["threshold"] == 0.5 and rec["agg"] == "p99"
    trace = json.loads(open(f"{bundle}/trace.json").read())
    assert obs.validate_chrome_trace(trace) == []
    assert any(
        e.get("name") == "incident_context" for e in trace["traceEvents"]
    )
    snap = json.loads(open(f"{bundle}/metrics.json").read())
    assert snap["kind"] == "obs_metrics_snapshot"

    # Registry surface: one incident, rule gauges populated.
    assert reg.counter("watchdog_incidents_total", "").value(rule="ttfr_p99") == 1
    assert reg.gauge("watchdog_rule_breached", "").value(rule="ttfr_p99") == 1.0

    # Cooldown: the window still holds the breach sample, but no new
    # bundle is written inside cooldown_s.
    wd.tick()
    assert len(wd.incidents()) == 1


def test_watchdog_rule_kinds_and_probe_error(tmp_path):
    """gauge/delta rule constructors breach on real metric movement, and
    a raising probe is recorded as probe_error without killing the tick."""
    reg = MetricsRegistry("t_wd_kinds")
    g = reg.gauge("stall_seconds", "worst increment")
    c = reg.counter("blocked_seconds_total", "writer blocked")

    def bad_probe():
        raise RuntimeError("probe exploded")

    wd = obs.Watchdog(
        [
            obs.gauge_rule("stall", g, 0.5, cooldown_s=3600.0),
            obs.counter_delta_rule(
                "blocked", c, 1.0, window_s=30.0, cooldown_s=3600.0
            ),
            obs.WatchRule("boom", bad_probe, 1.0, agg="gauge"),
        ],
        incident_dir=str(tmp_path / "inc"),
        registry=reg,
    )
    wd.tick()  # baseline: nothing breaches, boom errors
    assert [i["rule"] for i in wd.incidents() if i["kind"] == "probe_error"] == [
        "boom"
    ]
    assert wd.values()["stall"] == 0.0 and wd.values()["blocked"] == 0.0

    g.set(0.75)
    c.inc(5.0, writer="w0")
    wd.tick()
    fired = {i["rule"] for i in wd.incidents() if i.get("kind") == "incident"}
    assert fired == {"stall", "blocked"}
    assert wd.values()["stall"] == pytest.approx(0.75)
    assert wd.values()["blocked"] == pytest.approx(5.0)  # delta over window
    # Both bundles exist on disk with the full triple.
    for inc in wd.incidents():
        if inc.get("kind") != "incident":
            continue
        for part in ("incident.json", "trace.json", "metrics.json"):
            assert json.loads(open(f"{inc['bundle']}/{part}").read()) is not None


# ------------------------------------------------------------- query profile
def test_query_profile_breakdown_sums_to_ttfr():
    """Every served stream carries a committed QueryProfile whose six
    first-result stages tile the measured TTFR to within 5%, and the
    stage histograms carry trace-id exemplars."""
    store, plane = _serve_fixture()
    with QueryService(store, plane, compaction_interval=0.01) as svc:
        sessions = [svc.session(name=f"p{i}") for i in range(4)]
        streams = []
        for i, s in enumerate(sessions):
            tree = Eq("domain", ["a.com", "b.com", "c.com", "rare.net"][i])
            streams.append(s.submit("batched_index", 0, T_SPAN, tree))
            streams.append(s.submit("batched_scan", 0, T_SPAN, None))
        for sq in streams:
            sq.drain(timeout=120.0)
    for sq in streams:
        p = sq.profile
        assert p.committed and p.ttfr_s is not None and p.ttfr_s > 0
        stages = p.stages()
        assert set(stages) == set(
            ("admission", "plan", "density_fence", "device_step",
             "epilogue", "deliver")
        )
        assert all(v >= 0.0 for v in stages.values()), stages
        gap = abs(p.breakdown_sum_s() - p.ttfr_s)
        assert gap <= 0.05 * p.ttfr_s, (
            f"{p.scheme} q{p.qid}: stages {p.breakdown_sum_s():.6f}s vs "
            f"ttfr {p.ttfr_s:.6f}s ({gap / p.ttfr_s:.2%} off)"
        )
        # The queue sub-split never exceeds the whole admission stage.
        assert p.admission_queue_s <= p.admission_s + 1e-6
        assert p.steps_total >= 1 and p.device_total_s >= p.device_step_s

    import re

    h = obs.get_registry().histogram("query_profile_seconds", "")
    cell = h.snapshot(stage="device_step", scheme="batched_index")
    assert cell is not None and cell["count"] >= 1
    assert re.fullmatch(r"q\d+", cell["exemplar"]["trace_id"])
    th = obs.get_registry().histogram("query_profile_ttfr_seconds", "")
    tcell = th.snapshot(scheme="batched_scan")
    assert tcell is not None and re.fullmatch(r"q\d+", tcell["exemplar"]["trace_id"])


# -------------------------------------------------- /metrics under hammering
def _assert_hist_families_consistent(parsed):
    """Every histogram family in one scrape is internally consistent:
    cumulative buckets monotone in le, +Inf bucket equals _count."""
    for name, fam in parsed.items():
        if fam["type"] != "histogram":
            continue
        buckets, counts = {}, {}
        for (sname, labels), val in fam["samples"].items():
            ld = dict(labels)
            if sname.endswith("_bucket"):
                le = ld.pop("le")
                key = frozenset(ld.items())
                edge = float("inf") if le == "+Inf" else float(le)
                buckets.setdefault(key, []).append((edge, val))
            elif sname.endswith("_count"):
                counts[frozenset(ld.items())] = val
        for key, bs in buckets.items():
            bs.sort()
            vals = [v for _, v in bs]
            assert vals == sorted(vals), f"{name}: non-monotone buckets"
            assert bs[-1][0] == float("inf"), f"{name}: missing +Inf bucket"
            assert vals[-1] == counts[key], f"{name}: +Inf bucket != count"


def test_serve_prometheus_concurrent_scrapes_during_ingest():
    """Hammer /metrics from several threads while a writer feeds the live
    plane: every scrape parses, every histogram snapshot is internally
    consistent, no thread raises, and the port is released on stop()."""
    import socket
    from urllib.request import urlopen

    store, plane = _serve_fixture(n=2_000)
    ep = obs.serve_prometheus()  # all registries, incl. the live plane's
    stop = threading.Event()
    errors = []

    def writer_loop():
        w = DistBatchWriter(store, plane, batch_rows=256)
        rng = np.random.default_rng(5)
        budget = 1_800
        try:
            while not stop.is_set() and budget > 0:
                m = 128
                bts = np.sort(rng.integers(0, T_SPAN, m))
                bvals = {
                    "domain": rng.choice(
                        ["a.com", "b.com", "c.com", "rare.net"],
                        p=[0.6, 0.25, 0.13, 0.02], size=m,
                    ).tolist(),
                    "method": rng.choice(["GET", "POST"], size=m).tolist(),
                    "status": rng.choice(
                        ["200", "404"], size=m, p=[0.8, 0.2]
                    ).tolist(),
                }
                w.add(bts, bvals)
                budget -= m
        except Exception as e:  # surfaced below; must not die silently
            errors.append(e)
        finally:
            w.close()

    scrapes = [0] * 4

    def scrape_loop(i):
        deadline = time.perf_counter() + 1.2
        try:
            while time.perf_counter() < deadline:
                body = urlopen(ep.url, timeout=10).read().decode()
                _assert_hist_families_consistent(_parse_prom(body))
                scrapes[i] += 1
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=writer_loop)] + [
        threading.Thread(target=scrape_loop, args=(i,)) for i in range(4)
    ]
    try:
        for t in threads:
            t.start()
    finally:
        for t in threads[1:]:
            t.join()
        stop.set()
        threads[0].join()
        ep.stop()
    assert not errors, errors
    assert all(n > 0 for n in scrapes)
    # Port fully released: a fresh socket can bind it immediately.
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    try:
        s.bind((ep.host, ep.port))
    finally:
        s.close()


# --------------------------------------------------------------- daemon smoke
def test_serve_daemon_main_produces_incident(tmp_path, capsys):
    """`python -m repro.serve_db` end to end, in-process: a tight TTFR
    SLO must yield exit 0, the machine-readable header lines, and a
    validating incident bundle."""
    from repro.serve_db.__main__ import main

    try:
        rc = main(
            [
                "--rows", "1200", "--sessions", "2", "--writers", "1",
                "--duration", "1.5", "--incident-dir", str(tmp_path / "inc"),
                "--ttfr-slo", "0.000001", "--window", "5", "--tick", "0.1",
                "--groups", "1", "--tablets-per-device", "2",
            ]
        )
    finally:
        obs.flight_disable()  # main() arms the global recorder
        obs.flight_clear()
    assert rc == 0
    out = capsys.readouterr().out
    assert "METRICS_URL=http://" in out
    assert f"INCIDENT_DIR={tmp_path / 'inc'}" in out
    bundles = sorted((tmp_path / "inc").glob("*_ttfr_p99"))
    assert bundles, out
    trace = json.loads((bundles[0] / "trace.json").read_text())
    assert obs.validate_chrome_trace(trace) == []
    assert any(e.get("ph") == "X" for e in trace["traceEvents"])
    snap = json.loads((bundles[0] / "metrics.json").read_text())
    assert snap["kind"] == "obs_metrics_snapshot"
    assert "INCIDENT=" in out
