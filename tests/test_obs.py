"""Observability plane: registry semantics vs numpy oracles, span
nesting/parent integrity under the concurrent serve harness, occupancy
attribution summing to lock-held time, the disabled-mode overhead gate,
and Chrome-trace schema validation."""
import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import EventStore, Eq, web_proxy_schema
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor
from repro.core.ingest import BatchWriter, IngestMetrics, rate_series
from repro.launch.mesh import make_dev_mesh
from repro.obs.registry import MetricsRegistry
from repro.serve_db import QueryService

T_SPAN = 2 * 3600


# ---------------------------------------------------------------- registry
def test_counter_label_semantics():
    reg = MetricsRegistry("t_counter")
    c = reg.counter("rows")
    rng = np.random.default_rng(0)
    per = {}
    for _ in range(500):
        w = int(rng.integers(0, 5))
        v = float(rng.integers(1, 100))
        c.inc(v, writer=w)
        per[w] = per.get(w, 0.0) + v
    for w, total in per.items():
        assert c.value(writer=w) == total
    assert c.total() == pytest.approx(sum(per.values()))
    # reset of one label leaves the others
    c.reset(writer=0)
    assert c.value(writer=0) == 0.0
    assert c.value(writer=1) == per.get(1, 0.0)


def test_counter_threaded_total():
    reg = MetricsRegistry("t_threads")
    c = reg.counter("hits")

    def work(tid):
        for _ in range(2000):
            c.inc(1, thread=tid)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.total() == 8000


def test_histogram_vs_numpy_oracle():
    reg = MetricsRegistry("t_hist")
    edges = [0.001, 0.01, 0.1, 1.0]
    h = reg.histogram("lat", edges=edges)
    rng = np.random.default_rng(7)
    vals = rng.lognormal(mean=-4, sigma=2.0, size=2000)
    for v in vals:
        h.observe(float(v))
    snap = h.snapshot()
    # Oracle: np.histogram over (-inf, e0], (e0, e1], ..., (e_last, inf)
    oracle, _ = np.histogram(vals, bins=[-np.inf] + edges + [np.inf])
    assert snap["buckets"] == oracle.tolist()
    assert snap["count"] == len(vals)
    assert snap["sum"] == pytest.approx(vals.sum(), rel=1e-9)
    assert snap["min"] == pytest.approx(vals.min())
    assert snap["max"] == pytest.approx(vals.max())


def test_histogram_bucket_edge_exact():
    """A value exactly on an edge lands in the bucket that edge closes
    (half-open on the left), deterministically."""
    reg = MetricsRegistry("t_edge")
    h = reg.histogram("x", edges=[1.0, 2.0])
    for _ in range(10):
        h.observe(1.0)
    snap = h.snapshot()
    assert snap["buckets"] == [10, 0, 0]
    assert snap["count"] == 10


def test_registry_disabled_is_noop():
    reg = MetricsRegistry("t_disabled", enabled=False)
    c = reg.counter("n")
    h = reg.histogram("h")
    c.inc(5)
    h.observe(1.0)
    assert c.total() == 0.0
    assert h.count() == 0


def test_metric_kind_collision_raises():
    reg = MetricsRegistry("t_kind")
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


# ------------------------------------------------------------- IngestMetrics
def test_ingest_metrics_is_registry_view():
    m = IngestMetrics()
    m.rows += 100
    m.rows += 50
    m.blocked_seconds += 0.25
    assert m.rows == 150
    assert m.blocked_seconds == pytest.approx(0.25)
    # The same cells are visible on the default registry, per-writer.
    reg = obs.get_registry()
    c = reg.get("ingest_rows_total")
    assert c is not None and c.value(writer=m._label) == 150
    # Independent instances never share cells.
    m2 = IngestMetrics()
    assert m2.rows == 0
    m2.rows = 7
    assert m.rows == 150 and m2.rows == 7


# --------------------------------------------------------------- rate_series
def test_rate_series_conserves_rows():
    m = IngestMetrics()
    rng = np.random.default_rng(3)
    t0 = 1000.0
    for i in range(200):
        m.samples.append((t0 + float(rng.uniform(0, 10)), int(rng.integers(1, 500))))
    m.samples.sort()
    for bucket in (0.25, 0.5, 1.0):
        xs, rate = rate_series([m], bucket_s=bucket)
        total = sum(s[1] for s in m.samples)
        assert rate.sum() * bucket == pytest.approx(total)
        assert len(xs) == len(rate)


def test_rate_series_boundary_not_double_counted():
    """Events exactly on bucket boundaries land in exactly one bucket:
    totals conserve and the bucket assignment is the half-open one."""
    m = IngestMetrics()
    t0 = 50.0
    bucket = 0.25
    # Samples exactly on edges 0, 1, 2, ... of the bucket grid.
    for i in range(8):
        m.samples.append((t0 + i * bucket, 100))
    xs, rate = rate_series([m], bucket_s=bucket)
    assert rate.sum() * bucket == pytest.approx(800)
    # Each on-edge event opens its own bucket: one event per bucket.
    assert np.allclose(rate[: len(rate) - 1], 100 / bucket) or rate.max() * bucket == 100


def test_rate_series_empty():
    xs, rate = rate_series([IngestMetrics()])
    assert len(xs) == 0 and len(rate) == 0


# ----------------------------------------------------------------- OwnedLock
def test_owned_lock_partitions_held_time():
    lk = obs.OwnedLock("t_lock")
    with lk.hold("a"):
        time.sleep(0.02)
        with lk.reowner("b"):
            time.sleep(0.03)
        time.sleep(0.01)
    with lk.hold("c"):
        time.sleep(0.01)
    snap = lk.snapshot()
    by = snap["by_owner_s"]
    assert set(by) == {"a", "b", "c"}
    # Books balance exactly: per-owner segments partition each hold.
    assert sum(by.values()) == pytest.approx(snap["total_held_s"], rel=1e-9)
    assert by["b"] >= 0.025  # the re-owned stretch is charged to b
    assert snap["acquisitions"] == 2


def test_owned_lock_plain_with_is_unknown():
    lk = obs.OwnedLock("t_lock_plain")
    with lk:
        pass
    assert "unknown" in lk.snapshot()["by_owner_s"]


def test_owned_lock_nonblocking_contention():
    lk = obs.OwnedLock("t_lock_nb")
    assert lk.acquire(blocking=False, owner="x")
    assert not lk.acquire(blocking=False, owner="y")
    lk.release()
    snap = lk.snapshot()
    assert snap["acquisitions"] == 1
    assert "y" not in snap["by_owner_s"]


# ------------------------------------------------------------------- tracing
def test_span_nesting_and_parent_linkage():
    obs.enable()
    obs.clear()
    try:
        with obs.span("outer", cat="t") as so:
            with obs.span("inner", cat="t") as si:
                pass
        with obs.span("sibling", cat="t"):
            pass
    finally:
        obs.disable()
    recs = {r["name"]: r for r in obs.get_tracer().records}
    assert recs["inner"]["parent"] == recs["outer"]["sid"]
    assert recs["sibling"]["parent"] == 0
    assert recs["outer"]["parent"] == 0
    # Parent interval contains the child (same thread, same clock).
    o, i = recs["outer"], recs["inner"]
    assert o["t0"] <= i["t0"] and i["t0"] + i["dur"] <= o["t0"] + o["dur"] + 1e-6
    assert o["tid"] == i["tid"]


def test_traced_decorator_and_args():
    obs.enable()
    obs.clear()
    try:

        @obs.traced("deco.fn", cat="t")
        def fn(x):
            return x + 1

        assert fn(1) == 2
        with obs.span("with_args", cat="t", k=3) as sp:
            sp.set(result=9)
    finally:
        obs.disable()
    recs = {r["name"]: r for r in obs.get_tracer().records}
    assert "deco.fn" in recs
    assert recs["with_args"]["args"] == {"k": 3, "result": 9}


def test_chrome_trace_schema():
    obs.enable()
    obs.clear()
    try:
        with obs.span("a", cat="t"):
            with obs.span("b", cat="t"):
                pass
    finally:
        obs.disable()
    doc = obs.chrome_trace()
    # Round-trips through JSON and passes the shared validator.
    doc2 = json.loads(json.dumps(doc))
    assert obs.validate_chrome_trace(doc2) == []
    xs = [e for e in doc2["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"a", "b"}
    b = next(e for e in xs if e["name"] == "b")
    a = next(e for e in xs if e["name"] == "a")
    assert b["args"]["parent"] == a["args"]["sid"]


def test_chrome_trace_validator_catches_problems():
    assert obs.validate_chrome_trace({}) != []
    assert obs.validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [{"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0.0, "dur": -1.0}]}
    assert any("negative" in p for p in obs.validate_chrome_trace(bad))
    orphan = {
        "traceEvents": [
            {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0.0, "dur": 1.0,
             "args": {"sid": 1, "parent": 99}}
        ]
    }
    assert any("parent" in p for p in obs.validate_chrome_trace(orphan))


def test_metrics_snapshot_and_summary():
    reg = MetricsRegistry("t_snapshot")
    reg.counter("snap_rows").inc(42, writer="w")
    reg.histogram("snap_lat").observe(0.005)
    snap = obs.metrics_snapshot()
    assert snap["schema_version"] == 1
    assert "t_snapshot" in snap["registries"]
    cells = snap["registries"]["t_snapshot"]["snap_rows"]["cells"]
    assert cells == {"writer=w": 42.0}
    json.dumps(snap)  # JSON-serializable end to end
    text = obs.summary()
    assert "snap_rows" in text and "snap_lat" in text


# ------------------------------------------- serve harness: spans + occupancy
def _serve_fixture(n=4_000):
    rng = np.random.default_rng(11)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(
            ["a.com", "b.com", "c.com", "rare.net"], p=[0.6, 0.25, 0.13, 0.02], size=n
        ).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    plane = DistIngestPlane.for_store(
        store, make_dev_mesh(1, 1), capacity=2 * n, tablets_per_device=2,
        mem_rows=512, max_runs=4, append_rows=256,
    )
    w = DistBatchWriter(store, plane, batch_rows=1024)
    w.add(ts, {k: list(v) for k, v in vals.items()})
    w.close()
    return store, plane


def test_serve_spans_and_occupancy_under_4_sessions():
    store, plane = _serve_fixture()
    obs.enable()
    obs.clear()
    try:
        with QueryService(store, plane, compaction_interval=0.01) as svc:
            sessions = [svc.session(name=f"s{i}") for i in range(4)]
            streams = []
            for i, s in enumerate(sessions):
                tree = Eq("domain", ["a.com", "b.com", "c.com", "rare.net"][i])
                streams.append(s.submit("batched_index", 0, T_SPAN, tree))
                streams.append(s.submit("batched_scan", 0, T_SPAN, None))
            for sq in streams:
                for _ in sq.results():
                    pass
            occ = svc._device_lock.snapshot()
    finally:
        obs.disable()

    # --- span integrity ---------------------------------------------------
    recs = list(obs.get_tracer().records)
    by_sid = {r["sid"]: r for r in recs}
    names = {r["name"] for r in recs}
    assert "serve.turn" in names and "query.step" in names and "query.plan" in names
    for r in recs:
        if r["parent"]:
            assert r["parent"] in by_sid, f"orphan parent for {r['name']}"
            p = by_sid[r["parent"]]
            assert p["tid"] == r["tid"]
            # Parent interval contains the child (small epsilon: both
            # timestamps come from the same perf_counter clock).
            assert p["t0"] - 1e-6 <= r["t0"]
            assert r["t0"] + r["dur"] <= p["t0"] + p["dur"] + 1e-6
    # Every query.step under serving hangs off a serve.turn ancestor.
    steps = [r for r in recs if r["name"] == "query.step"]
    assert steps

    def has_turn_ancestor(r):
        while r["parent"]:
            r = by_sid[r["parent"]]
            if r["name"] == "serve.turn":
                return True
        return False

    assert all(has_turn_ancestor(r) for r in steps)

    # --- occupancy --------------------------------------------------------
    by = occ["by_owner_s"]
    assert "unknown" not in by
    assert "session_turn" in by and "density_read" in by
    assert set(by) <= {"session_turn", "density_read", "fold_increment"}
    assert sum(by.values()) == pytest.approx(occ["total_held_s"], rel=1e-6)
    # Plane lock: fully attributed too (appends, publishes, folds...).
    pocc = plane._lock.snapshot()
    assert "unknown" not in pocc["by_owner_s"]
    assert sum(pocc["by_owner_s"].values()) == pytest.approx(
        pocc["total_held_s"], rel=1e-6
    )
    # Trace exports cleanly after the run.
    assert obs.validate_chrome_trace(obs.chrome_trace()) == []


def test_fold_attribution_still_exact():
    """The registry migration must not change fold_events semantics: the
    query path never folds, sources are the known set."""
    store, plane = _serve_fixture(n=2_000)
    plane.compact(source="explicit")
    dq = DistQueryProcessor(store, plane=plane)
    dq.scan_range(None, 0, T_SPAN)
    fe = plane.telemetry()["fold_events"]
    assert set(fe) <= {"ingest", "background", "explicit"}
    assert fe.get("explicit", 0) >= 1


# -------------------------------------------------------- overhead gate (<2%)
def test_disabled_tracing_overhead_under_2pct():
    """The acceptance gate: with tracing disabled, the per-span cost on
    the query path must be < 2% of a scan microbench step. Measured
    directly: (disabled span cost x spans-per-scan) vs median scan
    time."""
    store, plane = _serve_fixture(n=2_000)
    dq = DistQueryProcessor(store, plane=plane)
    assert not obs.enabled()
    dq.scan_range(None, 0, T_SPAN)  # warm compiles
    scan_times = []
    for _ in range(10):
        t0 = time.perf_counter()
        dq.scan_range(None, 0, T_SPAN)
        scan_times.append(time.perf_counter() - t0)
    scan_s = float(np.median(scan_times))

    n_iter = 100_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        with obs.span("x", cat="t"):
            pass
    span_s = (time.perf_counter() - t0) / n_iter
    # A scan_range call opens O(1) spans; allow ten for headroom.
    overhead = 10 * span_s / scan_s
    assert overhead < 0.02, f"disabled-span overhead {overhead:.4%} of a scan"


# ------------------------------------------------------------- span sampling
def test_span_sampling_keeps_every_nth_root_with_children():
    obs.clear()
    obs.enable(sample=1 / 3)
    try:
        for i in range(9):
            with obs.span(f"root{i}", cat="t"):
                with obs.span(f"child{i}", cat="t"):
                    pass
    finally:
        obs.disable()
        assert obs.get_tracer().sample_n == 1  # disable resets the knob
    names = [r["name"] for r in obs.get_tracer().records]
    # Roots 1, 4, 7 (1-based counter % 3 == 1) survive, each with its
    # child; children exit first so they precede their root on record.
    assert names == ["child0", "root0", "child3", "root3", "child6", "root6"]
    recs = {r["name"]: r for r in obs.get_tracer().records}
    for i in (0, 3, 6):
        assert recs[f"child{i}"]["parent"] == recs[f"root{i}"]["sid"]
    obs.clear()


def test_span_sampling_dropped_root_children_follow():
    """A child under a dropped root is dropped even if the tree is deep,
    and a dropped span's fence/set are pass-through no-ops."""
    obs.clear()
    obs.enable(sample=1 / 2)  # keeps roots 1, 3, ... drops 2, 4, ...
    try:
        with obs.span("kept", cat="t"):
            pass
        with obs.span("dropped", cat="t") as sp:
            assert sp.fence(41) == 41
            sp.set(ignored=True)
            with obs.span("d.child", cat="t"):
                with obs.span("d.grandchild", cat="t"):
                    pass
        # After the dropped tree closes, sampling resumes normally.
        with obs.span("kept2", cat="t"):
            pass
    finally:
        obs.disable()
    names = [r["name"] for r in obs.get_tracer().records]
    assert names == ["kept", "kept2"]
    obs.clear()


def test_span_sampling_full_rate_unchanged():
    """enable(sample=1.0) and plain enable() keep every span (the default
    path stays byte-identical in behavior)."""
    for kwargs in ({}, {"sample": 1.0}, {"sample": None}):
        obs.clear()
        obs.enable(**kwargs)
        try:
            with obs.span("a", cat="t"):
                with obs.span("b", cat="t"):
                    pass
        finally:
            obs.disable()
        assert {r["name"] for r in obs.get_tracer().records} == {"a", "b"}
    with pytest.raises(ValueError):
        obs.enable(sample=-0.5)
    obs.disable()
    obs.clear()


def test_sampled_out_span_overhead_gate():
    """The sampling companion to the disabled gate: a sampled-OUT span
    must stay within the same cheap-singleton cost class — no record
    append, no sid allocation, just a thread-local depth touch."""
    obs.clear()
    obs.enable(sample=1 / 100_000)
    try:
        n_iter = 50_000
        t0 = time.perf_counter()
        for _ in range(n_iter):
            with obs.span("x", cat="t"):
                pass
        per_span = (time.perf_counter() - t0) / n_iter
    finally:
        obs.disable()
    # Only the first root of the period was kept.
    assert len(obs.get_tracer().records) == 1
    assert per_span < 50e-6, f"sampled-out span cost {per_span * 1e6:.1f}us"
    obs.clear()


# ----------------------------------------------------- Prometheus exposition
def _parse_prom(text):
    """Tiny exposition-format parser: name -> {"type": ..., "samples":
    {(sample_name, frozenset(labels.items())): value}}."""
    import re

    out = {}
    types = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            types[name] = kind
            out.setdefault(name, {"type": kind, "samples": {}})
            continue
        if line.startswith("#"):
            continue
        m = re.match(r"^([a-zA-Z0-9_:]+)(\{(.*)\})? (\S+)$", line)
        assert m, f"unparseable sample line: {line!r}"
        sname, _, labelstr, val = m.groups()
        labels = {}
        if labelstr:
            for part in re.findall(r'(\w+)="((?:[^"\\]|\\.)*)"', labelstr):
                labels[part[0]] = part[1].replace('\\"', '"').replace("\\\\", "\\")
        family = next((t for t in types if sname.startswith(t)), sname)
        out.setdefault(family, {"type": types.get(family), "samples": {}})
        fval = float("inf") if val == "+Inf" else float(val)
        out[family]["samples"][(sname, frozenset(labels.items()))] = fval
    return out


def test_prometheus_text_roundtrip():
    reg = MetricsRegistry("t_prom")
    c = reg.counter("prom_rows_total", "rows ingested")
    c.inc(5, writer="3")
    c.inc(2.5, writer="7")
    g = reg.gauge("prom_fill", "memtable fill fraction")
    g.set(0.5)
    h = reg.histogram("prom_lat_seconds", "latency", edges=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0, 0.05):
        h.observe(v, op="scan")

    doc = _parse_prom(obs.to_prometheus_text(reg))

    assert doc["prom_rows_total"]["type"] == "counter"
    s = doc["prom_rows_total"]["samples"]
    assert s[("prom_rows_total", frozenset({("writer", "3")}))] == 5.0
    assert s[("prom_rows_total", frozenset({("writer", "7")}))] == 2.5

    assert doc["prom_fill"]["type"] == "gauge"
    assert doc["prom_fill"]["samples"][("prom_fill", frozenset())] == 0.5

    assert doc["prom_lat_seconds"]["type"] == "histogram"
    hs = doc["prom_lat_seconds"]["samples"]

    def bucket(le):
        return hs[("prom_lat_seconds_bucket", frozenset({("op", "scan"), ("le", le)}))]

    # Cumulative buckets, exact against the observations above.
    assert bucket("0.01") == 1
    assert bucket("0.1") == 3
    assert bucket("1") == 4
    assert bucket("+Inf") == 5
    assert hs[("prom_lat_seconds_count", frozenset({("op", "scan")}))] == 5
    assert hs[("prom_lat_seconds_sum", frozenset({("op", "scan")}))] == pytest.approx(
        5.605
    )


def test_prometheus_text_escaping_and_empty():
    reg = MetricsRegistry("t_prom_esc")
    assert obs.to_prometheus_text(reg) == ""
    c = reg.counter("esc_total", 'help with "quotes"')
    c.inc(1, path='a"b\\c')
    text = obs.to_prometheus_text(reg)
    assert '# HELP esc_total help with \\"quotes\\"' in text
    doc = _parse_prom(text)
    assert doc["esc_total"]["samples"][
        ("esc_total", frozenset({("path", 'a"b\\c')}))
    ] == 1.0


def test_prometheus_text_all_registries_dedupes_names():
    a = MetricsRegistry("t_prom_a")
    b = MetricsRegistry("t_prom_b")
    a.counter("dup_total").inc(1)
    b.counter("dup_total").inc(100)
    text = obs.to_prometheus_text()
    assert text.count("# TYPE dup_total counter") == 1


# ----------------------------------------------------------------- exporters
def test_write_exporters_roundtrip(tmp_path):
    obs.enable()
    obs.clear()
    try:
        with obs.span("io", cat="t"):
            pass
    finally:
        obs.disable()
    tpath = tmp_path / "trace.json"
    mpath = tmp_path / "metrics.json"
    obs.write_chrome_trace(str(tpath))
    obs.write_metrics_json(str(mpath))
    tdoc = json.loads(tpath.read_text())
    mdoc = json.loads(mpath.read_text())
    assert obs.validate_chrome_trace(tdoc) == []
    assert mdoc["schema_version"] == 1
    assert "lock_occupancy" in mdoc


# ----------------------------------------------------- Prometheus endpoint
def test_serve_prometheus_start_scrape_stop():
    """The pull endpoint serves the exposition text at /metrics on an
    ephemeral port, 404s other paths, and stops cleanly (twice over:
    explicit stop and context manager)."""
    from urllib.error import HTTPError
    from urllib.request import urlopen

    reg = MetricsRegistry("t_prom_http")
    reg.counter("scrapes_total", "scrapes").inc(3, path="/metrics")
    ep = obs.serve_prometheus(reg)
    try:
        assert ep.port > 0
        body = urlopen(ep.url, timeout=5).read().decode()
        assert body == obs.to_prometheus_text(reg)
        assert 'scrapes_total{path="/metrics"} 3' in body
        with pytest.raises(HTTPError) as exc:
            urlopen(f"http://{ep.host}:{ep.port}/other", timeout=5)
        assert exc.value.code == 404
    finally:
        ep.stop()
    with pytest.raises(OSError):
        urlopen(f"http://{ep.host}:{ep.port}/metrics", timeout=1)
    with obs.serve_prometheus(reg) as ep2:
        assert urlopen(ep2.url, timeout=5).status == 200


# ------------------------------------------------------- lock wait accounting
def test_owned_lock_books_acquire_wait():
    """total_wait_s/wait_by_owner_s accumulate the time a would-be holder
    spent inside acquire(): a sole acquirer books ~zero wait, a thread
    blocked behind a deliberate hold books at least the hold time."""
    lk = obs.OwnedLock("t_wait_lock")
    with lk.hold("solo"):
        pass
    solo = lk.snapshot()
    assert solo["total_wait_s"] < 0.05  # uncontended: microseconds
    hold_s = 0.15
    started = threading.Event()

    def holder():
        with lk.hold("hog"):
            started.set()
            time.sleep(hold_s)

    t = threading.Thread(target=holder)
    t.start()
    started.wait()
    with lk.hold("waiter"):
        pass
    t.join()
    snap = lk.snapshot()
    assert snap["wait_by_owner_s"]["waiter"] > hold_s / 2
    assert abs(
        sum(snap["wait_by_owner_s"].values()) - snap["total_wait_s"]
    ) < 1e-9
    # Merged report carries the same keys; reset clears them.
    merged = obs.occupancy_snapshot()["t_wait_lock"]
    assert merged["total_wait_s"] == snap["total_wait_s"]
    lk.reset()
    assert lk.snapshot()["total_wait_s"] == 0.0
