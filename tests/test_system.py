"""End-to-end system test: the full pipeline the paper describes, plus the
LM platform it feeds — staged files -> parallel ingest -> store -> planned
+ batched queries -> tokenized training batches -> a few train steps ->
checkpoint/restore -> serve."""
import numpy as np


def test_full_pipeline(tmp_path):
    import jax
    import jax.numpy as jnp

    from repro.checkpointing import CheckpointManager
    from repro.core import And, Eq, EventStore, QueryProcessor, web_proxy_schema
    from repro.models import get_config, init_params
    from repro.models.model import forward_train
    from repro.pipeline import IngestWorkerPool, SyntheticWebProxySource
    from repro.pipeline.tokenizer import EventTokenizer
    from repro.serving import ServeEngine
    from repro.training.optimizer import OptConfig, adamw_init, adamw_update

    # --- stage + ingest (paper §II) ---
    src = SyntheticWebProxySource(n_domains=200, seed=9)
    files = src.write_files(
        str(tmp_path / "staged"), n_files=4, lines_per_file=2000, t_start=0, t_stop=7200
    )
    store = EventStore(web_proxy_schema(), n_shards=4, flush_rows=4096)
    pool = IngestWorkerPool(store, n_workers=2)
    for f in files:
        pool.submit_file(f)
    pool.drain(timeout_s=180)
    assert store.total_rows == 8000

    # --- query (paper §III): planned + batched ---
    qp = QueryProcessor(store)
    popular = src.domain_by_popularity(0.0)
    tree = And(Eq("domain", popular), Eq("method", "GET"))
    rows = sum(b.n for b in qp.run_scheme("batched_index", 0, 7200, tree))
    assert rows > 0

    # --- events -> tokens -> train (the analytics LM) ---
    cfg = get_config("llcysa-analytics-100m", smoke=True)
    tok = EventTokenizer(store, vocab_size=cfg.vocab_size)
    it = tok.sequences(0, 7200, seq_len=64, batch=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = adamw_init(params, opt_cfg)

    @jax.jit
    def step(p, s, b):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: forward_train(pp, cfg, b, remat=False), has_aux=True
        )(p)
        p, s, _ = adamw_update(p, grads, s, opt_cfg)
        return p, s, loss

    losses = []
    for _ in range(4):
        toks = jnp.asarray(next(it))
        batch = {"inputs": toks, "targets": jnp.roll(toks, -1, 1)}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)

    # --- checkpoint / restore ---
    mgr = CheckpointManager(tmp_path / "ckpt", keep=2)
    mgr.save(4, params, blocking=True)
    step_found, restored = mgr.restore_latest(params)
    assert step_found == 4

    # --- serve the trained model with adaptive batching ---
    eng = ServeEngine(cfg, restored, max_batch=2, cache_len=48)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new_tokens=4)
    done = eng.run()
    assert len(done) == 3 and all(len(r.output) == 4 for r in done)
