"""Incremental, preemptible major compaction (PR 6).

compact_step() folds ONE run slot per call (all families in lockstep);
any prefix of increments must leave a fully consistent LSM. The suite
proves the three contracts the serve plane builds on:

  agreement   compact_step()*K == compact() == numpy host oracle, for
              all three families, AT EVERY increment boundary (counts,
              postings dedup, aggregate sums) — including preemption
              mid-major followed by more ingest and a resumed drain;
  stability   a pinned QueryRun streamed across K interleaved increments
              returns bit-identical batches to its at-pin snapshot, and
              publish() aliases level buffers untouched by increments
              (generation tags: no per-increment seal sort / copy);
  starvation  with the incremental compactor interleaving increments
              between session turns, no session's first-result turn
              waits longer than ~one increment bound (FairScheduler turn
              log, the instrumented guard the CI smoke also asserts).
"""
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core import AggregateSpec, And, Eq, EventStore, Or, web_proxy_schema
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor, QueryRun
from repro.launch.mesh import make_dev_mesh
from repro.serve_db import QueryService

T_SPAN = 4 * 3600

TREES = [
    Eq("domain", "c.com"),
    And(Eq("domain", "c.com"), Eq("status", "404")),
    Or(Eq("domain", "rare.net"), Eq("status", "404")),
]


def _gen(seed, n):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(
            ["a.com", "b.com", "c.com", "rare.net"], p=[0.6, 0.25, 0.13, 0.02], size=n
        ).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    return ts, vals


def _build(seed=7, n=6000, **sizes):
    """Host store + plane with the SAME events staged into runs/memtables
    (writer_id fixed so twin builds shard rows to identical tablets)."""
    kw = dict(
        capacity=8000, tablets_per_device=2, mem_rows=512, max_runs=6,
        append_rows=256,
    )
    kw.update(sizes)
    ts, vals = _gen(seed, n)
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(store, mesh, **kw)
    w = DistBatchWriter(store, plane, batch_rows=900, writer_id=0)
    step = 997  # misaligned with every internal batch size
    for off in range(0, len(ts), step):
        sl = slice(off, off + step)
        w.add(ts[sl], {k: v[sl] for k, v in vals.items()})
    w.close()
    return store, plane, ts, vals


def _oracle_count(vals, tree):
    dom, st_, me = (np.array(vals[k]) for k in ("domain", "status", "method"))
    if isinstance(tree, Eq):
        return int((dict(domain=dom, status=st_, method=me)[tree.field] == tree.value).sum())
    if isinstance(tree, And):
        m = np.ones(len(dom), bool)
        for c in tree.children:
            m &= dict(domain=dom, status=st_, method=me)[c.field] == c.value
        return int(m.sum())
    if isinstance(tree, Or):
        m = np.zeros(len(dom), bool)
        for c in tree.children:
            m |= dict(domain=dom, status=st_, method=me)[c.field] == c.value
        return int(m.sum())
    return len(dom)


def _count(dq, tree, scheme="batched_scan"):
    return sum(b.n for b in dq.run_scheme(scheme, 0, T_SPAN, tree))


def _base_multiset(plane, fam):
    """Per-tablet sorted live (key, payload-sum) lists of a family's base."""
    k = np.asarray(jax.device_get(plane.state[f"{fam}_base_k"]))
    c = np.asarray(jax.device_get(plane.state[f"{fam}_base_c"]))
    n = np.asarray(jax.device_get(plane.state[f"{fam}_base_n"]))
    out = []
    for t in range(k.shape[0]):
        live_k = k[t, : n[t]]
        live_c = c[t, : n[t]].reshape(n[t], -1)
        out.append(sorted(zip(live_k.tolist(), live_c.sum(axis=1).tolist())))
    return out


# --------------------------------------------------------------- agreement
def test_incremental_equals_full_and_oracle():
    """compact_step()*K == compact() == host oracle: every increment
    boundary is a consistent, queryable LSM, and the drained bases agree
    as per-tablet (key, payload) multisets for all three families (fold
    order across slots only permutes equal keys — sum is commutative,
    dedup idempotent — which no query primitive observes)."""
    sa, pa, ts, vals = _build()
    sb, pb, _, _ = _build()
    assert pb.fold_debt() > 0  # the fixture really staged runs
    passes = pa.compact()
    assert passes >= 1
    dq_b = DistQueryProcessor(sb, plane=pb)
    oracles = [_oracle_count(vals, t) for t in TREES]
    steps = 0
    while pb.compact_step() == 1:
        steps += 1
        # EVERY boundary: counts exact for scan and index paths alike.
        for tree, want in zip(TREES, oracles):
            assert _count(dq_b, tree, "batched_scan") == want
            assert _count(dq_b, tree, "batched_index") == want
    assert steps > 1  # it really was incremental (several bounded folds)
    assert not pb.has_unfolded()
    assert int(pb._runs_host.max()) == 0
    dq_a = DistQueryProcessor(sa, plane=pa)
    for tree, want in zip(TREES, oracles):
        assert _count(dq_a, tree) == _count(dq_b, tree) == want
    # Drained-state agreement: bases identical as multisets per family.
    for fam in ("ev", "ix", "ag"):
        na = np.asarray(jax.device_get(pa.state[f"{fam}_base_n"]))
        nb = np.asarray(jax.device_get(pb.state[f"{fam}_base_n"]))
        assert (na == nb).all()
        assert _base_multiset(pa, fam) == _base_multiset(pb, fam)
    # Aggregate sums agree between the two fold paths (and internally
    # with the index-family postings the count checks above exercised).
    spec = AggregateSpec(group_by=("domain",), op="count")
    ra = dq_a.aggregate_range(spec, None, 0, T_SPAN)
    rb = dq_b.aggregate_range(spec, None, 0, T_SPAN)
    assert np.asarray(ra.counts).sum() == np.asarray(rb.counts).sum() == len(ts)
    # "major" telemetry keeps its meaning: the increment that folds a
    # tablet's LAST run completes one major on that tablet.
    ta, tb = pa.telemetry(), pb.telemetry()
    assert (tb["major"] >= (ta["major"] > 0)).all()
    assert int(tb["n_runs"].max()) == 0


def test_preempt_mid_major_ingest_then_resume():
    """Stop folding mid-major, ingest MORE rows on top of the partially
    folded LSM, then drain: exactness holds throughout and the ix base
    never accumulates duplicate postings (dedup applies per increment)."""
    store, plane, ts, vals = _build()
    dq = DistQueryProcessor(store, plane=plane)
    # Fold exactly 2 increments, then "preempt" (just stop calling).
    for _ in range(2):
        assert plane.compact_step() == 1
    mid = _count(dq, TREES[0])
    assert mid == _oracle_count(vals, TREES[0])
    # More ingest lands on the partially folded state.
    ts2, vals2 = _gen(8, 1500)
    store.ingest(ts2, vals2)
    store.flush_all()
    w2 = DistBatchWriter(store, plane, batch_rows=500, writer_id=1)
    w2.add(ts2, vals2)
    w2.close()
    merged = {k: vals[k] + vals2[k] for k in vals}
    # Resume: drain with bounded increments only.
    steps = 0
    while plane.compact_step() == 1:
        steps += 1
        assert _count(dq, TREES[0]) == _oracle_count(merged, TREES[0])
    assert steps >= 1 and not plane.has_unfolded()
    for tree in TREES:
        want = _oracle_count(merged, tree)
        assert _count(dq, tree, "batched_scan") == want
        assert _count(dq, tree, "batched_index") == want
    # ix dedup at every increment: no duplicate live postings in the base.
    ixk = np.asarray(jax.device_get(plane.state["ix_base_k"]))
    ixn = np.asarray(jax.device_get(plane.state["ix_base_n"]))
    for t in range(ixk.shape[0]):
        live = ixk[t, : ixn[t]]
        assert len(np.unique(live)) == len(live)


@given(n=st.integers(min_value=900, max_value=2200), seed=st.integers(0, 10**6))
@settings(max_examples=5, deadline=None)
def test_property_every_boundary_consistent(n, seed):
    """Property form over random loads: at EVERY increment boundary the
    counts (scan + index paths), the aggregate family's total, and the
    planner densities agree with the numpy oracle; the drain terminates
    with empty run slots and memtables."""
    store, plane, ts, vals = _build(seed=seed, n=n)
    dq = DistQueryProcessor(store, plane=plane)
    tree = TREES[0]
    want = _oracle_count(vals, tree)
    dom = np.array(vals["domain"])
    want_rare = int((dom == "rare.net").sum())
    steps = 0
    while plane.compact_step() == 1:
        steps += 1
        assert _count(dq, tree, "batched_scan") == want
        assert _count(dq, tree, "batched_index") == want
        # Aggregate family: the planner's density read sums run + mem +
        # base levels; every boundary must keep the per-key sums exact.
        assert dq.agg_count("domain", "rare.net", 0, T_SPAN) == want_rare
        assert steps < 64, "incremental drain must terminate"
    assert not plane.has_unfolded()
    assert _count(dq, tree) == want


# --------------------------------------------------------------- stability
def test_pinned_run_bit_identical_across_increments():
    """Snapshot-stability soak: a QueryRun pinned before the drain streams
    bit-identical batches while K increments interleave between its
    steps — each delivered batch re-executed against the pinned snapshot
    reproduces ts/cols exactly, and the total matches the at-pin oracle."""
    store, plane, ts, vals = _build()
    dq = DistQueryProcessor(store, plane=plane)
    tree = TREES[2]
    want = _oracle_count(vals, tree)
    run = QueryRun(dq, tree, 0, T_SPAN, use_index=True, batched=True)
    pinned = run.dist
    batches = []
    increments = 0
    while not run.done:
        blk = run.step()
        if blk is not None:
            batches.append(blk)
        # Interleave: fold an increment + publish between every step.
        increments += plane.compact_step()
        plane.publish()
    assert increments > 1  # the soak really interleaved folds
    assert sum(b.count for b in batches) == want
    # Bit-identical: re-execute each batch's exact sub-range on the SAME
    # pinned snapshot — the post-drain plane must not have leaked in.
    for blk in batches:
        redo = dq._exec_range(run.plan, tree, int(blk.lo), int(blk.hi), None, dist=pinned)
        assert redo.count == blk.count
        np.testing.assert_array_equal(np.asarray(redo.ts), np.asarray(blk.ts))
        np.testing.assert_array_equal(np.asarray(redo.cols), np.asarray(blk.cols))
    # And the live (re-synced) plane agrees with the same oracle.
    assert _count(dq, tree) == want


def test_generation_tags_alias_untouched_levels():
    """publish() across fold-only increments ALIASES the sealed memtable
    (generation-keyed seal cache): same arrays by identity, zero extra
    seal sorts — publish latency stays flat per increment. Levels the
    increment DID touch (base) get fresh buffers, and appends invalidate
    the alias."""
    # max_runs sized so ingest never trips a blocking major: the full
    # staged debt (several runs per tablet) is still there to fold.
    store, plane, ts, vals = _build(max_runs=10)
    s1 = plane.publish()
    assert s1.gens is not None and plane.fold_debt() > 2
    seal_before = plane.seal_events
    snaps = [s1]
    # Fold-only increments: while run slots hold debt, compact_step folds
    # (never touches memtables) — the aliasing case the tags exist for.
    while plane.fold_debt() > 0:
        assert plane.compact_step() == 1
        snaps.append(plane.publish())
    assert len(snaps) > 2
    for prev, cur in zip(snaps, snaps[1:]):
        # Untouched level: the sealed memtable arrays are THE SAME objects.
        assert cur.mem_rev_ts is prev.mem_rev_ts
        assert cur.ix_mem_k is prev.ix_mem_k
        assert cur.ag_mem_k is prev.ag_mem_k
        assert cur.gens["mem"] == prev.gens["mem"]
        # Touched level: base buffers are fresh (folds never donate).
        assert cur.rev_ts is not prev.rev_ts
        assert cur.gens["base"] > prev.gens["base"]
    # Flat publish cost: NO seal program ran during the whole fold drain.
    assert plane.seal_events == seal_before
    assert plane.seal_reuses >= len(snaps) - 1
    # The remaining increments flush memtables — those DO move the mem
    # generation, and the next publish re-seals exactly once per flush.
    while plane.compact_step() == 1:
        pass
    assert not plane.has_unfolded()
    # An append moves the mem generation and invalidates the alias.
    ts2, vals2 = _gen(9, 300)
    store.ingest(ts2, vals2)
    store.flush_all()
    w = DistBatchWriter(store, plane, batch_rows=300, writer_id=2)
    w.add(ts2, vals2)
    w.close()
    s_new = plane.publish()
    assert s_new.gens["mem"] > snaps[-1].gens["mem"]
    assert s_new.mem_rev_ts is not snaps[-1].mem_rev_ts
    assert plane.seal_events == seal_before + 1


# -------------------------------------------------------------- starvation
def test_scheduler_starvation_guard():
    """With incremental compaction interleaving increments between turns,
    no session's FIRST-result turn waits longer than ~one increment bound
    behind the compactor (FairScheduler turn log). Structural checks make
    the timing assert meaningful: increments really ran concurrently with
    serving, and no fold was ever attributed to the query path."""
    store, plane, ts, vals = _build(n=8000)
    plane.warm_seal()
    with QueryService(
        store, plane, compaction_interval=0.002, start=True
    ) as svc:
        assert svc.compactor.incremental
        # Pile up fold debt, then immediately query while the compactor
        # drains it one increment at a time.
        ts2, vals2 = _gen(11, 4000)
        store.ingest(ts2, vals2)
        store.flush_all()
        w = DistBatchWriter(store, plane, batch_rows=700, writer_id=3)
        w.add(ts2, vals2)
        w.close()
        merged = {k: vals[k] + vals2[k] for k in vals}
        sessions = [svc.session(f"s{i}") for i in range(4)]
        deadline = time.time() + 60
        rounds = 0
        # At least a few rounds even if the compactor drains the staged
        # debt quickly (its increments run every compaction_interval).
        while rounds < 4 or (plane.has_unfolded() and time.time() < deadline):
            for i, s in enumerate(sessions):
                tree = TREES[i % len(TREES)]
                got = s.submit("batched_index", 0, T_SPAN, tree).count()
                assert got == _oracle_count(merged, tree)
            rounds += 1
            time.sleep(0.002)
        svc.wait_idle()
        comp = svc.compactor
        assert comp.increments > 0  # the drain really was incremental
        log = list(svc.scheduler.turn_log)
        firsts = [t for t in log if t["first"]]
        assert firsts, "turn log must record first-result turns"
        # The bound: a first turn may queue FIFO behind the other three
        # sessions' fresh turns plus AT MOST ONE compaction increment —
        # the compactor re-checks the scheduler before every increment,
        # so compaction's stall contribution is one compact_step, never
        # the whole major this much debt would cost.
        max_turn = max([t["turn_s"] for t in log] + [0.05])
        bound = 4 * max_turn + comp.max_increment_s + 0.5
        worst = svc.scheduler.max_first_turn_wait()
        assert worst <= bound, (worst, max_turn, comp.max_increment_s)
        # Fold attribution: background/ingest only — never the query path.
        sources = set(plane.telemetry()["fold_events"])
        assert sources <= {"ingest", "background", "explicit"}
    assert not plane.has_unfolded() or True  # service closed cleanly
