"""Launch-layer unit tests: cell planning (the 40-cell assignment
accounting), abstract input specs, and roofline report assembly."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.launch.dryrun import plan_cells
from repro.launch.roofline import roofline_fraction, table
from repro.launch.steps import batch_shapes, cache_shapes
from repro.models import get_config, list_archs


def test_plan_cells_accounting():
    """10 archs x 4 shapes = 40 assigned cells; long_500k runs only for the
    2 sub-quadratic archs (8 documented skips) -> 32 pairs x 2 meshes."""
    cells = plan_cells()
    assert len(cells) == 64
    pairs = {(a, s) for a, s, _ in cells}
    assert len(pairs) == 32
    long_cells = {a for a, s, _ in cells if s == "long_500k"}
    assert long_cells == {"mamba2-780m", "zamba2-2.7b"}
    assert {m for _, _, m in cells} == {"single_pod", "multi_pod"}


def test_batch_shapes_per_family():
    b = batch_shapes(get_config("gemma2-9b"), SHAPES["train_4k"])
    assert b["inputs"].shape == (256, 4096) and b["targets"].shape == (256, 4096)
    b = batch_shapes(get_config("musicgen-medium"), SHAPES["prefill_32k"])
    assert "inputs" not in b and b["embeds"].shape == (32, 32768, 1536)
    b = batch_shapes(get_config("llama-3.2-vision-11b"), SHAPES["decode_32k"])
    assert b["inputs"].shape == (128, 1)
    assert b["vision_states"].shape == (128, 1601, 4096)


def test_cache_shapes_windowed_and_ssm():
    c = cache_shapes(get_config("gemma2-9b"), SHAPES["decode_32k"])
    # pattern (local, global): local ring cache is window-sized.
    assert c[0]["k"].shape == (21, 128, 4096, 8, 256)
    assert c[1]["k"].shape == (21, 128, 32768, 8, 256)
    c = cache_shapes(get_config("mamba2-780m"), SHAPES["long_500k"])
    assert c[0]["state"].shape == (48, 1, 48, 128, 64)  # O(1) in seq_len
    c = cache_shapes(get_config("zamba2-2.7b"), SHAPES["long_500k"])
    assert c[5]["sa"]["k"].shape == (9, 1, 524288, 32, 80)


def _minimal_dryrun_record(arch: str, shape: str, mesh: str) -> dict:
    """Format-faithful stand-in for one dryrun.run_cell() artifact — the
    fields roofline.load/table/roofline_fraction actually read."""
    return {
        "arch": arch,
        "shape": shape,
        "mesh": mesh,
        "n_chips": 256,
        "memory": {"peak_bytes": 8 * 2**30},
        "cost": {"flops_per_device": 1.0e12, "xla_raw_flops": 1.2e12},
        "collectives": {"total_bytes": 1.0e9, "raw_bytes_loop_once": 1.0e9},
        "roofline": {
            "compute_s": 2.0e-3,
            "memory_s": 1.0e-3,
            "collective_s": 5.0e-4,
            "bottleneck": "compute_s",
        },
        "model_flops_per_device": 0.8e12,
        "useful_flop_ratio": 0.8,
    }


def test_roofline_report_reads_artifacts(tmp_path):
    """Report assembly over a generated minimal fixture (a fresh clone has
    no experiments/dryrun — the full sweep takes hours; the report code is
    what this covers, not the sweep)."""
    import json

    from repro.launch.roofline import load

    cells = plan_cells()
    for arch, shape, mesh in cells:
        rec = _minimal_dryrun_record(arch, shape, mesh)
        (tmp_path / f"{arch}__{shape}__{mesh}.json").write_text(json.dumps(rec))
    # FAIL-prefixed artifacts must be skipped by load().
    (tmp_path / "FAIL__x__y__z.json").write_text("{}")
    results = load(str(tmp_path))
    assert len(results) == len(cells) >= 60
    lines = table(results)
    assert any("gemma2-9b" in l for l in lines)
    rec = next(iter(results.values()))
    assert roofline_fraction(rec) is None or roofline_fraction(rec) >= 0


def test_roofline_report_real_artifacts_if_present():
    """When a real dry-run sweep has been recorded, the report must still
    assemble from it (guarded: fresh clones have no artifacts)."""
    import pytest

    from repro.launch.roofline import load

    results = load("experiments/dryrun")
    if not results:
        pytest.skip("no experiments/dryrun artifacts in this checkout")
    lines = table(results)
    assert len(lines) > 2
