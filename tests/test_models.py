"""Per-architecture smoke tests (reduced configs, CPU): one train step and
one prefill+decode step; output shapes, finite losses, dtype discipline
(x64 is on globally for the store — no f64 may leak into model HLO)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, init_params, list_archs
from repro.models.model import decode_step, forward_train, prefill

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, s=S, with_targets=True):
    batch = {}
    if cfg.embed_input:
        batch["inputs"] = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size).astype(jnp.int32)
    else:
        batch["embeds"] = jax.random.normal(KEY, (B, s, cfg.d_model), jnp.dtype(cfg.dtype))
    if with_targets:
        batch["targets"] = jax.random.randint(KEY, (B, s), 0, cfg.vocab_size).astype(jnp.int32)
    if cfg.family == "vlm":
        batch["vision_states"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return batch


@pytest.mark.parametrize("arch", list_archs(assigned_only=False))
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, cfg)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda pp: forward_train(pp, cfg, b), has_aux=True)(p)
    )(params, batch)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 2.0 * np.log(cfg.vocab_size)
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    assert any(g > 0 for g in gnorms)


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_prefill(arch):
    # capacity_factor high enough that no MoE token drops: capacity
    # overflow legitimately makes prefills of different lengths drop
    # different tokens (GShard semantics), which is not what this test
    # checks (cache/decode mechanics are).
    cfg = get_config(arch, smoke=True).replace(
        dtype="float32", ssm_chunk=8, capacity_factor=16.0
    )
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size).astype(jnp.int32)
    batch = make_batch(cfg, s=S + 1, with_targets=False)
    pre = dict(batch)
    stepb = dict(batch)
    full = dict(batch)
    if cfg.embed_input:
        pre["inputs"], stepb["inputs"], full["inputs"] = toks[:, :S], toks[:, S:], toks
    else:
        emb = batch["embeds"]
        pre["embeds"], stepb["embeds"], full["embeds"] = emb[:, :S], emb[:, S:], emb
    _, caches, _ = jax.jit(lambda p, b: prefill(p, cfg, b, cache_len=S + 1))(params, pre)
    logits_d, _ = jax.jit(lambda p, b, c, cp: decode_step(p, cfg, b, c, cp))(
        params, stepb, caches, jnp.full((B,), S, jnp.int32)
    )
    logits_f, _, _ = jax.jit(lambda p, b: prefill(p, cfg, b))(params, full)
    err = float(jnp.max(jnp.abs(logits_d - logits_f)))
    assert err < 2e-3, f"{arch}: decode-vs-prefill err {err}"


def test_no_f64_in_model_hlo():
    """x64 is enabled globally for the store's packed keys; the model HLO
    must still be f64-free (dtype discipline)."""
    cfg = get_config("gemma2-9b", smoke=True)
    pshapes = jax.eval_shape(lambda: init_params(KEY, cfg))
    batch = {
        "inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    txt = jax.jit(lambda p, b: forward_train(p, cfg, b)).lower(pshapes, batch).as_text()
    assert "f64[" not in txt


def test_param_count_analytic_matches_init():
    for arch in list_archs():
        cfg = get_config(arch, smoke=True)
        params = init_params(KEY, cfg)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.02, (arch, actual, analytic)


def test_full_configs_match_assignment():
    """The exact published dims from the assignment table."""
    spec = {
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab_size == v, arch
    assert get_config("moonshot-v1-16b-a3b").n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").top_k == 6
    assert get_config("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert get_config("phi3.5-moe-42b-a6.6b").top_k == 2
    assert get_config("zamba2-2.7b").ssm_state == 64
    assert get_config("mamba2-780m").ssm_state == 128


def test_long_500k_eligibility():
    eligible = {a for a in list_archs() if get_config(a).sub_quadratic}
    assert eligible == {"mamba2-780m", "zamba2-2.7b"}


def test_sliding_window_masks_differ():
    """Local vs global attention must actually differ beyond the window."""
    from repro.models.attention import flash_attention

    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (1, 64, 2, 16), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 2, 16), jnp.float32)
    full = flash_attention(q, kk, v, causal=True)
    local = flash_attention(q, kk, v, causal=True, window=8)
    assert float(jnp.max(jnp.abs(full[:, :8] - local[:, :8]))) < 1e-5
    assert float(jnp.max(jnp.abs(full[:, 32:] - local[:, 32:]))) > 1e-4


def test_flash_attention_vs_naive():
    """Blocked online-softmax == naive attention, incl. GQA + softcap."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    b, s, h, kv, d = 2, 96, 8, 4, 32
    q = jax.random.normal(k1, (b, s, h, d), jnp.float32)
    kk = jax.random.normal(k2, (b, s, kv, d), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, d), jnp.float32)
    from repro.models.attention import flash_attention

    got = flash_attention(q, kk, v, causal=True, softcap_val=20.0, q_chunk=32, kv_block=32)
    # naive
    g = h // kv
    qf = q.reshape(b, s, kv, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kk) / np.sqrt(d)
    logits = jnp.tanh(logits / 20.0) * 20.0
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
