"""Distributed ingest plane: merge kernel oracles, host-vs-device
exact-agreement, live incremental visibility, and backpressure telemetry."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AggregateSpec, And, Eq, EventStore, Not, Or, web_proxy_schema
from repro.core.dist_ingest import (
    DistBatchWriter,
    DistIngestPlane,
    check_tablet_guidance,
)
from repro.core.dist_query import DistQueryProcessor, from_event_store
from repro.core.ingest import IngestMetrics
from repro.core.query import QueryProcessor
from repro.kernels.common import split_key_lanes
from repro.kernels.merge_runs import (
    merge_ranks_pallas,
    merge_ranks_ref,
    merge_sorted_device,
    merge_sorted_runs,
)
from repro.launch.mesh import make_dev_mesh

import jax
import jax.numpy as jnp

INT32_MAX = np.iinfo(np.int32).max


# ------------------------------------------------------- merge_runs kernel
def _random_runs(rng, k, max_n, key_bits=53, dup_frac=0.3):
    """Sorted int64 runs with forced intra- and inter-run duplicates."""
    runs = []
    shared = rng.integers(0, 1 << key_bits, size=max(max_n // 4, 1))
    for _ in range(k):
        n = int(rng.integers(0, max_n + 1))
        fresh = rng.integers(0, 1 << key_bits, size=n)
        take_shared = rng.random(n) < dup_frac
        keys = np.where(take_shared, rng.choice(shared, size=n) if n else fresh, fresh)
        keys = np.sort(keys.astype(np.int64))
        cols = rng.integers(0, 1000, size=(n, 3)).astype(np.int32)
        runs.append((keys, cols))
    return runs


@given(k=st.integers(2, 6), max_n=st.integers(1, 800), seed=st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_merge_sorted_runs_vs_numpy(k, max_n, seed):
    """Host merge == concat + stable argsort (the placeholder it retires),
    including duplicate keys and empty runs."""
    rng = np.random.default_rng(seed)
    runs = _random_runs(rng, k, max_n)
    mk, mc = merge_sorted_runs(runs)
    all_k = np.concatenate([kk for kk, _ in runs]) if runs else np.empty(0, np.int64)
    all_c = np.concatenate([cc for _, cc in runs]) if runs else np.empty((0, 3), np.int32)
    order = np.argsort(all_k, kind="stable")
    np.testing.assert_array_equal(mk, all_k[order])
    np.testing.assert_array_equal(mc, all_c[order])


@given(k=st.integers(2, 5), r_log=st.integers(1, 9), seed=st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_merge_ranks_pallas_vs_ref(k, r_log, seed):
    """Pallas rank kernel == jnp searchsorted reference on sentinel-padded
    lanes — ranks must be a permutation even with heavy key duplication."""
    rng = np.random.default_rng(seed)
    r = 1 << r_log
    keys = np.full((k, r), np.iinfo(np.int64).max, np.int64)
    for i in range(k):
        n = int(rng.integers(0, r + 1))
        keys[i, :n] = np.sort(rng.integers(0, 50, size=n).astype(np.int64))  # dense dups
    hi, lo = split_key_lanes(keys.reshape(-1))
    hi, lo = hi.reshape(k, r), lo.reshape(k, r)
    got = np.asarray(merge_ranks_pallas(jnp.asarray(hi), jnp.asarray(lo), interpret=True, block=min(64, r)))
    want = np.asarray(merge_ranks_ref(hi, lo))
    np.testing.assert_array_equal(got, want)
    assert sorted(got.reshape(-1).tolist()) == list(range(k * r))


def test_merge_sorted_device_pad_sentinels():
    """Device merge: sentinel padding stays a contiguous tail and payload
    columns travel with their keys."""
    rng = np.random.default_rng(5)
    k, r, f = 3, 64, 2
    keys = np.full((k, r), INT32_MAX, np.int32)
    cols = np.zeros((k, r, f), np.int32)
    ns = [40, 0, 64]  # one empty run, one exactly full
    for i, n in enumerate(ns):
        keys[i, :n] = np.sort(rng.integers(0, 20, size=n).astype(np.int32))
        cols[i, :n] = rng.integers(1, 100, size=(n, f))
    mk, mc = merge_sorted_device(jnp.asarray(keys), jnp.asarray(cols))
    mk, mc = np.asarray(mk), np.asarray(mc)
    n_tot = sum(ns)
    real_k = np.concatenate([keys[i, : ns[i]] for i in range(k)])
    real_c = np.concatenate([cols[i, : ns[i]] for i in range(k)])
    order = np.argsort(real_k, kind="stable")
    np.testing.assert_array_equal(mk[:n_tot], real_k[order])
    np.testing.assert_array_equal(mc[:n_tot], real_c[order])
    assert (mk[n_tot:] == INT32_MAX).all()


def test_tablet_major_compaction_uses_merge(monkeypatch):
    """Host Tablet major compaction goes through the merge kernel path and
    preserves scan results."""
    from repro.core.tables import Tablet

    t = Tablet(0, width=2, flush_rows=64, max_runs=2)
    rng = np.random.default_rng(9)
    all_k, all_c = [], []
    for _ in range(6):
        keys = np.sort(rng.integers(0, 10_000, size=64).astype(np.int64))
        cols = rng.integers(0, 50, size=(64, 2)).astype(np.int32)
        t.insert(keys, cols)
        all_k.append(keys)
        all_c.append(cols)
    t.compact()
    assert len(t.runs) == 1 and t.major_compactions >= 1
    got_k, got_c = t.scan_range(0, 10_001)
    flat_k = np.concatenate(all_k)
    flat_c = np.concatenate(all_c)
    order = np.argsort(flat_k, kind="stable")
    np.testing.assert_array_equal(got_k, flat_k[order])
    # Duplicate keys may reorder their payload between insertion batches;
    # compare as multisets per key.
    assert sorted(map(tuple, got_c)) == sorted(map(tuple, flat_c[order]))


# ------------------------------------------------- host-vs-device agreement
N_EVENTS = 12_000
T_SPAN = 4 * 3600


def _gen(seed=3, n=N_EVENTS):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(["a.com", "b.com", "c.com"], p=[0.6, 0.3, 0.1], size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n).tolist(),
        "bytes_out": rng.integers(10, 5000, size=n).astype(str).tolist(),
    }
    return ts, vals


@pytest.fixture(scope="module")
def ingested():
    """The same events through BOTH planes: host EventStore ingest and
    DistBatchWriter -> device tablets (2 tablets on the 1-device mesh)."""
    ts, vals = _gen()
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(
        mesh, store.schema.n_fields, capacity=N_EVENTS + 1024,
        tablets_per_device=2, mem_rows=2048, max_runs=3, append_rows=512,
    )
    w = DistBatchWriter(store, plane, batch_rows=1500)
    step = 997  # deliberately misaligned with every internal batch size
    for off in range(0, len(ts), step):
        sl = slice(off, off + step)
        w.add(ts[sl], {k: v[sl] for k, v in vals.items()})
    w.close()
    dq = DistQueryProcessor(store, plane=plane)
    return store, plane, dq, ts, {k: np.array(v) for k, v in vals.items()}


TREES = [
    (Eq("domain", "c.com"), lambda v: v["domain"] == "c.com"),
    (
        And(Eq("domain", "b.com"), Not(Eq("method", "POST"))),
        lambda v: (v["domain"] == "b.com") & (v["method"] != "POST"),
    ),
    (
        Or(Eq("status", "404"), Eq("domain", "c.com")),
        lambda v: (v["status"] == "404") | (v["domain"] == "c.com"),
    ),
]


@pytest.mark.parametrize("tree,mask_fn", TREES)
@pytest.mark.parametrize("t_range", [(0, T_SPAN), (1800, 5400)])
def test_device_ingest_count_matches_host(ingested, tree, mask_fn, t_range):
    _, _, dq, ts, vals = ingested
    t0, t1 = t_range
    count, top_ts, _ = dq.scan_range(tree, t0, t1)
    expect = int((mask_fn(vals) & (ts >= t0) & (ts <= t1)).sum())
    assert count == expect
    assert (top_ts >= t0).all() and (top_ts <= t1).all()


@pytest.mark.parametrize(
    "spec",
    [
        AggregateSpec(group_by=("status",), time_bucket_s=3600),
        AggregateSpec(group_by=("domain", "method")),
        AggregateSpec(group_by=("domain",), op="sum", value_field="bytes_out"),
        AggregateSpec(group_by=("status",), op="max", value_field="bytes_out"),
    ],
)
def test_device_ingest_aggregate_matches_host(ingested, spec):
    """Exact-agreement oracle: same events in -> identical aggregates out
    of the host iterator stack and the device plane."""
    store, _, dq, _, _ = ingested
    tree = Eq("domain", "a.com")
    host = QueryProcessor(store).aggregate(spec, 0, T_SPAN, tree)
    dist = dq.aggregate_range(spec, tree, 0, T_SPAN)

    def as_map(res):
        return {
            tuple(sorted((k, v) for k, v in r.items() if k not in ("value", "count"))): (
                r["value"], r["count"],
            )
            for r in res.rows(store)
        }

    assert as_map(host) == as_map(dist)


def test_live_incremental_visibility(ingested):
    """Rows written after the first publish become visible on the next
    query with no re-scatter (the DistStore incremental-update path)."""
    store, plane, dq, ts, vals = ingested
    tree = Eq("domain", "c.com")
    before, _, _ = dq.scan_range(tree, 0, T_SPAN)
    extra_ts = np.array([100, 200, 300])
    w = DistBatchWriter(store, plane, batch_rows=2)
    w.add(extra_ts, {"domain": ["c.com"] * 3, "method": ["GET"] * 3, "status": ["200"] * 3})
    w.close()
    after, _, _ = dq.scan_range(tree, 0, T_SPAN)
    assert after == before + 3
    # Re-publish with nothing new is a no-op (cached store view).
    assert plane.publish() is plane.publish()


def test_from_event_store_replay_matches_scatter_semantics():
    """from_event_store (now a bulk replay through the plane) yields the
    same query results as the host store, at several tablet widths."""
    ts, vals = _gen(seed=11, n=6000)
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    mesh = make_dev_mesh(1, 1)
    varr = {k: np.array(v) for k, v in vals.items()}
    expect = int((varr["domain"] == "b.com").sum())
    for tpd in (1, 3):
        dist = from_event_store(store, mesh, tablets_per_device=tpd)
        assert dist.n_tablets == tpd
        dq = DistQueryProcessor(store, dist)
        count, _, _ = dq.scan_range(Eq("domain", "b.com"), 0, T_SPAN)
        assert count == expect


# ----------------------------------------------------------- backpressure
def test_backpressure_counters_monotonic():
    """Device compaction counters and rows are monotone non-decreasing
    across flushes; blocked time only accrues when majors run."""
    ts, vals = _gen(seed=17, n=8000)
    store = EventStore(web_proxy_schema(), n_shards=4)
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(
        mesh, store.schema.n_fields, capacity=10_000,
        tablets_per_device=2, mem_rows=512, max_runs=2, append_rows=256,
    )
    m = IngestMetrics()
    w = DistBatchWriter(store, plane, batch_rows=400, metrics=m)
    prev = None
    for off in range(0, len(ts), 400):
        sl = slice(off, off + 400)
        w.add(ts[sl], {k: v[sl] for k, v in vals.items()})
        tel = plane.telemetry()
        cur = (
            int(tel["rows"].sum()), int(tel["minor"].sum()),
            int(tel["major"].sum()), float(tel["blocked_seconds"]),
        )
        if prev is not None:
            assert all(a >= b for a, b in zip(cur, prev)), (cur, prev)
        prev = cur
    w.close()
    tel = plane.telemetry()
    assert int(tel["rows"].sum()) == len(ts)
    assert int(tel["overflow"].sum()) == 0
    # Tiny memtables + tiny max_runs: majors must have fired and blocked.
    assert int(tel["major"].sum()) >= 1
    assert m.blocked_seconds > 0
    assert m.rows == len(ts)


def test_tablet_guidance():
    assert check_tablet_guidance(4, 8)
    assert not check_tablet_guidance(3, 8)


def test_concurrent_writers_threaded():
    """Several DistBatchWriters flushing from real threads: the plane lock
    must keep every row accounted and the memtables consistent."""
    import threading

    ts, vals = _gen(seed=31, n=6000)
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(
        mesh, store.schema.n_fields, capacity=8000,
        tablets_per_device=2, mem_rows=512, max_runs=2, append_rows=256,
    )
    n_w = 3
    per = len(ts) // n_w

    def work(i):
        w = DistBatchWriter(store, plane, batch_rows=333, writer_id=i)
        sl = slice(i * per, (i + 1) * per)
        for off in range(0, per, 333):
            s2 = slice(sl.start + off, min(sl.start + off + 333, sl.stop))
            w.add(ts[s2], {k: v[s2] for k, v in vals.items()})
        w.close()

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_w)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tel = plane.telemetry()
    assert int(tel["rows"].sum()) == n_w * per
    assert int(tel["overflow"].sum()) == 0
    dq = DistQueryProcessor(store, plane=plane)
    total, _, _ = dq.scan_range(None, 0, T_SPAN)
    assert total == n_w * per


def test_large_value_sum_agreement():
    """Sums of large numeric values must not wrap int32 anywhere: host
    iterator stack, combine_scan backends, and the device plane agree."""
    rng = np.random.default_rng(41)
    n = 3000
    ts = np.sort(rng.integers(0, 3600, n))
    vals = {
        "domain": ["a.com"] * n,
        "method": ["GET"] * n,
        "status": ["200"] * n,
        # ~2e9 per row: three rows already exceed int32.
        "bytes_out": rng.integers(1_900_000_000, 2_000_000_000, size=n).astype(str).tolist(),
    }
    store = EventStore(web_proxy_schema(), n_shards=2)
    store.ingest(ts, vals)
    store.flush_all()
    spec = AggregateSpec(group_by=("domain",), op="sum", value_field="bytes_out")
    host = QueryProcessor(store).aggregate(spec, 0, 3600 * 2)
    [row] = host.rows(store)
    assert row["value"] > np.iinfo(np.int32).max  # really exercised the widening
    mesh = make_dev_mesh(1, 1)
    dist = from_event_store(store, mesh)
    d = DistQueryProcessor(store, dist).aggregate_range(spec, None, 0, 3600 * 2)
    [drow] = d.rows(store)
    assert drow["value"] == row["value"] and drow["count"] == row["count"]


def test_writer_rejects_out_of_range_timestamps():
    """Same 30-bit contract as EventStore.ingest_encoded — raw unix-epoch
    seconds must fail loudly, not wrap into negative rev_ts."""
    from repro.core import keypack

    store = EventStore(web_proxy_schema(), n_shards=1)
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(mesh, store.schema.n_fields, capacity=64)
    w = DistBatchWriter(store, plane, batch_rows=1)
    with pytest.raises(ValueError, match="30-bit"):
        w.add(
            np.array([keypack.TS_MAX + 1]),
            {"domain": ["a.com"], "method": ["GET"], "status": ["200"]},
        )


def test_from_event_store_undersized_capacity_raises():
    """Explicit undersized capacity must fail loudly (the pre-plane
    scatter's contract), not silently drop rows into the overflow counter."""
    ts, vals = _gen(seed=23, n=2000)
    store = EventStore(web_proxy_schema(), n_shards=2)
    store.ingest(ts, vals)
    store.flush_all()
    mesh = make_dev_mesh(1, 1)
    with pytest.raises(ValueError, match="overflow"):
        from_event_store(store, mesh, capacity=500)


def test_published_store_survives_later_compactions():
    """A published DistStore view must stay valid (buffers not donated)
    after further ingest trips minor/major compactions."""
    ts, vals = _gen(seed=29, n=4000)
    store = EventStore(web_proxy_schema(), n_shards=2)
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane(
        mesh, store.schema.n_fields, capacity=10_000, mem_rows=512, max_runs=2, append_rows=256,
    )
    w = DistBatchWriter(store, plane, batch_rows=500)
    w.add(ts[:2000], {k: v[:2000] for k, v in vals.items()})
    w.close()
    ds = plane.publish()
    counts_before = np.asarray(jax.device_get(ds.counts)).copy()
    w.add(ts[2000:], {k: v[2000:] for k, v in vals.items()})
    w.close()
    plane.publish()
    # The old view still reads, and still shows the old counts.
    np.testing.assert_array_equal(np.asarray(jax.device_get(ds.counts)), counts_before)
