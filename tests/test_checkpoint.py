"""Checkpoint/restart fault tolerance: bitwise resume, crash-mid-write
recovery, keep-K GC, async ordering."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.checkpointing.checkpoint import gc_checkpoints, list_checkpoints


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16), "d": jnp.asarray(3, jnp.int32)},
    }


def test_save_restore_bitwise(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 7, t)
    step, got = restore_checkpoint(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_restore_latest_of_many(tmp_path):
    t = tree()
    for s in (1, 5, 3):
        save_checkpoint(tmp_path, s, t)
    step, _ = restore_checkpoint(tmp_path, t)
    assert step == 5


def test_crash_mid_write_ignored(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 1, t)
    # Simulate a crashed writer: orphaned tmp dir without manifest rename.
    fake = tmp_path / "step_00000002.tmp-999-123"
    fake.mkdir()
    (fake / "arr_00000.npy").write_bytes(b"junk")
    step, _ = restore_checkpoint(tmp_path, t)
    assert step == 1  # tmp dir invisible to restore
    gc_checkpoints(tmp_path, keep=3)
    assert not fake.exists()  # swept


def test_keep_k_gc(tmp_path):
    t = tree()
    for s in range(6):
        save_checkpoint(tmp_path, s, t)
    gc_checkpoints(tmp_path, keep=2)
    steps = [s for s, _ in list_checkpoints(tmp_path)]
    assert steps == [4, 5]


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 0, tree())
    bad = tree()
    bad["a"] = jnp.zeros((5, 5), jnp.float32)
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, bad)


def test_async_manager_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = tree()
    for s in (10, 20, 30):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x + s, t))
    mgr.wait()
    assert mgr.latest_step() == 30
    step, got = mgr.restore_latest(t)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]) + 30)
    assert len(list_checkpoints(tmp_path)) == 2  # keep-K applied


def test_resume_training_bitwise(tmp_path):
    """Interrupt-and-resume yields bitwise-identical params vs uninterrupted
    (determinism of the train step + checkpoint fidelity)."""
    from repro.models import get_config, init_params
    from repro.models.model import forward_train
    from repro.training.optimizer import OptConfig, adamw_init, adamw_update

    cfg = get_config("llcysa-analytics-100m", smoke=True).replace(vocab_size=128)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 32)), jnp.int32)
    batch = {"inputs": toks, "targets": jnp.roll(toks, -1, 1)}

    @jax.jit
    def step(params, state):
        (loss, _), grads = jax.value_and_grad(
            lambda p: forward_train(p, cfg, batch, remat=False), has_aux=True
        )(params)
        params, state, _ = adamw_update(params, grads, state, opt_cfg)
        return params, state

    p0 = init_params(jax.random.PRNGKey(0), cfg)
    s0 = adamw_init(p0, opt_cfg)

    # Uninterrupted: 6 steps.
    p, s = p0, s0
    for _ in range(6):
        p, s = step(p, s)
    ref = p

    # Interrupted at 3: checkpoint, "crash", restore, continue.
    p, s = p0, s0
    for _ in range(3):
        p, s = step(p, s)
    save_checkpoint(tmp_path / "p", 3, p)
    save_checkpoint(tmp_path / "s", 3, s)
    _, p = restore_checkpoint(tmp_path / "p", p0)
    _, s = restore_checkpoint(tmp_path / "s", s0)
    for _ in range(3):
        p, s = step(p, s)
    for a, b in zip(jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
