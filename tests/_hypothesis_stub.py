"""Minimal, dependency-free stand-in for `hypothesis`.

The suite's property tests are written against the real hypothesis API
(`pip install -e .[test]` pulls it in — see pyproject.toml). Some execution
environments are hermetic: no network, no hypothesis wheel. Rather than
skip every property test there, conftest.py installs this shim into
`sys.modules['hypothesis']` when the real package is absent.

It implements exactly the API surface the suite uses — `given`, `settings`,
and the strategies `integers / floats / booleans / sampled_from / lists /
tuples / data` — as a deterministic random sweep: each decorated test runs
`max_examples` times with values drawn from a per-test seeded numpy
Generator. No shrinking, no database, no coverage-guided search; it is a
fuzz harness, not a replacement. The draw distributions are uniform, which
matches how the suite uses hypothesis (range/shape sweeps, not adversarial
edge-case mining).
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

__version__ = "0.0-stub"

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """Base class: a strategy is anything with draw(rng) -> value."""

    def draw(self, rng: np.random.Generator):  # pragma: no cover - abstract
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(1 << 16) if min_value is None else int(min_value)
        self.hi = (1 << 16) if max_value is None else int(max_value)

    def draw(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, min_value=None, max_value=None, **_kw):
        self.lo = -1e6 if min_value is None else float(min_value)
        self.hi = 1e6 if max_value is None else float(max_value)

    def draw(self, rng):
        return float(rng.uniform(self.lo, self.hi))


class _Booleans(_Strategy):
    def draw(self, rng):
        return bool(rng.integers(0, 2))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def draw(self, rng):
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=None, **_kw):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = self.min_size + 10 if max_size is None else int(max_size)

    def draw(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.draw(rng) for _ in range(n)]


class _Tuples(_Strategy):
    def __init__(self, *elements):
        self.elements = elements

    def draw(self, rng):
        return tuple(e.draw(rng) for e in self.elements)


class _DataObject:
    """Interactive draw handle (`@given(st.data())` style)."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.draw(self._rng)


class _DataStrategy(_Strategy):
    def draw(self, rng):
        return _DataObject(rng)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator: records max_examples for the given() runner to pick up."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*pos_strategies, **kw_strategies):
    """Decorator: run the test max_examples times with drawn arguments.

    Positional strategies bind (like hypothesis) to the test's rightmost
    parameters; keyword strategies bind by name. Bound parameters are
    removed from the wrapper's visible signature so pytest still injects
    the remaining ones as fixtures.
    """

    def deco(fn):
        max_examples = getattr(fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        bound_names = set(kw_strategies)
        n_pos = len(pos_strategies)
        remaining = [p for p in params if p.name not in bound_names]
        pos_names = [p.name for p in remaining[len(remaining) - n_pos :]] if n_pos else []
        fixture_params = [
            p for p in remaining if p.name not in pos_names
        ]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for _ in range(max_examples):
                drawn = {name: s.draw(rng) for name, s in kw_strategies.items()}
                for name, s in zip(pos_names, pos_strategies):
                    drawn[name] = s.draw(rng)
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        # pytest introspects __wrapped__ first; it must not resurrect the
        # strategy-bound parameters as fixtures.
        del wrapper.__wrapped__
        return wrapper

    return deco


def assume(condition) -> bool:
    """Degraded assume: a failed assumption just skips nothing (the sweep
    is random, not guided); returns the condition for manual guarding."""
    return bool(condition)


class HealthCheck:
    all = ()
    function_scoped_fixture = "function_scoped_fixture"
    too_slow = "too_slow"


def _strategies_module() -> types.ModuleType:
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _Integers
    st.floats = _Floats
    st.booleans = _Booleans
    st.sampled_from = _SampledFrom
    st.lists = _Lists
    st.tuples = _Tuples
    st.data = _DataStrategy
    return st


def install() -> None:
    """Register this shim as `hypothesis` (+ `hypothesis.strategies`)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__version__ = __version__
    mod.strategies = _strategies_module()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
