"""MoE dispatch correctness: capacity semantics, gate normalization, and a
loop-based oracle for the dense path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import activation
from repro.models.moe import capacity_for, init_moe_params, moe_ffn


def oracle_moe(params, x, top_k, act):
    """Unlimited-capacity loop oracle."""
    b, s, d = x.shape
    xf = np.asarray(x.reshape(-1, d), np.float32)
    router = np.asarray(params["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(xf @ router), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = np.asarray(gates / gates.sum(-1, keepdims=True))
    idx = np.asarray(idx)
    wg = np.asarray(params["wi_gate"], np.float32)
    wu = np.asarray(params["wi_up"], np.float32)
    wo = np.asarray(params["wo"], np.float32)
    y = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(top_k):
            e = idx[t, j]
            g = np.asarray(activation(jnp.asarray(xf[t] @ wg[e]), "silu"))
            h = g * (xf[t] @ wu[e])
            y[t] += gates[t, j] * (h @ wo[e])
    return y.reshape(b, s, d)


def test_moe_matches_oracle_with_ample_capacity():
    key = jax.random.PRNGKey(0)
    d, ff, e, k = 16, 32, 4, 2
    params = init_moe_params(key, d, ff, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    y, aux = moe_ffn(params, x, top_k=k, capacity_factor=64.0, act="silu")
    want = oracle_moe(params, x, k, "silu")
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_capacity_is_mxu_aligned_and_scales():
    assert capacity_for(4096, 8, 2, 1.25) % 128 == 0
    assert capacity_for(4096, 8, 2, 1.25) >= 4096 * 2 * 1.25 / 8
    # Decode-sized token counts scale the floor down (sublane-aligned).
    assert capacity_for(16, 64, 2, 1.0) == 8
    assert capacity_for(128, 16, 2, 1.25) % 8 == 0


def test_capacity_drops_overflow_tokens():
    """With capacity 128 and all tokens routed to one expert, outputs
    beyond the capacity must be zero (dropped), not garbage."""
    key = jax.random.PRNGKey(2)
    d, ff, e = 8, 16, 2
    params = init_moe_params(key, d, ff, e, jnp.float32)
    # Bias the router so everything goes to expert 0 with top_k=1:
    # strictly positive inputs x with router column 0 = 1, column 1 = 0.
    router = np.zeros((d, e), np.float32)
    router[:, 0] = 1.0
    params["router"] = jnp.asarray(router)
    n = 400  # far above the tiny-cf capacity
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (1, n, d), jnp.float32)) + 0.1
    y, _ = moe_ffn(params, x, top_k=1, capacity_factor=0.01, act="silu")
    cap = capacity_for(n, 2, 1, 0.01)
    served = (np.abs(np.asarray(y[0])).sum(-1) > 1e-9).sum()
    assert served == cap, (served, cap)


def test_moe_grads_finite():
    key = jax.random.PRNGKey(4)
    d, ff, e, k = 16, 32, 8, 2
    params = init_moe_params(key, d, ff, e, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, d), jnp.float32)

    def loss(p, x):
        y, aux = moe_ffn(p, x, top_k=k, capacity_factor=1.25, act="silu")
        return (y**2).sum() + 0.01 * aux

    g = jax.grad(loss)(params, x)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
