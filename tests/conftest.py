# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (and the subprocess spawned
# by test_distributed.py) force placeholder device counts.
import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis when installed (`pip install -e .[test]`);
# hermetic environments without it fall back to a deterministic random-sweep
# shim with the same API so the suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub", os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    _stub.install()
