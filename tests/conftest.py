# NOTE: no XLA_FLAGS here by design — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (and the subprocess spawned
# by test_distributed.py) force placeholder device counts.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
