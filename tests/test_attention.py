"""Attention primitives: flash vs naive (values + grads), decode masks,
ring-buffer slot maps, q_offset continuation."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import (
    decode_attention,
    flash_attention,
    ring_slot_positions,
)


def naive(q, k, v, causal=True, window=None, softcap=None, scale=None, q_offset=0):
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    t = k.shape[1]
    scale = scale or 1.0 / np.sqrt(d)
    qf = q.reshape(b, s, kh, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    qi = q_offset + jnp.arange(s)[:, None]
    kj = jnp.arange(t)[None, :]
    m = jnp.ones((s, t), bool)
    if causal:
        m &= kj <= qi
    if window:
        m &= kj > qi - window
    logits = jnp.where(m[None, None, None], logits, -2e30)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(b, s, h, d)


@given(
    s=st.integers(3, 120),
    h_and_kv=st.sampled_from([(1, 1), (4, 4), (4, 2), (8, 2)]),
    causal=st.booleans(),
    window=st.sampled_from([None, 4, 16]),
    cap=st.sampled_from([None, 20.0]),
    qc=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
def test_flash_matches_naive_sweep(s, h_and_kv, causal, window, cap, qc, seed):
    h, kv = h_and_kv
    if window is not None and not causal:
        window = None  # windowed non-causal not a used configuration
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, s, h, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, s, kv, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, s, kv, 16), jnp.float32)
    got = flash_attention(
        q, k, v, causal=causal, window=window, softcap_val=cap, q_chunk=qc, kv_block=16
    )
    want = naive(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-5)


def test_flash_grads_match_naive():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 48, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 48, 2, 16), jnp.float32)
    w = jax.random.normal(ks[0], (2, 48, 4, 16), jnp.float32)  # cotangent-ish

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, causal=True, window=8, softcap_val=30.0,
                                q_chunk=16, kv_block=16) * w).sum()

    def f_naive(q, k, v):
        return (naive(q, k, v, causal=True, window=8, softcap=30.0) * w).sum()

    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_q_offset_continuation():
    """Chunked prefill: attending from offset q rows over a longer KV must
    equal the tail of full attention."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    S = 64
    q = jax.random.normal(ks[0], (1, S, 2, 8), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, 2, 8), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, 2, 8), jnp.float32)
    full = flash_attention(q, k, v, causal=True, q_chunk=16, kv_block=16)
    tail = flash_attention(q[:, 48:], k, v, causal=True, q_offset=48, q_chunk=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(full[:, 48:]), np.asarray(tail), rtol=2e-4, atol=1e-5)


def test_ring_slot_positions():
    cur = jnp.asarray([0, 3, 7, 8, 19], jnp.int32)
    pos = np.asarray(ring_slot_positions(cur, 8))
    for bi, c in enumerate([0, 3, 7, 8, 19]):
        for j in range(8):
            p = pos[bi, j]
            assert p % 8 == j
            assert p <= c
            assert p > c - 8
    # unwritten slots (p < 0) only when cur < W-1
    assert (pos[0] < 0).sum() == 7  # cur=0: only slot 0 valid
    assert (pos[4] >= 0).all()  # cur=19 > W: all slots valid


def test_decode_attention_ring_equals_linear():
    """Masked ring-cache decode == linear-cache decode with a window."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, L, KH, D, W = 2, 32, 2, 8, 8
    q = jax.random.normal(ks[0], (B, 1, 4, D), jnp.float32)
    k_lin = jax.random.normal(ks[1], (B, L, KH, D), jnp.float32)
    v_lin = jax.random.normal(ks[2], (B, L, KH, D), jnp.float32)
    cur = jnp.asarray([17, 23], jnp.int32)
    want = decode_attention(q, k_lin, v_lin, cur, window=W)
    # Build the ring cache from the linear one.
    k_ring = jnp.zeros((B, W, KH, D), jnp.float32)
    v_ring = jnp.zeros((B, W, KH, D), jnp.float32)
    for bi, c in enumerate([17, 23]):
        for p in range(max(c - W + 1, 0), c + 1):
            k_ring = k_ring.at[bi, p % W].set(k_lin[bi, p])
            v_ring = v_ring.at[bi, p % W].set(v_lin[bi, p])
    got = decode_attention(
        q, k_ring, v_ring, cur, window=W, slot_positions=ring_slot_positions(cur, W)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
