"""Concurrent query-serving plane: N threads of sessions stream
randomized queries against one live plane; every session's merged
results agree exactly with the single-caller host oracle, first-batch
monotonicity holds, and the background compactor never changes any
in-flight session's results."""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    AggregateSpec, And, Eq, EventStore, Or, QueryProcessor, web_proxy_schema,
)
from repro.core.batching import alg1_next_k
from repro.core.dist_ingest import DistBatchWriter, DistIngestPlane
from repro.core.dist_query import DistQueryProcessor, QueryRun
from repro.core.query import QueryStats
from repro.launch.mesh import make_dev_mesh
from repro.serve_db import QueryService, TurnQuantum
from repro.serve_db.scheduler import FairScheduler, QueryEntry

T_SPAN = 2 * 3600
SCHEMES = ["scan", "batched_scan", "index", "batched_index"]


def _gen(seed, n):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(
            ["a.com", "b.com", "c.com", "rare.net"], p=[0.6, 0.25, 0.13, 0.02], size=n
        ).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    return ts, vals


TREES = [
    Eq("domain", "rare.net"),
    Eq("domain", "c.com"),
    And(Eq("domain", "c.com"), Eq("status", "404")),
    Or(Eq("domain", "rare.net"), Eq("domain", "c.com")),
    None,
]


@pytest.fixture(scope="module")
def served():
    """One live plane (unfolded runs at rest: mem_rows small enough that
    minors fire, no threshold major) behind one QueryService, plus the
    host store the oracle runs on."""
    ts, vals = _gen(seed=23, n=8_000)
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=16_000, tablets_per_device=2,
        mem_rows=1024, max_runs=6, append_rows=512,
    )
    w = DistBatchWriter(store, plane, batch_rows=1500)
    w.add(ts, {k: list(v) for k, v in vals.items()})
    w.close()
    svc = QueryService(store, plane, compaction_interval=0.01)
    yield store, plane, svc, ts, {k: np.array(v) for k, v in vals.items()}
    svc.close()


def _oracle(store, scheme, t0, t1, tree):
    return sum(b.n for b in QueryProcessor(store).run_scheme(scheme, t0, t1, tree))


# ----------------------------------------------------------- shared law
def test_turn_quantum_uses_shared_alg1_law():
    q = TurnQuantum(k0=2.0, c=1.5, t_min=0.02, t_max=0.25, max_batches=8)
    want = min(max(alg1_next_k(2.0, 0.01, 3, 1.5, 0.25, 0.02), 1.0), 8.0)
    q.update(0.01, 3)
    assert q.k == pytest.approx(want)
    # Hot turns shrink toward a single batch (interactive fairness).
    for _ in range(8):
        q.update(5.0, q.budget())
    assert q.budget() == 1
    # Fast turns grow geometrically up to the cap.
    for _ in range(20):
        q.update(1e-4, q.budget())
    assert q.budget() == 8


def test_scheduler_ttfr_priority():
    sched = FairScheduler()
    a = QueryEntry(session=None, stream=None)
    b = QueryEntry(session=None, stream=None)
    c = QueryEntry(session=None, stream=None)
    sched.submit(a)
    sched.requeue(b)  # continuing stream, queued first
    sched.submit(c)
    # Fresh queries (no first result yet) preempt continuing streams, FIFO.
    assert sched.pop_turn(timeout=0) is a
    assert sched.ttfr_waiting()
    assert sched.pop_turn(timeout=0) is c
    assert not sched.ttfr_waiting()
    assert sched.pop_turn(timeout=0) is b
    assert not sched.has_pending()
    assert sched.pop_turn(timeout=0) is None


# ------------------------------------------------------ oracle agreement
def test_single_session_all_schemes_agree(served):
    store, plane, svc, ts, vals = served
    s = svc.session("solo")
    tree = TREES[0]
    for scheme in SCHEMES:
        got = s.submit(scheme, 0, T_SPAN, tree).count()
        want = _oracle(store, scheme, 0, T_SPAN, tree)
        assert got == want and got > 0, (scheme, got, want)
    s.close()


def test_concurrent_sessions_agree_with_host_oracle(served):
    """The headline invariant: N client threads, each streaming a
    randomized query mix through its own session, all against the live
    plane — every count equals the single-caller host oracle's."""
    store, plane, svc, ts, vals = served
    n_threads = 4
    rng = np.random.default_rng(11)
    jobs = []
    for i in range(n_threads):
        mine = []
        for _ in range(3):
            tree = TREES[int(rng.integers(len(TREES)))]
            scheme = SCHEMES[int(rng.integers(len(SCHEMES)))]
            lo = int(rng.integers(0, T_SPAN // 2))
            hi = int(rng.integers(lo + 600, T_SPAN + 1))
            mine.append((scheme, lo, hi, tree))
        jobs.append(mine)
    results = [[] for _ in range(n_threads)]
    errors = []

    def client(i):
        try:
            s = svc.session(f"client-{i}")
            for scheme, lo, hi, tree in jobs[i]:
                results[i].append(s.submit(scheme, lo, hi, tree).count())
            s.close()
        except BaseException as e:  # surface in the main thread
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    for i in range(n_threads):
        for (scheme, lo, hi, tree), got in zip(jobs[i], results[i]):
            want = _oracle(store, scheme, lo, hi, tree)
            assert got == want, (i, scheme, lo, hi, got, want)


def test_host_backend_sessions_match_dist(served):
    """Host-path sessions run the SAME scheduler — the live oracle."""
    store, plane, svc, ts, vals = served
    sd = svc.session("d")
    sh = svc.session("h", backend="host")
    for scheme in ("batched_scan", "batched_index"):
        qd = sd.submit(scheme, 0, T_SPAN, TREES[1])
        qh = sh.submit(scheme, 0, T_SPAN, TREES[1])
        assert qd.count() == qh.count() > 0
    sd.close()
    sh.close()


def test_aggregate_and_density_sessions(served):
    store, plane, svc, ts, vals = served
    spec = AggregateSpec(group_by=("status",), op="count", time_bucket_s=3600)
    s = svc.session("agg")
    rb = s.submit_aggregate(spec, 0, T_SPAN, TREES[1]).drain()
    assert len(rb) == 1
    want = QueryProcessor(store).aggregate(spec, 0, T_SPAN, TREES[1])
    res = rb[0].blocks[0]
    np.testing.assert_array_equal(np.sort(res.values), np.sort(want.values))
    assert rb[0].count == int(want.counts.sum())
    dens = s.submit_density("domain", "rare.net", 0, T_SPAN).count()
    assert dens == store.agg_count("domain", "rare.net", 0, T_SPAN) > 0
    s.close()


# --------------------------------------------------- streaming contracts
def test_first_batch_monotonicity_and_streaming(served):
    """Batches of one session stream in submission order with strictly
    advancing time sub-ranges (Alg-2's p advances monotonically), seq
    numbers are contiguous, and the first batch arrives no later than
    completion."""
    store, plane, svc, ts, vals = served
    s = svc.session("stream")
    q = s.submit("batched_scan", 0, T_SPAN, TREES[1])
    batches = q.drain()
    assert len(batches) > 1  # the range really was batched
    assert [rb.seq for rb in batches] == list(range(len(batches)))
    los = [rb.lo for rb in batches]
    assert all(b > a for a, b in zip(los, los[1:])), los
    assert all(rb.hi >= rb.lo for rb in batches)
    assert q.first_result_s is not None and q.total_s is not None
    assert q.first_result_s <= q.total_s + 1e-9
    s.close()


def test_empty_plan_sessions_run_zero_batches(served):
    store, plane, svc, ts, vals = served
    s = svc.session("empty")
    stats = QueryStats()
    q = s.submit("batched_index", 0, T_SPAN, Eq("domain", "never-seen.example"),
                 stats=stats)
    assert q.count() == 0
    assert stats.plan is not None and stats.plan.mode == "empty"
    assert stats.batches == 0  # no device program ever dispatched
    s.close()


# ------------------------------------------- compactor vs in-flight runs
def test_fold_mid_query_never_changes_results(served):
    """Deterministic form of the compactor invariant: pin a QueryRun,
    step one batch, force a full fold (memtables -> runs -> base), then
    finish the run — the pinned snapshot must produce exactly the oracle
    counts, because published levels are stable (compactions never donate
    published buffers)."""
    store, plane, svc, ts, vals = served
    svc.wait_idle()
    proc = DistQueryProcessor(store, plane=plane)
    tree = TREES[3]
    run = QueryRun(proc, tree, 0, T_SPAN, use_index=True, batched=True)
    total = run.step().count
    assert not run.done  # fold lands mid-query
    # Put fresh rows in the memtable so the fold moves state at EVERY
    # level, then fold explicitly (the compactor thread's exact call).
    extra_ts, extra_vals = _gen(seed=91, n=500)
    w = DistBatchWriter(store, plane, batch_rows=500)
    w.add(extra_ts, extra_vals)
    w.close()
    plane.compact(source="background")
    while not run.done:
        blk = run.step()
        total += blk.count
    # Oracle over the ORIGINAL rows only: the pinned snapshot predates
    # the extra ingest, so the fold neither loses nor leaks rows.
    want = _oracle(store, "batched_index", 0, T_SPAN, tree)
    got_new = sum(b.count for b in proc.execute(tree, 0, T_SPAN))
    store.ingest(extra_ts, extra_vals)
    store.flush_all()
    want_new = _oracle(store, "batched_index", 0, T_SPAN, tree)
    assert total == want, (total, want)
    assert got_new == want_new, (got_new, want_new)  # post-fold query sees all


def test_background_compactor_folds_when_idle(served):
    """The serve plane schedules compact() off the query path: after the
    sessions above left unfolded state, the compactor folds it during an
    idle window, attributed as 'background' — and nothing is ever
    attributed to a query."""
    store, plane, svc, ts, vals = served
    svc.wait_idle()
    deadline = time.time() + 120
    while plane.has_unfolded() and time.time() < deadline:
        time.sleep(0.02)
    assert not plane.has_unfolded(), "compactor never drained the plane"
    assert svc.compactor.folds >= 1
    tel = plane.telemetry()
    assert tel["fold_events"].get("background", 0) >= 1
    # Fold accounting is exhaustive: every fold source is a known,
    # non-query path (reads cannot fold by construction).
    assert set(tel["fold_events"]) <= {"ingest", "background", "explicit"}
    # Results after the fold still match the oracle exactly.
    s = svc.session("post-fold")
    got = s.submit("batched_index", 0, T_SPAN, TREES[0]).count()
    assert got == _oracle(store, "batched_index", 0, T_SPAN, TREES[0])
    s.close()


def test_queries_while_ingesting(served):
    """Sessions stream while a writer ingests: acknowledged rows are
    visible to the NEXT submitted query (publish-freshness through the
    serve plane), and full-range counts are monotone non-decreasing."""
    store, plane, svc, ts, vals = served
    svc.wait_idle()
    s = svc.session("live")
    base = s.submit("batched_scan", 0, T_SPAN, None).count()
    counts = [base]
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            counts.append(s.submit("batched_scan", 0, T_SPAN, None).count())

    t = threading.Thread(target=reader)
    t.start()
    w = DistBatchWriter(store, plane, batch_rows=400)
    n_extra = 1_200
    extra_ts, extra_vals = _gen(seed=77, n=n_extra)
    for off in range(0, n_extra, 400):
        sl = slice(off, off + 400)
        w.add(extra_ts[sl], {k: v[sl] for k, v in extra_vals.items()})
    w.close()
    after_ack = s.submit("batched_scan", 0, T_SPAN, None).count()
    stop.set()
    t.join()
    s.close()
    assert after_ack == base + n_extra, (after_ack, base, n_extra)
    assert all(b >= a for a, b in zip(counts, counts[1:])), counts
    # Restore the host store to match (later tests compare against it).
    store.ingest(extra_ts, extra_vals)
    store.flush_all()


# -------------------------------------------------------------- telemetry
def test_session_telemetry_surfaced_in_plane(served):
    """Serve-plane clients and ingest writers report through ONE
    structure: telemetry()['sessions'] next to
    ['blocked_seconds_per_writer']."""
    store, plane, svc, ts, vals = served
    s = svc.session("telemetry")
    q = s.submit("batched_scan", 0, T_SPAN, TREES[1])
    n = q.count()
    s.close()
    tel = plane.telemetry()
    assert s.session_id in tel["sessions"]
    rec = tel["sessions"][s.session_id]
    assert rec["queries"] >= 1.0
    assert rec["rows"] >= float(n)
    assert rec["batches"] == float(q.batches) >= 1.0
    assert rec["first_result_s_max"] > 0.0
    assert rec["queue_wait_s"] >= 0.0
    assert "blocked_seconds_per_writer" in tel  # one structure, both planes


def test_fill_bounded_seal(served):
    """publish() sorts only the live memtable fill: a publish right after
    a full fold seals the minimum bucket, and the sealed level still
    carries every row (count agreement above proves correctness; here we
    check the bound actually engages)."""
    store, plane, svc, ts, vals = served
    svc.wait_idle()
    deadline = time.time() + 120
    while plane.has_unfolded() and time.time() < deadline:
        time.sleep(0.02)
    with plane._lock:
        plane._dirty = True  # force a re-seal of the (empty) memtable
    plane.publish()
    assert plane.last_seal_rows == 8  # minimum bucket, not mem_rows
    w = DistBatchWriter(store, plane, batch_rows=600)
    extra_ts, extra_vals = _gen(seed=55, n=600)
    w.add(extra_ts, extra_vals)
    w.close()
    store.ingest(extra_ts, extra_vals)
    store.flush_all()
    plane.publish()
    # Live fill now nonzero but far below mem_rows: bucket is in between.
    assert 8 <= plane.last_seal_rows < plane.mem_rows
    s = svc.session("seal")
    got = s.submit("batched_scan", 0, T_SPAN, None).count()
    assert got == _oracle(store, "batched_scan", 0, T_SPAN, None)
    s.close()
