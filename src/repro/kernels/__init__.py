"""Pallas TPU kernels for the store's compute hot-spots — the pieces the
paper implements as server-side Accumulo iterators/combiners:

  filter_scan        server-side filter iterator (WholeRowIterator subclass)
                     -> vectorized predicate program over columnar VMEM tiles
  merge_intersect    client-side index key-set intersection (query plan AND)
                     -> blockwise binary-search membership over sorted keys
  aggregate_combine  combiner framework (count aggregation)
                     -> block-segmented sum over sorted (key, count) runs
  combine_scan       fused filter + combiner (scan-time aggregation for the
                     iterator stack) -> one VMEM pass per tablet tile

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper; on CPU defaults to the vectorized jnp reference since
interpret-mode Pallas is an emulation, on TPU to the kernel), ref.py
(pure-jnp oracle used for allclose validation).

All kernels operate on int32 lanes only (dictionary codes / split key
lanes) — the packed int64 keys never enter a kernel, by design (TPU-native
layout; see DESIGN.md hardware-adaptation table).
"""
from . import aggregate_combine, combine_scan, filter_scan, merge_intersect  # noqa: F401
