"""Helpers shared across kernel subpackages (core-import-free: the
kernels package must never import repro.core at module scope — core's
__init__ imports the query/iterator modules that need the kernels)."""
from __future__ import annotations

from typing import Tuple

import numpy as np


def pow2(n: int) -> int:
    """Smallest power of two >= n. Every kernel pads shapes to pow2
    buckets to bound the retrace count; one definition, not one clone
    per package (enforced by reprolint's kernel-contract rule)."""
    p = 1
    while p < n:
        p *= 2
    return p


def split_key_lanes(keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """int64 packed keys -> (hi, lo) int32 lanes. TPU-native carry format:
    kernels only ever see 32-bit lanes; the lo lane's bit pattern is
    preserved via a uint32 view (negative int32 == high-bit-set lane)."""
    keys = np.asarray(keys, dtype=np.int64)
    hi = (keys >> 32).astype(np.int32)
    lo = (keys & 0xFFFFFFFF).astype(np.uint32).view(np.int32)
    return hi, lo
