"""Shared postfix predicate-program evaluator (jnp/lax, value-level).

One implementation of the filter program semantics (core/filter.py opcodes),
written against plain jnp values so it can run

  * inside a Pallas kernel body (combine_scan: the fused filter half),
  * in the jitted jnp references (filter_scan/ref.py, combine_scan/ref.py),
  * inside the shard_map distributed scan (core/dist_query.py).

The Pallas filter_scan kernel keeps its own lax.switch formulation (scalar
branch dispatch is cheaper there); everything else routes through here so
the program semantics exist in exactly two audited places.

This module is also the canonical home of the program opcodes and stack
bound: the kernels package must stay import-free of `repro.core` (core's
__init__ imports query/iterator modules that need the kernels — a
module-level back-edge would be a cycle), so core/filter.py re-exports
these constants rather than defining them.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# Opcodes (postfix program over a boolean stack; see core/filter.py for
# the compiler). Order matters: filter_scan's lax.switch branch table
# indexes by opcode value.
OP_NOP = 0
OP_PUSH_EQ = 1
OP_PUSH_IN = 2
OP_PUSH_TRUE = 3
OP_AND = 4
OP_OR = 5
OP_NOT = 6

MAX_STACK = 8


def program_eval_rows(cols, opcodes, arg0, arg1, codesets):
    """Evaluate a compiled filter program over a columnar block.

    cols (n, f) int32 dictionary codes; opcodes/arg0/arg1 (p,) int32;
    codesets (s, m) int32 padded with -1. Returns bool (n,) match mask.
    Pure jnp: traceable under jit, shard_map, and Pallas.
    """
    n = cols.shape[0]

    def step(i, carry):
        stack, sp = carry
        op = opcodes[i]
        f = arg0[i]
        arg = arg1[i]
        col = jnp.take(cols, f, axis=1)
        cset = jnp.take(codesets, arg, axis=0)
        eq = col == arg
        inset = jnp.any((col[:, None] == cset[None, :]) & (cset[None, :] >= 0), axis=1)
        tru = jnp.ones((n,), jnp.bool_)

        is_push = (op == OP_PUSH_EQ) | (op == OP_PUSH_IN) | (op == OP_PUSH_TRUE)
        push_val = jnp.where(
            op == OP_PUSH_EQ, eq, jnp.where(op == OP_PUSH_IN, inset, tru)
        )
        a = lax.dynamic_index_in_dim(stack, sp - 2, axis=0, keepdims=False)
        b = lax.dynamic_index_in_dim(stack, sp - 1, axis=0, keepdims=False)
        binres = jnp.where(op == OP_AND, a & b, a | b)

        # Three mutually exclusive effects; NOP leaves everything alone.
        stack_push = lax.dynamic_update_index_in_dim(stack, push_val, sp, axis=0)
        stack_bin = lax.dynamic_update_index_in_dim(stack, binres, sp - 2, axis=0)
        stack_not = lax.dynamic_update_index_in_dim(stack, ~b, sp - 1, axis=0)

        is_bin = (op == OP_AND) | (op == OP_OR)
        is_not = op == OP_NOT
        stack = jnp.where(
            is_push, stack_push, jnp.where(is_bin, stack_bin, jnp.where(is_not, stack_not, stack))
        )
        sp = sp + jnp.where(is_push, 1, jnp.where(is_bin, -1, 0)).astype(sp.dtype)
        return stack, sp

    stack0 = jnp.zeros((MAX_STACK, n), jnp.bool_)
    stack, _ = lax.fori_loop(0, opcodes.shape[0], step, (stack0, jnp.int32(0)))
    return stack[0]
