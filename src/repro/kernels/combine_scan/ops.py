"""Public fused combiner op: filter + group-aggregate a sorted run in one
kernel pass.

Input is a run sorted by int64 group key (the CombinerIterator packs group
field codes + time bucket into one key, then sorts). Output is one row per
group that has at least one filter-surviving event: (group key, aggregate,
match count).

Pallas path: tile-local fused kernel + an O(n_tiles) stitch epilogue for
groups straddling tile boundaries. CPU default: the jnp reference
(identical output, asserted in tests) — same backend policy as
filter_scan/aggregate_combine."""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Tuple

import jax
import numpy as np

from ..common import split_key_lanes
from ..filter_scan.ops import LANE, _bucket, _pow2, pad_program
from ..program_eval import OP_PUSH_TRUE
from .combine_scan import BLOCK, OP_MAX, OP_MIN, OP_SUM, combine_scan_pallas
from .ref import combine_scan_ref

if TYPE_CHECKING:  # runtime import would cycle: core/__init__ needs kernels
    from ...core.filter import FilterProgram

OPS = {"count": OP_SUM, "sum": OP_SUM, "min": OP_MIN, "max": OP_MAX}

_SENTINEL32 = np.iinfo(np.int32).max


def trivial_program() -> "FilterProgram":
    """All-rows-match program (combiner with no residual filter)."""
    from ...core.filter import FilterProgram

    return FilterProgram(
        opcodes=np.asarray([OP_PUSH_TRUE], np.int32),
        arg0=np.zeros(1, np.int32),
        arg1=np.zeros(1, np.int32),
        codesets=np.full((1, 1), -1, np.int32),
        max_depth=1,
    )


def _stitch(keys, heads, aggs, cnts, n, op_kind: int) -> None:
    """Fold tile-boundary-straddling groups into their open segment head.
    In-place on the padded arrays; O(n_tiles) host loop."""
    for t in range(1, (len(heads) + BLOCK - 1) // BLOCK):
        i = t * BLOCK
        if i >= n:
            break
        if keys[i] == keys[i - 1]:
            h = i - 1
            while not heads[h]:
                h -= 1
            if op_kind == OP_SUM:
                aggs[h] += aggs[i]
            elif op_kind == OP_MIN:
                aggs[h] = min(aggs[h], aggs[i])
            else:
                aggs[h] = max(aggs[h], aggs[i])
            cnts[h] += cnts[i]
            heads[i] = False


def combine_scan(
    group_keys: np.ndarray,
    values: Optional[np.ndarray],
    cols: np.ndarray,
    prog: Optional[FilterProgram],
    op: str = "count",
    backend: str = "auto",
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fused scan-time aggregation over a sorted run.

    group_keys: int64 (n,) ascending (duplicates = same group).
    values:     int32 (n,) aggregand; ignored for op='count' (may be None).
    cols:       int32 (n, f) dictionary codes — the filter's input.
    prog:       residual FilterProgram, or None for match-all.
    op:         'count' | 'sum' | 'min' | 'max'.

    Returns (unique group keys, aggregates int64, match counts), all
    restricted to groups with count > 0 — filtered-out groups never leave
    the server. Sum/count aggregates accumulate in int64 across tiles and
    blocks; the Pallas kernel's tile-local partials are int32, which is
    exact as long as one BLOCK-row tile cannot wrap (|value| < 2^31/BLOCK
    per row — always true for count, whose values are 1s). Sums over
    larger values route to the int64 jnp reference automatically.
    """
    op_kind = OPS[op]
    group_keys = np.asarray(group_keys, dtype=np.int64)
    n, f = cols.shape
    assert group_keys.shape == (n,), (group_keys.shape, n)
    if n == 0:
        return (
            np.empty(0, np.int64),
            np.empty(0, np.int64),
            np.empty(0, np.int32),
        )
    if op == "count":
        values = np.ones(n, np.int32)
    values = np.asarray(values, dtype=np.int32)
    if prog is None:
        prog = trivial_program()
    opc, a0, a1, cs = pad_program(prog)
    hi, lo = split_key_lanes(group_keys)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if (
        backend == "pallas"
        and op == "sum"
        and values.size
        and int(np.abs(values, dtype=np.int64).max()) > (2**31 - 1) // BLOCK
    ):
        # A single tile's int32 partial could wrap: use the int64 ref.
        backend = "ref"

    if backend == "ref":
        # Pow2-bucket rows to bound retraces (adaptive batching varies n
        # every call). Sentinel-key padding rows may pass a trivial filter,
        # but they form their own trailing segments, dropped by the [:n]
        # slice below.
        n_pad = _pow2(n)
        f_pad = f
    else:
        n_pad = _bucket(n, BLOCK)
        f_pad = _bucket(f, LANE)
    hi_p = np.full(n_pad, _SENTINEL32, np.int32)
    lo_p = np.full(n_pad, _SENTINEL32, np.int32)
    val_p = np.zeros(n_pad, np.int32)
    cols_p = np.full((n_pad, f_pad), -1, np.int32)
    hi_p[:n], lo_p[:n], val_p[:n] = hi, lo, values
    cols_p[:n, :f] = cols

    if backend == "ref":
        heads, aggs, cnts = combine_scan_ref(
            hi_p, lo_p, val_p, cols_p, opc, a0, a1, cs, op_kind=op_kind
        )
        heads = np.asarray(heads)[:n]
        aggs = np.asarray(aggs)[:n]
        cnts = np.asarray(cnts)[:n]
    else:
        interpret = jax.default_backend() != "tpu"
        heads, aggs, cnts = combine_scan_pallas(
            hi_p, lo_p, val_p, cols_p, opc, a0, a1, cs,
            op_kind=op_kind, interpret=interpret,
        )
        heads = np.asarray(heads).copy()
        # Widen before the stitch: cross-tile accumulation must be int64
        # (tile-local int32 partials are bounded by BLOCK rows each).
        aggs = np.asarray(aggs).astype(np.int64)
        cnts = np.asarray(cnts).copy()
        _stitch(group_keys, heads, aggs, cnts, n, op_kind)
        heads = heads[:n]
        aggs = aggs[:n]
        cnts = cnts[:n]

    keep = heads & (cnts > 0)
    return group_keys[keep], np.asarray(aggs[keep], np.int64), cnts[keep]
