"""Fused server-side filter + combiner kernel (scan-time aggregation)."""
from .combine_scan import BLOCK, combine_scan_pallas  # noqa: F401
from .ops import OPS, combine_scan, trivial_program  # noqa: F401
from .ref import combine_scan_ref  # noqa: F401
