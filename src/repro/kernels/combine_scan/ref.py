"""Pure-jnp oracle for the fused combine_scan kernel: whole-array filter +
segmented aggregation over a run sorted by group key. Identical semantics,
no tiling (so no stitch epilogue needed)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..program_eval import program_eval_rows
from .combine_scan import _IDENTITY, OP_SUM, _segment_agg


@functools.partial(jax.jit, static_argnames=("op_kind",))
def combine_scan_ref(hi, lo, val, cols, opcodes, arg0, arg1, codesets, *, op_kind: int):
    """Returns (heads bool (n,), per-group masked aggregate at head
    positions, per-group match count at head positions)."""
    n = hi.shape[0]
    mask = program_eval_rows(cols, opcodes, arg0, arg1, codesets)
    prev_hi = jnp.concatenate([jnp.full((1,), -1, hi.dtype), hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), -1, lo.dtype), lo[:-1]])
    heads = (hi != prev_hi) | (lo != prev_lo)
    heads = heads.at[0].set(True)
    seg_id = jnp.cumsum(heads.astype(jnp.int32)) - 1
    # Sums accumulate in int64 (unbounded run lengths must not wrap 32-bit
    # counts); min/max are order statistics and stay in the input's range.
    acc_dtype = jnp.int64 if op_kind == OP_SUM else jnp.int32
    identity = jnp.asarray(_IDENTITY[op_kind], acc_dtype)
    contrib = jnp.where(mask, val.astype(acc_dtype), identity)
    seg_agg = _segment_agg(contrib, seg_id, n, op_kind)
    seg_cnt = jax.ops.segment_sum(mask.astype(jnp.int32), seg_id, num_segments=n)
    aggs = jnp.where(heads, jnp.take(seg_agg, seg_id, axis=0), identity)
    cnts = jnp.where(heads, jnp.take(seg_cnt, seg_id, axis=0), 0)
    return heads, aggs, cnts
