"""Pallas TPU kernel: fused server-side filter + combiner, one VMEM pass.

The iterator-stack aggregation path ("count events per src_ip per hour")
previously needed two kernel dispatches per tablet tile: filter_scan to get
the match mask, then aggregate_combine over the surviving rows — with the
row tile making a round trip through HBM in between. This kernel fuses
both: for each (BLOCK,)-tile of a run sorted by group key it

  1. evaluates the compiled postfix filter program over the columnar tile
     (same semantics as filter_scan — shared interpreter, program_eval.py),
  2. computes segment heads from group-key changes ((hi, lo) int32 lanes,
     as in aggregate_combine),
  3. segment-aggregates the masked values (sum / min / max; count is a sum
     of the mask) and the masked row counts,

writing, per tile:

  heads (BLOCK,) bool   — group starts, relative to the tile only
  aggs  (BLOCK,) int32  — at head positions, tile-local masked aggregate
  cnts  (BLOCK,) int32  — at head positions, tile-local matching-row count

Cross-tile stitching (a group straddling a tile boundary) runs in the
ops.py epilogue, O(n_tiles) — the same two-level reduction split as
aggregate_combine. Empty groups (cnt 0) are dropped there too, so a group
whose every row fails the filter never reaches the client.

VMEM budget per block @ BLOCK=1024, F_pad=128: cols tile 512 KiB, key
lanes + values 12 KiB, program + codesets <= 20 KiB — comfortable on a
v5e core with double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..program_eval import program_eval_rows

BLOCK = 1024

OP_SUM = 0  # also count: values are 1s
OP_MIN = 1
OP_MAX = 2

_IDENTITY = {
    OP_SUM: 0,
    OP_MIN: jnp.iinfo(jnp.int32).max,
    OP_MAX: jnp.iinfo(jnp.int32).min,
}


def _segment_agg(contrib, seg_id, n, op_kind: int):
    if op_kind == OP_SUM:
        return jax.ops.segment_sum(contrib, seg_id, num_segments=n)
    if op_kind == OP_MIN:
        return jax.ops.segment_min(contrib, seg_id, num_segments=n)
    return jax.ops.segment_max(contrib, seg_id, num_segments=n)


def _kernel(
    hi_ref, lo_ref, val_ref, cols_ref,
    opcodes_ref, arg0_ref, arg1_ref, codesets_ref,
    heads_ref, aggs_ref, cnts_ref,
    *, op_kind: int,
):
    hi = hi_ref[...]
    lo = lo_ref[...]
    val = val_ref[...].astype(jnp.int32)
    cols = cols_ref[...]  # (BLOCK, F_pad) int32
    n = hi.shape[0]

    # Fused filter half: match mask for the whole tile in registers — the
    # row tile never leaves VMEM between filter and combine.
    mask = program_eval_rows(
        cols, opcodes_ref[...], arg0_ref[...], arg1_ref[...], codesets_ref[...]
    )

    prev_hi = jnp.concatenate([jnp.full((1,), -1, hi.dtype), hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), -1, lo.dtype), lo[:-1]])
    heads = (hi != prev_hi) | (lo != prev_lo)
    heads = heads.at[0].set(True)
    seg_id = jnp.cumsum(heads.astype(jnp.int32)) - 1

    identity = jnp.int32(_IDENTITY[op_kind])
    contrib = jnp.where(mask, val, identity)
    seg_agg = _segment_agg(contrib, seg_id, n, op_kind)
    seg_cnt = jax.ops.segment_sum(mask.astype(jnp.int32), seg_id, num_segments=n)

    aggs_ref[...] = jnp.where(heads, jnp.take(seg_agg, seg_id, axis=0), identity)
    cnts_ref[...] = jnp.where(heads, jnp.take(seg_cnt, seg_id, axis=0), 0)
    heads_ref[...] = heads


@functools.partial(
    jax.jit, static_argnames=("op_kind", "interpret", "block")
)
def combine_scan_pallas(
    hi, lo, val, cols, opcodes, arg0, arg1, codesets,
    *, op_kind: int, interpret: bool = True, block: int = BLOCK,
):
    """hi/lo/val (n,) int32 sorted by (hi, lo); cols (n, f_pad) int32 with
    f_pad a lane multiple; program arrays as in filter_scan. n % block == 0.
    Returns (heads bool (n,), tile-local head aggregates int32 (n,),
    tile-local head match counts int32 (n,))."""
    n = hi.shape[0]
    f_pad = cols.shape[1]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_kernel, op_kind=op_kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, f_pad), lambda i: (i, 0)),
            pl.BlockSpec(opcodes.shape, lambda i: (0,)),
            pl.BlockSpec(arg0.shape, lambda i: (0,)),
            pl.BlockSpec(arg1.shape, lambda i: (0,)),
            pl.BlockSpec(codesets.shape, lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.bool_),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(hi, lo, val, cols, opcodes, arg0, arg1, codesets)
