"""Pure-jnp oracle for the filter_scan kernel: identical postfix-program
semantics, straight-line vectorized evaluation (no Pallas). The program
interpreter itself lives in kernels/program_eval.py, shared with the fused
combine_scan kernel and the distributed scan."""
from __future__ import annotations

import jax

from ..program_eval import program_eval_rows


@jax.jit
def filter_scan_ref(cols, opcodes, arg0, arg1, codesets):
    """cols (n, f) int32; program (p,); codesets (s, m). Returns bool (n,)."""
    return program_eval_rows(cols, opcodes, arg0, arg1, codesets)
