"""Pure-jnp oracle for the filter_scan kernel: identical postfix-program
semantics, straight-line vectorized evaluation (no Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ...core.filter import (
    MAX_STACK,
    OP_AND,
    OP_NOT,
    OP_OR,
    OP_PUSH_EQ,
    OP_PUSH_IN,
    OP_PUSH_TRUE,
)


@jax.jit
def filter_scan_ref(cols, opcodes, arg0, arg1, codesets):
    """cols (n, f) int32; program (p,); codesets (s, m). Returns bool (n,)."""
    n = cols.shape[0]

    def step(i, carry):
        stack, sp = carry
        op = opcodes[i]
        f = arg0[i]
        arg = arg1[i]
        col = jnp.take(cols, f, axis=1)
        cset = jnp.take(codesets, arg, axis=0)
        eq = col == arg
        inset = jnp.any((col[:, None] == cset[None, :]) & (cset[None, :] >= 0), axis=1)
        tru = jnp.ones((n,), jnp.bool_)

        is_push = (op == OP_PUSH_EQ) | (op == OP_PUSH_IN) | (op == OP_PUSH_TRUE)
        push_val = jnp.where(
            op == OP_PUSH_EQ, eq, jnp.where(op == OP_PUSH_IN, inset, tru)
        )
        a = lax.dynamic_index_in_dim(stack, sp - 2, axis=0, keepdims=False)
        b = lax.dynamic_index_in_dim(stack, sp - 1, axis=0, keepdims=False)
        binres = jnp.where(op == OP_AND, a & b, a | b)

        # Three mutually exclusive effects; NOP leaves everything alone.
        stack_push = lax.dynamic_update_index_in_dim(stack, push_val, sp, axis=0)
        stack_bin = lax.dynamic_update_index_in_dim(stack, binres, sp - 2, axis=0)
        stack_not = lax.dynamic_update_index_in_dim(stack, ~b, sp - 1, axis=0)

        is_bin = (op == OP_AND) | (op == OP_OR)
        is_not = op == OP_NOT
        stack = jnp.where(
            is_push, stack_push, jnp.where(is_bin, stack_bin, jnp.where(is_not, stack_not, stack))
        )
        sp = sp + jnp.where(is_push, 1, jnp.where(is_bin, -1, 0)).astype(sp.dtype)
        return stack, sp

    stack0 = jnp.zeros((MAX_STACK, n), jnp.bool_)
    stack, _ = lax.fori_loop(0, opcodes.shape[0], step, (stack0, jnp.int32(0)))
    return stack[0]
