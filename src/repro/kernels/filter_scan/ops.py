"""Public entry point for the filter kernel: padding, program bucketing,
backend dispatch.

Backend policy: on TPU the Pallas kernel runs natively; on CPU (this
container) interpret-mode Pallas is a Python emulation, so the production
query path uses the jnp reference (identical semantics — asserted by the
kernel test suite) and the kernel is exercised with interpret=True in
tests."""
from __future__ import annotations

from typing import TYPE_CHECKING, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pow2 as _pow2
from ..program_eval import OP_NOP

if TYPE_CHECKING:  # runtime import would cycle: core/__init__ needs kernels
    from ...core.filter import FilterProgram
from .filter_scan import BLOCK_ROWS, LANE, filter_scan_pallas
from .ref import filter_scan_ref


def _bucket(n: int, b: int) -> int:
    return max(((n + b - 1) // b) * b, b)


def pad_program(prog: FilterProgram) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad program length to a power of two (bounds retrace count) and the
    codeset table to power-of-two rows/cols."""
    p = _pow2(max(prog.length, 1))
    opc = np.full(p, OP_NOP, np.int32)
    a0 = np.zeros(p, np.int32)
    a1 = np.zeros(p, np.int32)
    opc[: prog.length] = prog.opcodes
    a0[: prog.length] = prog.arg0
    a1[: prog.length] = prog.arg1
    s, m = prog.codesets.shape
    cs = np.full((_pow2(max(s, 1)), _pow2(max(m, 1))), -1, np.int32)
    cs[:s, :m] = prog.codesets
    return opc, a0, a1, cs


def filter_scan(
    cols: np.ndarray,
    prog: FilterProgram,
    backend: str = "auto",
) -> np.ndarray:
    """Evaluate a compiled filter program over a columnar block.

    cols: (n, n_fields) int32 dictionary codes.
    Returns: (n,) bool match mask (numpy).
    """
    n, f = cols.shape
    if n == 0:
        return np.zeros(0, bool)
    opc, a0, a1, cs = pad_program(prog)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        # Bucket rows to powers of two: adaptive batching produces a
        # different n every call, and per-shape retracing would dominate
        # (measured 100ms+/batch). Padding rows can't match: codes are
        # >= 0, pad is -1.
        n_pad = _pow2(n)
        if n_pad != n:
            cols = np.concatenate([cols, np.full((n_pad - n, f), -1, np.int32)])
        mask = filter_scan_ref(jnp.asarray(cols), opc, a0, a1, cs)
        return np.asarray(mask)[:n]
    # Pallas path: pad rows to the block multiple and fields to the lane.
    n_pad = _bucket(n, BLOCK_ROWS)
    f_pad = _bucket(f, LANE)
    cols_p = np.zeros((n_pad, f_pad), np.int32)
    cols_p[:n, :f] = cols
    interpret = jax.default_backend() != "tpu"
    mask = filter_scan_pallas(
        jnp.asarray(cols_p), opc, a0, a1, cs, interpret=interpret
    )
    return np.asarray(mask)[:n]
