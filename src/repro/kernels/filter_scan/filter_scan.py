"""Pallas TPU kernel: server-side filter iterator.

Accumulo evaluates filter conditions row-by-row in a JVM iterator
(WholeRowIterator subclass — paper §III-B). The TPU-native equivalent
evaluates a compiled postfix predicate program (see core/filter.py) over a
VMEM-resident columnar tile of dictionary codes, producing a match bitmap
for the whole tile at once.

Tiling: the event-table run is laid out (rows, fields_padded) int32 with
fields padded to a lane multiple (128). Each grid step processes a
(BLOCK_ROWS, F_pad) tile; the program arrays (a few hundred bytes) and the
codeset table replicate into every block. The boolean evaluation stack
lives in registers as a loop-carried (MAX_STACK, BLOCK_ROWS) value —
program depth is bounded at compile time.

VMEM budget per block @ BLOCK_ROWS=1024, F_pad=128, M<=256, S<=16:
  tile 1024*128*4 = 512 KiB, codesets <=16 KiB, stack 8*1024 bool -> well
  inside a v5e core's VMEM alongside double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..program_eval import (
    MAX_STACK,
    OP_AND,
    OP_NOP,
    OP_NOT,
    OP_PUSH_EQ,
    OP_PUSH_IN,
    OP_PUSH_TRUE,
    OP_OR,
)

BLOCK_ROWS = 1024
LANE = 128


def _kernel(cols_ref, opcodes_ref, arg0_ref, arg1_ref, codesets_ref, mask_ref):
    cols = cols_ref[...]  # (BR, F_pad) int32
    opcodes = opcodes_ref[...]  # (P,) int32
    arg0 = arg0_ref[...]
    arg1 = arg1_ref[...]
    codesets = codesets_ref[...]  # (S, M) int32, -1 padded
    br = cols.shape[0]
    n_ops = opcodes.shape[0]

    def push(stack, sp, v):
        return lax.dynamic_update_index_in_dim(stack, v, sp, axis=0), sp + 1

    def step(i, carry):
        stack, sp = carry
        op = opcodes[i]
        f = arg0[i]
        arg = arg1[i]
        col = lax.dynamic_index_in_dim(cols, f, axis=1, keepdims=False)  # (BR,)
        cset = lax.dynamic_index_in_dim(codesets, arg, axis=0, keepdims=False)

        def do_nop(s, p):
            return s, p

        def do_eq(s, p):
            return push(s, p, col == arg)

        def do_in(s, p):
            hit = jnp.any((col[:, None] == cset[None, :]) & (cset[None, :] >= 0), axis=1)
            return push(s, p, hit)

        def do_true(s, p):
            return push(s, p, jnp.ones((br,), jnp.bool_))

        def do_and(s, p):
            a = lax.dynamic_index_in_dim(s, p - 2, axis=0, keepdims=False)
            b = lax.dynamic_index_in_dim(s, p - 1, axis=0, keepdims=False)
            return lax.dynamic_update_index_in_dim(s, a & b, p - 2, axis=0), p - 1

        def do_or(s, p):
            a = lax.dynamic_index_in_dim(s, p - 2, axis=0, keepdims=False)
            b = lax.dynamic_index_in_dim(s, p - 1, axis=0, keepdims=False)
            return lax.dynamic_update_index_in_dim(s, a | b, p - 2, axis=0), p - 1

        def do_not(s, p):
            a = lax.dynamic_index_in_dim(s, p - 1, axis=0, keepdims=False)
            return lax.dynamic_update_index_in_dim(s, ~a, p - 1, axis=0), p

        branches = [do_nop, do_eq, do_in, do_true, do_and, do_or, do_not]
        # OP_* values are 0..6 in the order above.
        return lax.switch(op, branches, stack, sp)

    stack0 = jnp.zeros((MAX_STACK, br), jnp.bool_)
    stack, _ = lax.fori_loop(0, n_ops, step, (stack0, jnp.int32(0)))
    mask_ref[...] = stack[0]


# Sanity: opcode numbering must match the branch table above.
assert (OP_NOP, OP_PUSH_EQ, OP_PUSH_IN, OP_PUSH_TRUE, OP_AND, OP_OR, OP_NOT) == (
    0,
    1,
    2,
    3,
    4,
    5,
    6,
)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def filter_scan_pallas(
    cols, opcodes, arg0, arg1, codesets, *, interpret: bool = True, block_rows: int = BLOCK_ROWS
):
    """cols (n, f_pad) int32 [n % block_rows == 0, f_pad % 128 == 0];
    program arrays (p,); codesets (s, m). Returns bool (n,) match mask."""
    n, f_pad = cols.shape
    assert n % block_rows == 0, (n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, f_pad), lambda i: (i, 0)),
            pl.BlockSpec(opcodes.shape, lambda i: (0,)),
            pl.BlockSpec(arg0.shape, lambda i: (0,)),
            pl.BlockSpec(arg1.shape, lambda i: (0,)),
            pl.BlockSpec(codesets.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(cols, opcodes, arg0, arg1, codesets)
