from .ops import filter_scan, pad_program  # noqa: F401
from .ref import filter_scan_ref  # noqa: F401
from .filter_scan import BLOCK_ROWS, LANE, filter_scan_pallas  # noqa: F401
