from .ops import filter_scan  # noqa: F401
