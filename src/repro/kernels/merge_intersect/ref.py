"""Pure-jnp oracle for merge_intersect: reconstruct packed int64 keys from
the (hi, lo) lanes and use searchsorted membership.

member_mask_keys is the traceable device form (jit / shard_map safe): the
distributed index step calls it per tablet to intersect posting slabs
inside the query program — the same membership computation the Pallas
kernel performs on (hi, lo) lanes for host key sets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def member_mask_keys(a, b):
    """Membership mask of each element of `a` in `b`; `b` sorted ascending
    (sentinel padding allowed — sentinels are ordinary values, so mask
    sentinel probes out at the caller)."""
    pos = jnp.searchsorted(b, a)
    pos_c = jnp.clip(pos, 0, b.shape[0] - 1)
    return (pos < b.shape[0]) & (b[pos_c] == a)


def _join(hi, lo):
    return (hi.astype(jnp.int64) << 32) | (lo.astype(jnp.int64) & 0xFFFFFFFF)


@jax.jit
def intersect_mask_ref(a_hi, a_lo, b_hi, b_lo):
    """Membership mask of a in b; b sorted ascending by (hi, lo-unsigned)."""
    return member_mask_keys(_join(a_hi, a_lo), _join(b_hi, b_lo))
