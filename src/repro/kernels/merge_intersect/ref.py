"""Pure-jnp oracle for merge_intersect: reconstruct packed int64 keys from
the (hi, lo) lanes and use searchsorted membership."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _join(hi, lo):
    return (hi.astype(jnp.int64) << 32) | (lo.astype(jnp.int64) & 0xFFFFFFFF)


@jax.jit
def intersect_mask_ref(a_hi, a_lo, b_hi, b_lo):
    """Membership mask of a in b; b sorted ascending by (hi, lo-unsigned)."""
    a = _join(a_hi, a_lo)
    b = _join(b_hi, b_lo)
    pos = jnp.searchsorted(b, a)
    pos_c = jnp.clip(pos, 0, b.shape[0] - 1)
    return (pos < b.shape[0]) & (b[pos_c] == a)
