"""Public sorted-set ops used by the query planner's combine step.

intersect_sorted: A ∩ B over sorted int64 packed-key vectors (the planner's
AND path — paper Fig 2). union_sorted: A ∪ B (the OR path; bandwidth-bound
merge, no kernel warranted — jnp sort of the concatenation).

The Pallas path requires the probe set in VMEM; adaptive batching keeps
index-scan result sets small, and ops enforces MAX_VMEM_KEYS as the
documented cap (falls back to the reference beyond it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pow2 as _pow2, split_key_lanes as _split
from .merge_intersect import BLOCK, intersect_mask_pallas
from .ref import intersect_mask_ref

MAX_VMEM_KEYS = 1 << 20  # 2 lanes * 4 B * 1M = 8 MiB resident in VMEM


def intersect_sorted(a: np.ndarray, b: np.ndarray, backend: str = "auto") -> np.ndarray:
    """Intersection of two sorted (ascending, non-negative) int64 key sets.
    Returns sorted int64 array."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    if a.size == 0 or b.size == 0:
        return np.empty(0, np.int64)
    # Probe the smaller set from the larger: kernel cost n log m.
    if a.size < b.size:
        a, b = b, a
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend != "ref" and b.size > MAX_VMEM_KEYS:
        backend = "ref"
    a_hi, a_lo = _split(a)
    b_hi, b_lo = _split(b)
    if backend == "ref":
        # Pow2-bucket both sides to avoid per-shape retraces (sentinels:
        # A pads with -1 hi / never matches; B pads with +INF order).
        na, nb = a.size, b.size
        pa, pb = _pow2(na), _pow2(nb)
        ah = np.full(pa, -1, np.int32); ah[:na] = a_hi
        al = np.zeros(pa, np.int32); al[:na] = a_lo
        bh = np.full(pb, np.iinfo(np.int32).max, np.int32); bh[:nb] = b_hi
        bl = np.full(pb, -1, np.int32); bl[:nb] = b_lo
        mask = np.asarray(intersect_mask_ref(ah, al, bh, bl))[:na]
        return a[mask]
    # Pallas: pad A to the block multiple with sentinel keys that cannot
    # match (hi = -1 never occurs: real hi >= 0); pad B to a power of two
    # with +INF in (hi, lo-unsigned) order.
    n_pad = ((a.size + BLOCK - 1) // BLOCK) * BLOCK
    m_pad = _pow2(b.size)
    ah = np.full(n_pad, -1, np.int32)
    al = np.zeros(n_pad, np.int32)
    ah[: a.size] = a_hi
    al[: a.size] = a_lo
    bh = np.full(m_pad, np.iinfo(np.int32).max, np.int32)
    bl = np.full(m_pad, -1, np.int32)  # 0xFFFFFFFF: max in unsigned order
    bh[: b.size] = b_hi
    bl[: b.size] = b_lo
    interpret = jax.default_backend() != "tpu"
    mask = np.asarray(
        intersect_mask_pallas(
            jnp.asarray(ah), jnp.asarray(al), jnp.asarray(bh), jnp.asarray(bl), interpret=interpret
        )
    )[: a.size]
    return a[mask]


def union_sorted(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Union of two sorted int64 key sets (planner OR path)."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    if a.size == 0:
        return np.unique(b)
    if b.size == 0:
        return np.unique(a)
    return np.unique(np.concatenate([a, b]))
