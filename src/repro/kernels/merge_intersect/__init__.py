from .ops import intersect_sorted, union_sorted  # noqa: F401
