from .ops import intersect_sorted, union_sorted  # noqa: F401
from .ref import member_mask_keys  # noqa: F401
