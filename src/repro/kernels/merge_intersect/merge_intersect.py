"""Pallas TPU kernel: sorted key-set intersection.

The paper's query planner executes equality conditions as index-table scans
and combines the resulting row-ID sets "via intersection or union" at the
client (§III-B, Fig 2). The hot case is intersection of two sorted event-key
vectors. Keys are 53-bit packed integers carried as (hi, lo) int32 lanes —
the kernel never touches 64-bit lanes (TPU-native; int64 would lower to
emulated pairs anyway).

Algorithm: grid over A in (BLOCK,) tiles; the full B lane-pair is VMEM
resident (index-scan result sets are adaptively batched to ~k rows, so B is
small — ops.py enforces the documented cap). For each a in the tile, a
vectorized branchless binary search over B (log2(m) fori steps, B padded to
a power of two with +INF sentinels) finds the candidate slot; membership is
an exact (hi, lo) compare. Comparison is lexicographic with the lo lane
compared as unsigned (x ^ 0x80000000 trick).

Output: per-element membership bitmap; compaction happens in ops.py (jnp),
keeping the kernel shape-static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

BLOCK = 2048
SIGN = -0x80000000  # int32 sign bit, as a weak-typed Python literal


def _as_unsigned_order(lo):
    """Map int32 bit patterns to an order-preserving signed value for
    unsigned comparison: u(a) < u(b)  <=>  (a ^ SIGN) < (b ^ SIGN)."""
    return lo ^ SIGN


def _kernel(a_hi_ref, a_lo_ref, b_hi_ref, b_lo_ref, out_ref):
    a_hi = a_hi_ref[...]  # (BLOCK,)
    a_lo = _as_unsigned_order(a_lo_ref[...])
    b_hi = b_hi_ref[...]  # (M,) padded to pow2 with INT32_MAX sentinels
    b_lo = _as_unsigned_order(b_lo_ref[...])
    m = b_hi.shape[0]
    n_steps = max(m.bit_length() - 1, 0)  # m is a power of two

    # Branchless lower-bound binary search, vectorized over the A tile.
    lo_idx = jnp.zeros(a_hi.shape, jnp.int32)

    def step(s, lo_idx):
        half = jnp.int32(m) >> (s + 1)
        mid = lo_idx + half
        mh = jnp.take(b_hi, mid, axis=0)
        ml = jnp.take(b_lo, mid, axis=0)
        # b[mid] < a  (lexicographic on (hi, lo-unsigned))
        lt = (mh < a_hi) | ((mh == a_hi) & (ml < a_lo))
        return jnp.where(lt, mid, lo_idx)

    lo_idx = lax.fori_loop(0, n_steps, step, lo_idx)
    # lo_idx is the last index with b[idx] < a (or 0); candidate = idx and
    # idx+1 both checked for exact equality.
    cand0_h = jnp.take(b_hi, lo_idx, axis=0)
    cand0_l = jnp.take(b_lo, lo_idx, axis=0)
    nxt = jnp.minimum(lo_idx + 1, m - 1)
    cand1_h = jnp.take(b_hi, nxt, axis=0)
    cand1_l = jnp.take(b_lo, nxt, axis=0)
    hit = ((cand0_h == a_hi) & (cand0_l == a_lo)) | ((cand1_h == a_hi) & (cand1_l == a_lo))
    out_ref[...] = hit


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def intersect_mask_pallas(a_hi, a_lo, b_hi, b_lo, *, interpret: bool = True, block: int = BLOCK):
    """a_* (n,) int32 [n % block == 0]; b_* (m,) int32, m a power of two,
    sorted ascending by (hi, lo-unsigned) and padded with INT32_MAX.
    Returns bool (n,): a in b."""
    n = a_hi.shape[0]
    assert n % block == 0
    grid = (n // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec(b_hi.shape, lambda i: (0,)),
            pl.BlockSpec(b_lo.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo)
