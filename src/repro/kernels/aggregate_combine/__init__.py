from .ops import combine_sorted_counts  # noqa: F401
