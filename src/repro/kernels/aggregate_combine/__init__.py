from .ops import combine_sorted_counts  # noqa: F401
from .ref import combine_blocks_ref, combine_sorted_ref  # noqa: F401
from .aggregate_combine import BLOCK, combine_blocks_pallas  # noqa: F401
