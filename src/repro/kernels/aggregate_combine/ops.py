"""Public combiner op: sums counts of duplicate keys in a sorted run.

Pallas path: tile-local segmented sums from the kernel + an O(n_tiles)
stitching epilogue for keys straddling tile boundaries. CPU default: the
jnp reference (identical output, asserted in tests)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pow2 as _pow2, split_key_lanes as _split
from .aggregate_combine import BLOCK, combine_blocks_pallas
from .ref import combine_blocks_ref


def combine_sorted_counts(
    keys: np.ndarray, counts: np.ndarray, backend: str = "auto"
) -> Tuple[np.ndarray, np.ndarray]:
    """(sorted int64 keys with possible duplicates, int32 counts) ->
    (unique sorted keys, summed counts)."""
    keys = np.asarray(keys, dtype=np.int64)
    counts = np.asarray(counts, dtype=np.int32)
    n = keys.size
    if n == 0:
        return keys, counts
    hi, lo = _split(keys)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        # Pow2-bucket to avoid per-shape retraces. Pad keys with INT64_MAX
        # pairs and zero counts: they form trailing segments summing to 0
        # that the [:n] slice drops.
        n_pad = _pow2(n)
        if n_pad != n:
            mx = np.iinfo(np.int32).max
            hi = np.concatenate([hi, np.full(n_pad - n, mx, np.int32)])
            lo = np.concatenate([lo, np.full(n_pad - n, mx, np.int32)])
            counts = np.concatenate([counts, np.zeros(n_pad - n, np.int32)])
        heads, sums = combine_blocks_ref(jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(counts))
        heads = np.asarray(heads)[:n]
        sums = np.asarray(sums)[:n]
        return keys[heads], sums[heads]
    n_pad = ((n + BLOCK - 1) // BLOCK) * BLOCK
    hi_p = np.full(n_pad, np.iinfo(np.int32).max, np.int32)
    lo_p = np.full(n_pad, np.iinfo(np.int32).max, np.int32)
    cnt_p = np.zeros(n_pad, np.int32)
    hi_p[:n], lo_p[:n], cnt_p[:n] = hi, lo, counts
    interpret = jax.default_backend() != "tpu"
    heads, sums = combine_blocks_pallas(
        jnp.asarray(hi_p), jnp.asarray(lo_p), jnp.asarray(cnt_p), interpret=interpret
    )
    heads = np.asarray(heads).copy()
    sums = np.asarray(sums).copy()
    # Stitch tile boundaries: if the first key of tile t equals the last key
    # of tile t-1, fold its head sum into the open segment and clear the
    # flag. O(n_tiles) host loop — the classic two-level reduction epilogue.
    for t in range(1, n_pad // BLOCK):
        i = t * BLOCK
        if i >= n:
            break
        if keys[i] == keys[i - 1]:
            # Find the open segment's head (last head position before i).
            h = i - 1
            while not heads[h]:
                h -= 1
            sums[h] += sums[i]
            heads[i] = False
            sums[i] = 0
    heads = heads[:n]
    sums = sums[:n]
    return keys[heads], sums[heads]
