"""Pallas TPU kernel: the Accumulo combiner, as a block-segmented sum.

The paper's aggregate table "maintains a count of particular value
occurrences by time interval", with counts pre-summed by ingest workers and
finished "on the server side using Accumulo's combiner framework" (§II).
After a major compaction the table is a sorted run of (key, count) entries
possibly containing duplicate keys; the combiner sums counts per unique key.

Kernel: grid over (BLOCK,)-tiles of the sorted run. Within a tile it
computes head flags (key != previous key), per-segment sums via a prefix-sum
difference (cumsum(count) gathered at segment ends), and writes
  heads  (BLOCK,) bool   — segment starts, relative to the tile only
  sums   (BLOCK,) int32  — at head positions, the tile-local segment total

Cross-tile stitching (a key straddling a tile boundary) is O(n_tiles) and
runs in the ops.py epilogue — the canonical two-level reduction split.
Keys are (hi, lo) int32 lanes; equality needs no unsigned trickery.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _kernel(hi_ref, lo_ref, cnt_ref, heads_ref, sums_ref):
    hi = hi_ref[...]
    lo = lo_ref[...]
    cnt = cnt_ref[...].astype(jnp.int32)
    n = hi.shape[0]
    prev_hi = jnp.concatenate([jnp.full((1,), -1, hi.dtype), hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), -1, lo.dtype), lo[:-1]])
    heads = (hi != prev_hi) | (lo != prev_lo)
    heads = heads.at[0].set(True)
    # Per-segment sums from an inclusive prefix sum: for the segment that
    # starts at i and ends at j (inclusive), sum = pfx[j] - pfx[i] + cnt[i].
    pfx = jnp.cumsum(cnt)
    seg_id = jnp.cumsum(heads.astype(jnp.int32)) - 1
    # Segment end position for each row's segment = max row index per seg.
    seg_end = jax.ops.segment_max(
        jnp.arange(n, dtype=jnp.int32), seg_id, num_segments=n
    )
    end_for_row = jnp.take(seg_end, seg_id, axis=0)
    seg_sum_at_head = jnp.take(pfx, end_for_row, axis=0) - pfx + cnt
    sums_ref[...] = jnp.where(heads, seg_sum_at_head, 0)
    heads_ref[...] = heads


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def combine_blocks_pallas(hi, lo, cnt, *, interpret: bool = True, block: int = BLOCK):
    """hi/lo/cnt (n,) int32, n % block == 0, sorted by (hi, lo).
    Returns (heads bool (n,), tile-local head sums int32 (n,))."""
    n = hi.shape[0]
    assert n % block == 0
    grid = (n // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.bool_),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(hi, lo, cnt)
