"""Pure-jnp oracle for the combiner: full-array segmented sum over a sorted
(key, count) run. Matches core/tables.py::_combine_sorted semantics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def combine_blocks_ref(hi, lo, cnt):
    """Returns (heads bool (n,), per-segment total at head positions).

    Signature-paired with combine_blocks_pallas (kernel-contract); the
    reference computes exact per-segment totals in one pass where the
    kernel produces tile-local sums that ops.py stitches."""
    n = hi.shape[0]
    prev_hi = jnp.concatenate([jnp.full((1,), -1, hi.dtype), hi[:-1]])
    prev_lo = jnp.concatenate([jnp.full((1,), -1, lo.dtype), lo[:-1]])
    heads = (hi != prev_hi) | (lo != prev_lo)
    heads = heads.at[0].set(True)
    seg = jnp.cumsum(heads.astype(jnp.int32)) - 1
    sums = jax.ops.segment_sum(cnt.astype(jnp.int32), seg, num_segments=n)
    at_head = jnp.where(heads, jnp.take(sums, seg, axis=0), 0)
    return heads, at_head


combine_sorted_ref = combine_blocks_ref  # legacy name
