"""Pure-jnp oracle + device-side form of the k-way merge rank computation.

merge_ranks_keys is traceable (jit / shard_map safe): the device ingest
plane calls it per tablet inside the major-compaction shard_map program
with int32 rev_ts keys; the Pallas kernel is its (hi, lo)-lane twin for
TPU execution of the host tablets' 64-bit packed keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_ranks_keys(keys):
    """keys (K, R): each row sorted ascending, sentinel-padded (sentinel =
    dtype max, sorting after every real key). Returns int32 (K, R) output
    ranks — a permutation of [0, K*R), stable in (run, index) order."""
    k, r = keys.shape
    own = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32), (k, r))
    ranks = own
    for i in range(k):
        for j in range(k):
            if i == j:
                continue
            side = "right" if i < j else "left"  # earlier runs win ties
            cnt = jnp.searchsorted(keys[i], keys[j], side=side).astype(jnp.int32)
            ranks = ranks.at[j].add(cnt)
    return ranks


def _join(hi, lo):
    return (hi.astype(jnp.int64) << 32) | (lo.astype(jnp.int64) & 0xFFFFFFFF)


@jax.jit
def merge_ranks_ref(runs_hi, runs_lo):
    """(hi, lo)-lane oracle for merge_ranks_pallas: reconstruct the packed
    int64 keys and rank via searchsorted."""
    return merge_ranks_keys(_join(runs_hi, runs_lo))
