"""Pallas TPU kernel: k-way sorted-run merge rank computation.

Major compaction merges the LSM tablet's sorted runs into one run
(tables.py host tablets, dist_ingest.py device tablets). The previous
placeholder concatenated and re-sorted — O(n log n) comparison sort that
ignores the input's sortedness. This kernel computes, for every element,
its final position in the merged output directly:

    rank(x in run j) = index of x within run j
                     + sum over runs i < j of |{y in run i : y <= x}|
                     + sum over runs i > j of |{y in run i : y <  x}|

The <=/< split is the stable tie-break (earlier runs win), which makes the
ranks a permutation of [0, K*R) even with duplicate keys — the scatter
epilogue in ops.py then places keys and payload columns in one pass.

Keys are (hi, lo) int32 lanes (64-bit packed host keys never touch 64-bit
device lanes — same convention as merge_intersect; 32-bit device keys pass
hi=0). Runs are padded to a power-of-two length R with +INF sentinels
(hi=INT32_MAX, lo=unsigned max), which sort after every real key, so the
merged output carries its sentinels as a contiguous tail.

Each count is a branchless binary-search descent over one run: log2(R)
fori steps plus one final adjust, vectorized across a (BLOCK,) element
tile; the full (K, R) key lanes stay VMEM-resident across the grid
(ops.py enforces the documented VMEM cap and falls back to the jnp
reference beyond it). Work per element is K*log2(R) compares vs log2(K*R)
full data movements for the sort — and the payload columns never enter
the kernel at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

BLOCK = 1024
SIGN = -0x80000000  # int32 sign bit, as a weak-typed Python literal


def _as_unsigned_order(lo):
    """Order-preserving signed image of a uint32 bit pattern:
    u(a) < u(b)  <=>  (a ^ SIGN) < (b ^ SIGN)."""
    return lo ^ SIGN


def _count_rank(b_hi, b_lo, x_hi, x_lo, tie_wins, r: int):
    """Per-element count of run entries ordered before x.

    b_* (R,) sorted ascending by (hi, lo-unsigned); x_* (BLOCK,) probe
    keys; tie_wins: scalar bool — equal keys in this run count as before x
    (the stable earlier-run-wins tie-break). Branchless descent: after
    log2(R) halving steps plus one final adjust, pos = the count."""
    n_steps = max(r.bit_length() - 1, 0)  # r is a power of two
    pos = jnp.zeros(x_hi.shape, jnp.int32)

    def before(cand):
        ch = jnp.take(b_hi, cand, axis=0)
        cl = jnp.take(b_lo, cand, axis=0)
        lt = (ch < x_hi) | ((ch == x_hi) & (cl < x_lo))
        eq = (ch == x_hi) & (cl == x_lo)
        return lt | (eq & tie_wins)

    def step(s, pos):
        half = jnp.int32(r) >> (s + 1)
        return jnp.where(before(pos + half - 1), pos + half, pos)

    pos = lax.fori_loop(0, n_steps, step, pos)
    return pos + before(pos).astype(jnp.int32)


def _kernel(tile_hi_ref, tile_lo_ref, runs_hi_ref, runs_lo_ref, rank_ref, *, k: int, r: int, block: int):
    j = pl.program_id(0)  # which run this tile belongs to
    tb = pl.program_id(1)  # tile index within the run
    x_hi = tile_hi_ref[0, :]
    x_lo = _as_unsigned_order(tile_lo_ref[0, :])
    # Own index within run j (duplicates within a run stay in order).
    own = tb * block + lax.broadcasted_iota(jnp.int32, (block, 1), 0).reshape(block)
    rank = own
    for i in range(k):  # static unroll: K is small (max_runs + 1)
        b_hi = runs_hi_ref[i, :]
        b_lo = _as_unsigned_order(runs_lo_ref[i, :])
        tie_wins = jnp.int32(i) < j
        cnt = _count_rank(b_hi, b_lo, x_hi, x_lo, tie_wins, r)
        rank = rank + jnp.where(jnp.int32(i) == j, 0, cnt)
    rank_ref[0, :] = rank


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def merge_ranks_pallas(runs_hi, runs_lo, *, interpret: bool = True, block: int = BLOCK):
    """runs_* (K, R) int32 lanes, each row sorted ascending by
    (hi, lo-unsigned) and +INF-sentinel padded; R a power of two with
    R % block == 0 (or R == block after clamping in ops.py). Returns
    int32 (K, R) output ranks — a permutation of [0, K*R)."""
    k, r = runs_hi.shape
    block = min(block, r)
    assert r % block == 0, (r, block)
    grid = (k, r // block)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, r=r, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda j, b: (j, b)),
            pl.BlockSpec((1, block), lambda j, b: (j, b)),
            pl.BlockSpec((k, r), lambda j, b: (0, 0)),
            pl.BlockSpec((k, r), lambda j, b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda j, b: (j, b)),
        out_shape=jax.ShapeDtypeStruct((k, r), jnp.int32),
        interpret=interpret,
    )(runs_hi, runs_lo, runs_hi, runs_lo)
