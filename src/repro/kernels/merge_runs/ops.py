"""Public k-way sorted-run merge — major compaction's data plane.

merge_sorted_runs: host entry point (numpy in/out) used by tables.py
tablet compaction on 64-bit packed keys. merge_sorted_device: traceable
form used per tablet inside the dist_ingest shard_map compaction program
on 32-bit rev_ts keys. Both compute output ranks (Pallas kernel / jnp
reference — identical results, asserted in tests) and scatter keys plus
payload columns in one pass; the payload never enters the rank kernel.

Backend policy matches the other store kernels: jnp reference on CPU,
Pallas on TPU, with a documented VMEM cap (the full key lanes stay
resident) falling back to the reference.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import pow2 as _pow2, split_key_lanes
from .merge_runs import merge_ranks_pallas
from .ref import merge_ranks_keys, merge_ranks_ref

# 2 lanes * 4 B * 1M keys = 8 MiB resident in VMEM.
MAX_VMEM_KEYS = 1 << 20

_SENTINEL64 = np.iinfo(np.int64).max


def merge_sorted_runs(
    runs: Sequence[Tuple[np.ndarray, np.ndarray]], backend: str = "auto"
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge K sorted (keys int64 [n_i], cols [n_i, w]) runs into one.

    Keys ascend within each run (duplicates allowed — the merge is stable
    in run order, matching the concat+stable-argsort it replaces). Returns
    (keys [n], cols [n, w]) with n = sum n_i.
    """
    runs = [(np.asarray(k, np.int64), np.asarray(c)) for k, c in runs]
    runs = [(k, c) for k, c in runs if k.size]
    if not runs:
        return np.empty(0, np.int64), np.empty((0, 0), np.int32)
    if len(runs) == 1:
        return runs[0]
    k = len(runs)
    w = runs[0][1].shape[1]
    col_dtype = runs[0][1].dtype
    n_total = sum(kk.size for kk, _ in runs)

    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend != "ref" and k * _pow2(max(kk.size for kk, _ in runs)) > MAX_VMEM_KEYS:
        backend = "ref"

    if backend == "ref":
        # CPU: the same rank computation with unpadded numpy searchsorted
        # (per-run lengths kept exact — no sentinel work, no dispatch).
        ranks = [
            np.arange(kk.size, dtype=np.int64)
            + sum(
                np.searchsorted(other, kk, side="right" if i < j else "left")
                for i, (other, _) in enumerate(runs)
                if i != j
            )
            for j, (kk, _) in enumerate(runs)
        ]
        out_keys = np.empty(n_total, np.int64)
        out_cols = np.empty((n_total, w), col_dtype)
        for (kk, cc), rk in zip(runs, ranks):
            out_keys[rk] = kk
            out_cols[rk] = cc
        return out_keys, out_cols

    # Pallas: sentinel-pad to a (k, pow2) grid of (hi, lo) lanes.
    r = _pow2(max(kk.size for kk, _ in runs))
    keys_pad = np.full((k, r), _SENTINEL64, np.int64)
    cols_pad = np.zeros((k, r, w), col_dtype)
    for i, (kk, cc) in enumerate(runs):
        keys_pad[i, : kk.size] = kk
        cols_pad[i, : kk.size] = cc
    hi, lo = split_key_lanes(keys_pad.reshape(-1))
    interpret = jax.default_backend() != "tpu"
    ranks = np.asarray(
        merge_ranks_pallas(
            jnp.asarray(hi.reshape(k, r)), jnp.asarray(lo.reshape(k, r)), interpret=interpret
        )
    )
    # Scatter epilogue: ranks are a permutation of [0, k*r); sentinels
    # land as a contiguous tail past n_total and are sliced away.
    flat = ranks.reshape(-1)
    out_keys = np.empty(k * r, np.int64)
    out_keys[flat] = keys_pad.reshape(-1)
    out_cols = np.empty((k * r, w), col_dtype)
    out_cols[flat] = cols_pad.reshape(-1, w)
    return out_keys[:n_total], out_cols[:n_total]


def merge_pair_device(a_keys, a_cols, b_keys, b_cols, backend: str = "auto"):
    """Resumable 2-way merge: fold ONE sorted run into a base, traceable
    (jit / shard_map safe) — the entry point of incremental major
    compaction (DistIngestPlane.compact_step folds one run slot per call,
    so the preemption unit is one of these merges instead of the whole
    k-way fold).

    a_keys (Ca,), b_keys (Cb,): each sorted ascending with the dtype-max
    sentinel past the live fill (callers mask stale slots first); a_cols
    (Ca, W) / b_cols (Cb, W) travel with their keys. Returns the merged
    (Ca+Cb,) keys and (Ca+Cb, W) cols — all real keys first (stable:
    a-side wins ties), sentinels as a contiguous tail. Backend policy is
    merge_sorted_device's (jnp reference on CPU, Pallas ranks on TPU)."""
    ca, cb = a_keys.shape[0], b_keys.shape[0]
    w = a_cols.shape[-1]
    l2 = _pow2(max(ca, cb))
    sentinel = jnp.asarray(jnp.iinfo(a_keys.dtype).max, a_keys.dtype)
    pk = jnp.full((2, l2), sentinel, a_keys.dtype)
    pk = pk.at[0, :ca].set(a_keys).at[1, :cb].set(b_keys)
    pc = jnp.zeros((2, l2, w), a_cols.dtype)
    pc = pc.at[0, :ca].set(a_cols).at[1, :cb].set(b_cols)
    mk, mc = merge_sorted_device(pk, pc, backend=backend)
    return mk[: ca + cb], mc[: ca + cb]


def merge_window_keys(keys, start: int, length: int):
    """Windowed (rank-resumable) form of the k-way merge: output ranks
    [start, start+length) only. keys (K, R) sorted ascending per row,
    sentinel-padded. Concatenating consecutive windows reproduces the
    full merged key sequence exactly (asserted in tests) — the
    finer-than-one-run preemption granularity available if a single
    base+run fold ever outgrows its stall budget. Ranks come from the
    same computation both backends share, so the window content never
    depends on backend."""
    from .ref import merge_ranks_keys

    ranks = merge_ranks_keys(keys).reshape(-1)
    sentinel = jnp.asarray(jnp.iinfo(keys.dtype).max, keys.dtype)
    in_win = (ranks >= start) & (ranks < start + length)
    dest = jnp.where(in_win, ranks - start, jnp.int32(length))
    return jnp.full((length,), sentinel, keys.dtype).at[dest].set(
        keys.reshape(-1), mode="drop"
    )


def _device_lanes(run_keys):
    """Split device-tablet keys into the (hi, lo) int32 lane pair the Pallas
    rank kernel consumes. int32 keys (event tablets: non-negative rev_ts,
    INT32_MAX sentinel) ride the lo lane with hi = 0 — signed and unsigned
    order coincide for non-negative values, and the sentinel stays maximal.
    int64 keys (index/aggregate tablets: packed 62-bit keys, INT64_MAX
    sentinel) split exactly like the host path."""
    if run_keys.dtype == jnp.int32:
        return jnp.zeros_like(run_keys), run_keys
    hi = (run_keys >> 32).astype(jnp.int32)
    lo = (run_keys & 0xFFFFFFFF).astype(jnp.uint32).astype(jnp.int32)
    return hi, lo


def merge_sorted_device(run_keys, run_cols, backend: str = "auto"):
    """Traceable k-way merge for device tablets (jit / shard_map safe).

    run_keys (K, R) int32 or int64: each row sorted ascending, padded with
    the dtype-max sentinel. run_cols (K, R, F) payload (F may be 0).
    Returns the merged (K*R,) keys and (K*R, F) cols — sentinels as a
    contiguous tail.

    Backend policy matches merge_sorted_runs: jnp searchsorted reference on
    CPU, the Pallas rank kernel on TPU (interpret elsewhere), with the
    VMEM-resident key-lane cap falling back to the reference. Ranks are
    identical between backends (asserted in tests), so the choice never
    changes results.
    """
    k, r = run_keys.shape
    f = run_cols.shape[-1]
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    r2 = _pow2(r)
    if backend != "ref" and k * r2 > MAX_VMEM_KEYS:
        backend = "ref"
    if backend == "ref":
        ranks = merge_ranks_keys(run_keys).reshape(-1)
        out_keys = jnp.zeros((k * r,), run_keys.dtype).at[ranks].set(run_keys.reshape(-1))
        out_cols = jnp.zeros((k * r, f), run_cols.dtype).at[ranks].set(run_cols.reshape(k * r, f))
        return out_keys, out_cols
    # Sentinel-pad each run to a power of two: added sentinels sort after
    # every real key, so real ranks are unchanged and sentinels (original
    # and pad) fill the permutation's tail. Scatter at the padded length,
    # then slice — real keys all rank below k*r, so the slice recovers the
    # unpadded contract exactly.
    sentinel = jnp.asarray(jnp.iinfo(run_keys.dtype).max, run_keys.dtype)
    padded = jnp.full((k, r2), sentinel, run_keys.dtype).at[:, :r].set(run_keys)
    padded_cols = jnp.zeros((k, r2, f), run_cols.dtype).at[:, :r].set(run_cols)
    hi, lo = _device_lanes(padded)
    interpret = jax.default_backend() != "tpu"
    ranks = merge_ranks_pallas(hi, lo, interpret=interpret).reshape(-1)
    out_keys = jnp.full((k * r2,), sentinel, run_keys.dtype).at[ranks].set(padded.reshape(-1))
    out_cols = jnp.zeros((k * r2, f), run_cols.dtype).at[ranks].set(padded_cols.reshape(k * r2, f))
    return out_keys[: k * r], out_cols[: k * r]
