"""Public k-way sorted-run merge — major compaction's data plane.

merge_sorted_runs: host entry point (numpy in/out) used by tables.py
tablet compaction on 64-bit packed keys. merge_sorted_device: traceable
form used per tablet inside the dist_ingest shard_map compaction program
on 32-bit rev_ts keys. Both compute output ranks (Pallas kernel / jnp
reference — identical results, asserted in tests) and scatter keys plus
payload columns in one pass; the payload never enters the rank kernel.

Backend policy matches the other store kernels: jnp reference on CPU,
Pallas on TPU, with a documented VMEM cap (the full key lanes stay
resident) falling back to the reference.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..common import split_key_lanes
from .merge_runs import merge_ranks_pallas
from .ref import merge_ranks_keys, merge_ranks_ref

# 2 lanes * 4 B * 1M keys = 8 MiB resident in VMEM.
MAX_VMEM_KEYS = 1 << 20

_SENTINEL64 = np.iinfo(np.int64).max


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def merge_sorted_runs(
    runs: Sequence[Tuple[np.ndarray, np.ndarray]], backend: str = "auto"
) -> Tuple[np.ndarray, np.ndarray]:
    """Merge K sorted (keys int64 [n_i], cols [n_i, w]) runs into one.

    Keys ascend within each run (duplicates allowed — the merge is stable
    in run order, matching the concat+stable-argsort it replaces). Returns
    (keys [n], cols [n, w]) with n = sum n_i.
    """
    runs = [(np.asarray(k, np.int64), np.asarray(c)) for k, c in runs]
    runs = [(k, c) for k, c in runs if k.size]
    if not runs:
        return np.empty(0, np.int64), np.empty((0, 0), np.int32)
    if len(runs) == 1:
        return runs[0]
    k = len(runs)
    w = runs[0][1].shape[1]
    col_dtype = runs[0][1].dtype
    n_total = sum(kk.size for kk, _ in runs)

    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend != "ref" and k * _pow2(max(kk.size for kk, _ in runs)) > MAX_VMEM_KEYS:
        backend = "ref"

    if backend == "ref":
        # CPU: the same rank computation with unpadded numpy searchsorted
        # (per-run lengths kept exact — no sentinel work, no dispatch).
        ranks = [
            np.arange(kk.size, dtype=np.int64)
            + sum(
                np.searchsorted(other, kk, side="right" if i < j else "left")
                for i, (other, _) in enumerate(runs)
                if i != j
            )
            for j, (kk, _) in enumerate(runs)
        ]
        out_keys = np.empty(n_total, np.int64)
        out_cols = np.empty((n_total, w), col_dtype)
        for (kk, cc), rk in zip(runs, ranks):
            out_keys[rk] = kk
            out_cols[rk] = cc
        return out_keys, out_cols

    # Pallas: sentinel-pad to a (k, pow2) grid of (hi, lo) lanes.
    r = _pow2(max(kk.size for kk, _ in runs))
    keys_pad = np.full((k, r), _SENTINEL64, np.int64)
    cols_pad = np.zeros((k, r, w), col_dtype)
    for i, (kk, cc) in enumerate(runs):
        keys_pad[i, : kk.size] = kk
        cols_pad[i, : kk.size] = cc
    hi, lo = split_key_lanes(keys_pad.reshape(-1))
    interpret = jax.default_backend() != "tpu"
    ranks = np.asarray(
        merge_ranks_pallas(
            jnp.asarray(hi.reshape(k, r)), jnp.asarray(lo.reshape(k, r)), interpret=interpret
        )
    )
    # Scatter epilogue: ranks are a permutation of [0, k*r); sentinels
    # land as a contiguous tail past n_total and are sliced away.
    flat = ranks.reshape(-1)
    out_keys = np.empty(k * r, np.int64)
    out_keys[flat] = keys_pad.reshape(-1)
    out_cols = np.empty((k * r, w), col_dtype)
    out_cols[flat] = cols_pad.reshape(-1, w)
    return out_keys[:n_total], out_cols[:n_total]


def merge_sorted_device(run_keys, run_cols):
    """Traceable k-way merge for device tablets (jit / shard_map safe).

    run_keys (K, R) int32: each row sorted ascending, padded with the
    int32-max sentinel. run_cols (K, R, F) payload. Returns the merged
    (K*R,) keys and (K*R, F) cols — sentinels as a contiguous tail.
    """
    k, r = run_keys.shape
    f = run_cols.shape[-1]
    ranks = merge_ranks_keys(run_keys).reshape(-1)
    out_keys = jnp.zeros((k * r,), run_keys.dtype).at[ranks].set(run_keys.reshape(-1))
    out_cols = jnp.zeros((k * r, f), run_cols.dtype).at[ranks].set(run_cols.reshape(-1, f))
    return out_keys, out_cols
