from .ops import (  # noqa: F401
    MAX_VMEM_KEYS,
    merge_pair_device,
    merge_sorted_device,
    merge_sorted_runs,
    merge_window_keys,
)
from .ref import merge_ranks_keys, merge_ranks_ref  # noqa: F401
from .merge_runs import merge_ranks_pallas  # noqa: F401
