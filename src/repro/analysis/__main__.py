"""CLI: ``python -m repro.analysis [paths...] [options]``.

Exit status 0 iff there are no fresh (non-baselined) findings, no stale
baseline entries, and no parse errors — the CI contract.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .engine import (
    all_rules,
    default_baseline_path,
    load_baseline,
    render_json,
    render_text,
    run_analysis,
)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint — repo-native static analysis (see docs/static_analysis.md)",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=["src"],
        help="files or directories to analyze (default: src)",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt"
    )
    parser.add_argument(
        "--baseline",
        default=default_baseline_path(),
        help="baseline.json path (default: the checked-in analysis/baseline.json)",
    )
    parser.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline: report every finding as fresh",
    )
    parser.add_argument(
        "--allow-stale-baseline",
        action="store_true",
        help="do not fail on baseline entries that match no finding",
    )
    parser.add_argument(
        "--verbose",
        action="store_true",
        help="text format: also print baselined findings",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name}: {rule.description}")
        return 0

    baseline = None if args.no_baseline else load_baseline(args.baseline)
    result = run_analysis(args.paths, baseline=baseline)
    if args.allow_stale_baseline:
        result.stale_baseline = []
    print(render_json(result) if args.fmt == "json" else render_text(result, args.verbose))
    return 1 if result.failed else 0


if __name__ == "__main__":
    sys.exit(main())
