"""reprolint — repo-native static analysis for the repro codebase.

An AST-based pass that machine-checks the concurrency and hot-path
invariants the distributed planes rely on (see docs/static_analysis.md
for the rule catalog):

  guarded-by            lock discipline on annotated shared fields
  no-sync-in-hot-path   hidden device syncs in latency-critical paths
  jit-purity            no host side effects inside traced functions
  no-donate-in-plane    publish() aliasing forbids buffer donation
  kernel-contract       every Pallas kernel ships a matching reference

Run as ``python -m repro.analysis [paths...]``; CI gates on it. Findings
are suppressed inline with ``# reprolint: disable=<rule>`` or
grandfathered (with a justification) in ``analysis/baseline.json``.
"""
from .engine import (  # noqa: F401
    AnalysisResult,
    Baseline,
    FileContext,
    Finding,
    all_rules,
    collect_files,
    load_baseline,
    render_json,
    render_text,
    run_analysis,
)

__all__ = [
    "AnalysisResult",
    "Baseline",
    "FileContext",
    "Finding",
    "all_rules",
    "collect_files",
    "load_baseline",
    "render_json",
    "render_text",
    "run_analysis",
]
