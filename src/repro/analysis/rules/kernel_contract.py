"""kernel-contract: every Pallas kernel package ships a checked reference.

The kernel inventory's value is the exact-agreement story: each
``kernels/<name>/`` package pairs its Pallas entry point with a
jnp/numpy reference the tests oracle against. The rule enforces the
package shape so a new kernel cannot silently skip it:

  * ``ops.py`` and ``ref.py`` must both exist;
  * the package ``__init__`` must re-export from BOTH ``.ops`` and
    ``.ref`` (callers and tests import the pair from one place);
  * every public ``<stem>_pallas`` function must have a ``<stem>_ref``
    whose positional parameter names match exactly (keyword-only knobs
    like ``interpret=``/block sizes are implementation detail and are
    ignored);
  * shared helpers (top-level defs of ``kernels/common.py`` and
    ``kernels/program_eval.py``, e.g. ``pow2``, ``split_key_lanes``,
    ``program_eval_rows``) must be imported, not re-implemented — names
    compare with leading underscores stripped, so a private ``_pow2``
    clone is still caught.

This is a project rule: it needs the package view, and anchors package-
level findings on the package ``__init__.py``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..engine import FileContext, Finding, ProjectRule

RULE = "kernel-contract"

_SHARED_MODULES = ("common.py", "program_eval.py")


def _positional_params(fn: ast.AST) -> Tuple[str, ...]:
    a = fn.args
    return tuple(p.arg for p in (*a.posonlyargs, *a.args))


def _top_level_defs(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _parse(path: str) -> Optional[ast.Module]:
    try:
        with open(path, "r", encoding="utf-8") as fh:
            return ast.parse(fh.read(), filename=path)
    except (OSError, SyntaxError, ValueError):
        return None


class KernelContractRule(ProjectRule):
    name = RULE
    description = (
        "kernels/<name>/ must ship ops.py + ref.py with matching "
        "<stem>_pallas/<stem>_ref signatures, export both, and import "
        "shared helpers instead of re-implementing them"
    )

    def check_file(self, ctx: FileContext) -> List[Finding]:
        return []

    def check_project(self, ctxs: Sequence[FileContext]) -> List[Finding]:
        # A kernel package = a directory whose PARENT is named 'kernels'
        # and which contains an __init__.py, discovered from the scanned
        # file set (so the rule follows whatever tree it is pointed at).
        packages: Dict[str, FileContext] = {}
        ctx_by_abs: Dict[str, FileContext] = {}
        for ctx in ctxs:
            ap = os.path.abspath(ctx.path)
            ctx_by_abs[ap] = ctx
            d = os.path.dirname(ap)
            if os.path.basename(os.path.dirname(d)) == "kernels":
                pkg_init = os.path.join(d, "__init__.py")
                if os.path.exists(pkg_init):
                    packages.setdefault(d, None)
        findings: List[Finding] = []
        for pkg_dir in sorted(packages):
            findings.extend(self._check_package(pkg_dir, ctx_by_abs))
        return findings

    # ------------------------------------------------------------------
    def _ctx_or_parse(
        self, path: str, ctx_by_abs: Dict[str, FileContext]
    ) -> Tuple[Optional[FileContext], Optional[ast.Module]]:
        ctx = ctx_by_abs.get(os.path.abspath(path))
        if ctx is not None:
            return ctx, ctx.tree
        return None, _parse(path)

    def _check_package(
        self, pkg_dir: str, ctx_by_abs: Dict[str, FileContext]
    ) -> List[Finding]:
        findings: List[Finding] = []
        pkg = os.path.basename(pkg_dir)
        init_path = os.path.join(pkg_dir, "__init__.py")
        init_ctx, init_tree = self._ctx_or_parse(init_path, ctx_by_abs)

        def pkg_finding(message: str, ctx=None, node_or_line=1) -> Finding:
            if ctx is not None:
                return ctx.finding(RULE, node_or_line, message)
            # Anchor on the __init__ when the offending file is not in
            # the scanned set (or does not exist).
            anchor = init_ctx
            if anchor is not None:
                return anchor.finding(RULE, 1, message)
            return Finding(RULE, init_path, 1, message, snippet=f"kernels/{pkg}")

        # (a) ops.py + ref.py exist
        ops_path = os.path.join(pkg_dir, "ops.py")
        ref_path = os.path.join(pkg_dir, "ref.py")
        for req in (ops_path, ref_path):
            if not os.path.exists(req):
                findings.append(
                    pkg_finding(
                        f"kernel package '{pkg}' is missing {os.path.basename(req)} "
                        "— every kernel ships a Pallas entry point (ops.py) AND a "
                        "jnp/numpy reference (ref.py) the tests oracle against"
                    )
                )
        if not (os.path.exists(ops_path) and os.path.exists(ref_path)):
            return findings

        # (b) __init__ exports from both .ops and .ref
        if init_tree is not None:
            modules = {
                node.module
                for node in ast.walk(init_tree)
                if isinstance(node, ast.ImportFrom) and node.level >= 1
            }
            for missing in {"ops", "ref"} - modules:
                findings.append(
                    pkg_finding(
                        f"kernel package '{pkg}' __init__ does not re-export from "
                        f".{missing} — callers and tests import the pallas/ref "
                        "pair from the package root",
                        ctx=init_ctx,
                        node_or_line=1,
                    )
                )

        # (c) signature parity: <stem>_pallas in any package module needs a
        # <stem>_ref in ref.py with identical positional parameter names.
        ref_ctx, ref_tree = self._ctx_or_parse(ref_path, ctx_by_abs)
        refs: Dict[str, Tuple[str, ...]] = {}
        if ref_tree is not None:
            for fn in _top_level_defs(ref_tree):
                refs[fn.name] = _positional_params(fn)
        module_files = sorted(
            f
            for f in os.listdir(pkg_dir)
            if f.endswith(".py") and f not in {"__init__.py", "ref.py"}
        )
        for fname in module_files:
            fpath = os.path.join(pkg_dir, fname)
            mctx, mtree = self._ctx_or_parse(fpath, ctx_by_abs)
            if mtree is None:
                continue
            for fn in _top_level_defs(mtree):
                if not fn.name.endswith("_pallas") or fn.name.startswith("_"):
                    continue
                stem = fn.name[: -len("_pallas")]
                ref_name = f"{stem}_ref"
                if ref_name not in refs:
                    findings.append(
                        pkg_finding(
                            f"'{fn.name}' has no '{ref_name}' in ref.py — every "
                            "Pallas entry point pairs with a reference "
                            "implementation of the same public signature",
                            ctx=mctx,
                            node_or_line=fn,
                        )
                    )
                elif refs[ref_name] != _positional_params(fn):
                    findings.append(
                        pkg_finding(
                            f"'{fn.name}' positional params "
                            f"{_positional_params(fn)} != '{ref_name}' params "
                            f"{refs[ref_name]} — the pallas/ref pair must agree "
                            "so oracle tests can call either interchangeably",
                            ctx=mctx,
                            node_or_line=fn,
                        )
                    )

        # (d) no re-implementation of shared kernel helpers
        kernels_dir = os.path.dirname(pkg_dir)
        shared: Set[str] = set()
        for mod in _SHARED_MODULES:
            tree = _parse(os.path.join(kernels_dir, mod))
            if tree is not None:
                shared.update(fn.name.lstrip("_") for fn in _top_level_defs(tree))
        if shared:
            for fname in sorted(
                f for f in os.listdir(pkg_dir) if f.endswith(".py")
            ):
                fpath = os.path.join(pkg_dir, fname)
                mctx, mtree = self._ctx_or_parse(fpath, ctx_by_abs)
                if mtree is None:
                    continue
                for fn in _top_level_defs(mtree):
                    if fn.name.lstrip("_") in shared:
                        findings.append(
                            pkg_finding(
                                f"'{fn.name}' re-implements shared kernel helper "
                                f"'{fn.name.lstrip('_')}' — import it from "
                                "kernels/common.py / kernels/program_eval.py "
                                "instead of cloning it per package",
                                ctx=mctx,
                                node_or_line=fn,
                            )
                        )
        return findings
