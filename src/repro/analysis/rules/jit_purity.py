"""jit-purity: functions handed to the JAX tracers must be pure.

Any function passed (positionally, via ``functools.partial``, or as a
decorator) to ``jax.jit`` / ``shard_map`` / ``jax.pmap`` /
``pl.pallas_call`` is traced: its Python body runs ONCE at trace time,
so host side effects either vanish on the cached path or — worse —
leak trace-time garbage into live state. The rule resolves the callee
through the lexical scopes of the file and flags, inside its body (and
nested helpers):

  * assignments to ``self.<attr>``        — trace-time object mutation
  * calls into ``time.*`` / ``random.*`` / ``np.random.*`` — host
    nondeterminism baked into the trace (``jax.random`` is fine: keys
    are explicit)
  * mutation of closed-over host containers — ``xs.append(...)``,
    ``d[k] = v``, ``s.add(...)`` etc. where the receiver is a free
    variable of the traced function (locals and parameters are fine)

Only callees defined in the same file are checked (a Name that resolves
to an import or a runtime-built closure is skipped — dynamic tests cover
those); that keeps the rule zero-false-positive on idiomatic code.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..engine import FileContext, Finding, Rule
from .common import base_name, dotted_name, imported_names, local_names

RULE = "jit-purity"

_TRACER_LASTS = {"jit", "shard_map", "pmap", "pallas_call"}
_IMPURE_PREFIXES = ("time.", "random.", "np.random.", "numpy.random.")
_MUTATORS = {
    "append",
    "extend",
    "insert",
    "add",
    "update",
    "setdefault",
    "pop",
    "popitem",
    "remove",
    "discard",
    "clear",
    "appendleft",
    "extendleft",
}


def _is_tracer(name: Optional[str]) -> bool:
    return bool(name) and name.split(".")[-1] in _TRACER_LASTS


def _traced_arg(call: ast.Call) -> Optional[ast.AST]:
    """The function argument of a tracer call, unwrapping partial(...)."""
    if not call.args:
        return None
    arg = call.args[0]
    if isinstance(arg, ast.Call):
        inner = dotted_name(arg.func)
        if inner and inner.split(".")[-1] == "partial" and arg.args:
            return arg.args[0]
        return None
    return arg


class JitPurityRule(Rule):
    name = RULE
    description = (
        "functions traced by jax.jit/shard_map/pmap/pallas_call must not "
        "assign self.*, call time./random., or mutate closed-over containers"
    )

    def check_file(self, ctx: FileContext) -> List[Finding]:
        findings: List[Finding] = []
        checked: Set[int] = set()  # id() of FunctionDefs already checked

        def walk_scope(body, scopes: List[Dict[str, ast.AST]]) -> None:
            scope: Dict[str, ast.AST] = {}
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    scope[node.name] = node
            frames = scopes + [scope]

            def resolve(name: str) -> Optional[ast.AST]:
                for frame in reversed(frames):
                    if name in frame:
                        return frame[name]
                return None

            def scan(node: ast.AST) -> None:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        if _is_tracer(dotted_name(dec)) or (
                            isinstance(dec, ast.Call) and _is_tracer(dotted_name(dec.func))
                        ):
                            self._check_pure(ctx, node, findings, checked)
                    walk_scope(node.body, frames)
                    return
                if isinstance(node, ast.ClassDef):
                    walk_scope(node.body, frames)
                    return
                if isinstance(node, ast.Call) and _is_tracer(dotted_name(node.func)):
                    target = _traced_arg(node)
                    if isinstance(target, ast.Name):
                        fn = resolve(target.id)
                        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                            self._check_pure(ctx, fn, findings, checked)
                    elif isinstance(target, ast.Lambda):
                        self._check_pure(ctx, target, findings, checked)
                for child in ast.iter_child_nodes(node):
                    scan(child)

            for node in body:
                scan(node)

        walk_scope(ctx.tree.body, [])
        return findings

    # ------------------------------------------------------------------
    def _check_pure(
        self,
        ctx: FileContext,
        fn: ast.AST,
        findings: List[Finding],
        checked: Set[int],
    ) -> None:
        if id(fn) in checked:
            return
        checked.add(id(fn))
        name = getattr(fn, "name", "<lambda>")
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        # Module aliases (np, jnp, functools...) are never "closed-over
        # containers" — treat them as bound.
        bound = local_names(fn) | imported_names(ctx.tree)
        self._scan_body(ctx, name, body, bound, findings)

    def _scan_body(
        self,
        ctx: FileContext,
        name: str,
        body: List[ast.AST],
        bound: Set[str],
        findings: List[Finding],
    ) -> None:
        def flag(node: ast.AST, what: str) -> None:
            findings.append(
                ctx.finding(RULE, node, f"traced function '{name}' {what}")
            )

        def scan(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested helper: traced too; its locals shadow, outer
                # locals become part of its (allowed) closure only if
                # they are OUR locals — keep them in `bound`.
                from .common import local_names as _ln

                self._scan_body(ctx, name, node.body, bound | _ln(node), findings)
                return
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) else [node.target]
                for tgt in targets:
                    for leaf in ast.walk(tgt):
                        if (
                            isinstance(leaf, ast.Attribute)
                            and isinstance(leaf.value, ast.Name)
                            and leaf.value.id == "self"
                            and isinstance(leaf.ctx, ast.Store)
                        ):
                            flag(leaf, f"assigns 'self.{leaf.attr}' at trace time")
                        elif isinstance(leaf, ast.Subscript) and isinstance(
                            leaf.ctx, ast.Store
                        ):
                            root = base_name(leaf.value)
                            if root and root not in bound and root != "self":
                                flag(
                                    leaf,
                                    f"mutates closed-over container '{root}' via "
                                    "subscript store",
                                )
            if isinstance(node, ast.Call):
                dn = dotted_name(node.func)
                if dn and dn.startswith(_IMPURE_PREFIXES):
                    flag(node, f"calls host-impure '{dn}' (runs once at trace time)")
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATORS
                ):
                    root = base_name(node.func.value)
                    if (
                        root
                        and root not in bound
                        and root != "self"
                        and isinstance(node.func.value, ast.Name)
                    ):
                        flag(
                            node,
                            f"mutates closed-over container '{root}."
                            f"{node.func.attr}(...)'",
                        )
            for child in ast.iter_child_nodes(node):
                scan(child)

        for stmt in body:
            scan(stmt)
