"""no-donate-in-plane: the plane programs must never donate buffers.

``DistIngestPlane.publish()`` (PR 4) hands out ZERO-COPY snapshots: the
published DistStore aliases the plane's sealed device buffers, and every
in-flight QueryRun pins such a snapshot for its whole lifetime. A jitted
step compiled with ``donate_argnums``/``donate_argnames`` lets XLA
reuse an input buffer for its output — which would scribble over arrays
a published snapshot still reads. The single allowed donation (the
append step's memtable slab, which publish() never aliases — it seals a
sorted COPY) carries an inline suppression with its justification; any
new donation in ``core/dist_ingest.py`` / ``core/dist_query.py`` is a
correctness bug until proven otherwise.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import FileContext, Finding, Rule, norm_path

RULE = "no-donate-in-plane"

_PLANE_FILES = {"repro/core/dist_ingest.py", "repro/core/dist_query.py"}
_DONATE_KEYWORDS = {"donate_argnums", "donate_argnames"}


class NoDonateInPlaneRule(Rule):
    name = RULE
    description = (
        "donate_argnums/donate_argnames are forbidden in the plane modules — "
        "publish() zero-copy snapshots alias plane buffers"
    )

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if norm_path(ctx.path) not in _PLANE_FILES:
            return []
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg in _DONATE_KEYWORDS:
                    findings.append(
                        ctx.finding(
                            RULE,
                            kw.value,
                            f"'{kw.arg}' in a plane program: published snapshots "
                            "alias plane buffers zero-copy, so donation lets XLA "
                            "overwrite arrays an in-flight query still reads",
                        )
                    )
        return findings
