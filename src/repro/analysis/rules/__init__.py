"""Rule registry. Order here is report order for equal file:line."""
from .guarded_by import GuardedByRule
from .hot_path import HotPathSyncRule
from .jit_purity import JitPurityRule
from .kernel_contract import KernelContractRule
from .no_donate import NoDonateInPlaneRule

REGISTRY = [
    GuardedByRule,
    HotPathSyncRule,
    JitPurityRule,
    NoDonateInPlaneRule,
    KernelContractRule,
]

__all__ = [
    "REGISTRY",
    "GuardedByRule",
    "HotPathSyncRule",
    "JitPurityRule",
    "NoDonateInPlaneRule",
    "KernelContractRule",
]
