"""no-sync-in-hot-path: hidden device syncs in latency-critical code.

Tag a function hot with ``# reprolint: hot-path`` on (or directly above)
its ``def`` line — the dist_query step paths and the serve_db turn path
carry the tag. Inside a hot function (nested defs inherit), the rule
flags the host-device sync points that silently serialize the pipeline:

  * ``x.item()``                        — always a blocking device->host copy
  * ``jax.block_until_ready(x)``        — an explicit wait that bypasses span
                                          accounting; use ``sp.fence(x)`` on an
                                          open span so the wait is charged as
                                          device time
  * ``np.asarray(x)`` / ``jax.device_get(x)`` — device->host materialization,
                                          allowed only on an already-fenced
                                          value (``np.asarray(sp.fence(x))``)
  * ``float(f(...))`` / ``int(f(...))`` / ``bool(f(...))`` — coercing a call
                                          result forces the sync inline;
                                          fence it first (``int(sp.fence(...))``)

The scalar-coercion check only fires when the operand is itself a call
(the common ``int(step(...))`` shape); coercing an already-materialized
name (``int(total)`` after ``total = sp.fence(...)``) is clean.
"""
from __future__ import annotations

import ast
from typing import List

from ..engine import FileContext, Finding, Rule
from .common import dotted_name, is_fence_call

RULE = "no-sync-in-hot-path"

_MATERIALIZERS = {"np.asarray", "numpy.asarray", "jax.device_get"}
_BLOCKERS = {"jax.block_until_ready", "block_until_ready"}
_COERCIONS = {"float", "int", "bool"}


class HotPathSyncRule(Rule):
    name = RULE
    description = (
        "no .item()/block_until_ready/np.asarray/scalar-coercion syncs inside "
        "'# reprolint: hot-path' functions unless wrapped in sp.fence(...)"
    )

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if not ctx.hot_lines:
            return []
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if ctx.is_hot_def(node):
                    self._check_hot(ctx, node, findings)
        return findings

    def _check_hot(self, ctx: FileContext, fn: ast.AST, findings: List[Finding]) -> None:
        # ast.walk descends into nested defs too — they run on the same
        # hot path unless they are separately (not) tagged; inherit.
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = dotted_name(func)
            if isinstance(func, ast.Attribute) and func.attr == "item":
                findings.append(
                    ctx.finding(
                        RULE,
                        node,
                        ".item() blocks on the device inside a hot path — "
                        "materialize via np.asarray(sp.fence(x)) once, outside "
                        "the per-step loop if possible",
                    )
                )
            elif name in _BLOCKERS:
                findings.append(
                    ctx.finding(
                        RULE,
                        node,
                        "bare block_until_ready in a hot path bypasses span "
                        "accounting — use sp.fence(x) on the enclosing span so "
                        "the wait is charged as device time",
                    )
                )
            elif name in _MATERIALIZERS:
                if not (node.args and is_fence_call(node.args[0])):
                    findings.append(
                        ctx.finding(
                            RULE,
                            node,
                            f"{name}(...) on a device value syncs inline in a hot "
                            "path — fence it first: "
                            f"{name}(sp.fence(...))",
                        )
                    )
            elif (
                isinstance(func, ast.Name)
                and func.id in _COERCIONS
                and len(node.args) == 1
                and isinstance(node.args[0], ast.Call)
                and not is_fence_call(node.args[0])
            ):
                findings.append(
                    ctx.finding(
                        RULE,
                        node,
                        f"{func.id}(...) on a call result forces a device sync in "
                        f"a hot path — fence it: {func.id}(sp.fence(...))",
                    )
                )
