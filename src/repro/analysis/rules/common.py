"""Small AST helpers shared by the reprolint rules."""
from __future__ import annotations

import ast
from typing import Optional

#: with-item methods that take/hold a lock when called on one
LOCK_CALL_METHODS = {"hold", "reowner", "acquire"}


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_path(node: ast.AST) -> Optional[str]:
    """For an attribute chain rooted at ``self``, the path after it
    (``self.scheduler._cv`` -> ``scheduler._cv``); else None."""
    name = dotted_name(node)
    if name and name.startswith("self."):
        return name[len("self."):]
    return None


def lock_path_of_with_item(expr: ast.AST) -> Optional[str]:
    """The lock a ``with`` item holds, as a self-relative path.

    Recognizes ``with self.<lock>:``, ``with self.<lock>.hold(o):``,
    ``with self.<lock>.reowner(o):`` and bare ``self.<lock>.acquire(...)``
    call forms. Returns e.g. ``_lock`` or ``scheduler._cv``.
    """
    if isinstance(expr, ast.Call):
        func = expr.func
        if isinstance(func, ast.Attribute) and func.attr in LOCK_CALL_METHODS:
            return self_path(func.value)
        return None
    return self_path(expr)


def is_fence_call(node: ast.AST) -> bool:
    """True for ``<anything>.fence(...)`` — a span-charged device wait."""
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "fence"
    )


def func_params(fn: ast.AST) -> set:
    a = fn.args
    names = set()
    for group in (a.posonlyargs, a.args, a.kwonlyargs):
        names.update(p.arg for p in group)
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def local_names(fn: ast.AST) -> set:
    """Names bound inside ``fn``'s own scope: params plus every Store-ctx
    Name, loop/with/comprehension target, and nested def/class name.
    Nested function bodies are NOT descended into (they are their own
    scope)."""
    names = func_params(fn)

    def visit(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                names.add(child.name)
                continue  # own scope
            if isinstance(child, ast.Lambda):
                continue
            if isinstance(child, ast.Name) and isinstance(
                child.ctx, (ast.Store, ast.Del)
            ):
                names.add(child.id)
            visit(child)

    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        visit(stmt)
        if isinstance(stmt, ast.Name) and isinstance(stmt.ctx, (ast.Store, ast.Del)):
            names.add(stmt.id)
    return names


def imported_names(tree: ast.AST) -> set:
    """Every name an import statement binds anywhere in the module —
    used to keep module aliases (np, jnp, jax...) out of the
    closed-over-container mutation check."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                names.add(alias.asname or alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                names.add(alias.asname or alias.name)
    return names


def base_name(node: ast.AST) -> Optional[str]:
    """The root Name of an attribute/subscript chain (``a.b[c].d`` -> a)."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None
