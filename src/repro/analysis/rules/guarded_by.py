"""guarded-by: lock discipline on annotated shared fields.

Declare a field's lock with a trailing comment on the assignment that
introduces it (usually in ``__init__``)::

    self._fill = np.zeros(T, np.int64)  # guarded-by: _lock

From then on, every ``self._fill`` access anywhere in the class must be
(a) lexically inside ``with self._lock:`` / ``with self._lock.hold(o):``
/ ``with self._lock.reowner(o):``, or (b) inside a method annotated
``# holds: _lock`` (on the def line or the line above it) — the
annotation is the method's documented precondition, checked at its call
sites by eyeball and at its body by this rule. Dotted lock paths
(``# guarded-by: scheduler._cv``) are supported. ``__init__`` is exempt
(construction happens-before sharing), as is any line carrying a
``# guarded-by:`` declaration itself.

The rule is lexical: it cannot see locks taken by a caller (annotate the
callee with ``# holds:``) or callbacks invoked under a lock elsewhere
(suppress with a justification). That is the point — the annotation
makes the locking protocol reviewable text instead of tribal knowledge.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..engine import FileContext, Finding, Rule
from .common import lock_path_of_with_item, self_path

RULE = "guarded-by"


class GuardedByRule(Rule):
    name = RULE
    description = (
        "fields annotated '# guarded-by: <lock>' must be accessed under that "
        "lock or inside a method annotated '# holds: <lock>'"
    )

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if not ctx.guarded:
            return []
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(self._check_class(ctx, node))
        return findings

    # ------------------------------------------------------------------
    def _check_class(self, ctx: FileContext, cls: ast.ClassDef) -> List[Finding]:
        guarded: Dict[str, str] = {}  # field -> lock path
        decl_lines: Set[int] = set()
        # Pass 1: find guarded declarations (any self.X assignment whose
        # statement overlaps a '# guarded-by:' line).
        for node in ast.walk(cls):
            if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                continue
            span = range(node.lineno, (node.end_lineno or node.lineno) + 1)
            lock = next((ctx.guarded[ln] for ln in span if ln in ctx.guarded), None)
            if lock is None:
                continue
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for tgt in targets:
                path = self_path(tgt)
                if path is not None and "." not in path:
                    guarded[path] = lock
                    decl_lines.update(span)
        if not guarded:
            return []

        findings: List[Finding] = []
        seen: Set[tuple] = set()

        def flag(node: ast.Attribute, lock: str) -> None:
            key = (node.lineno, node.attr)
            if key in seen:
                return
            seen.add(key)
            findings.append(
                ctx.finding(
                    RULE,
                    node,
                    f"'self.{node.attr}' is guarded by 'self.{lock}' but accessed "
                    f"without holding it (wrap in `with self.{lock}` / "
                    f"`.hold(owner)`, or annotate the method `# holds: {lock}`)",
                )
            )

        def visit(node: ast.AST, held: Set[str]) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = held | ctx.holds_for_def(node)
                for child in node.body:
                    visit(child, inner)
                return
            if isinstance(node, (ast.With, ast.AsyncWith)):
                inner = set(held)
                for item in node.items:
                    lock = lock_path_of_with_item(item.context_expr)
                    if lock is not None:
                        inner.add(lock)
                    visit(item.context_expr, held)
                for child in node.body:
                    visit(child, inner)
                return
            if (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guarded
                and node.lineno not in decl_lines
            ):
                if guarded[node.attr] not in held:
                    flag(node, guarded[node.attr])
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        for stmt in cls.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if stmt.name == "__init__":
                    continue
                visit(stmt, set())
            elif isinstance(stmt, ast.ClassDef):
                continue  # nested classes have their own field namespace
            else:
                visit(stmt, set())
        return findings
