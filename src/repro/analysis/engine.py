"""reprolint engine: file walking, directive parsing, baselines, reporters.

The engine is deliberately small — rules do the real work. It owns the
pieces every rule shares:

  * ``FileContext`` — one parsed source file plus its comment directives
    (``# guarded-by:``, ``# holds:``, ``# reprolint: hot-path``,
    ``# reprolint: disable=...``), extracted per physical line so rules
    never re-scan source text.
  * ``Finding`` — rule id + file:line + message + the offending source
    line (the *fingerprint* used for baseline matching; line numbers
    churn, stripped line text rarely does).
  * Inline suppression — a finding whose line carries
    ``# reprolint: disable=<rule>[,<rule>...]`` (or ``disable=all``) is
    dropped before reporting.
  * ``Baseline`` — grandfathered findings checked into
    ``analysis/baseline.json``, each with a mandatory one-line
    justification. The baseline is a RATCHET: an entry that no longer
    matches any real finding is *stale* and fails the run, so the list
    only shrinks.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------------
# Comment-directive grammar. Directives attach to the physical line they sit
# on; rules decide which lines they consult (e.g. a ``def``'s directives may
# live on the def line or the line above it — see FileContext.def_lines).
# --------------------------------------------------------------------------
_DISABLE_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\- ]+)")
_HOT_RE = re.compile(r"#\s*reprolint:\s*hot-path\b")
_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w.]*)")
_HOLDS_RE = re.compile(r"#\s*holds:\s*([A-Za-z_][\w.]*(?:\s*,\s*[A-Za-z_][\w.]*)*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str  # as given on the command line (usually repo-relative)
    line: int  # 1-based
    message: str
    snippet: str = ""  # stripped source line — the baseline fingerprint

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, norm_path(self.path), self.snippet)


class FileContext:
    """A parsed source file plus its per-line reprolint directives."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.lines: List[str] = source.splitlines()
        self.tree = tree
        # line -> payload, all 1-based
        self.disable: Dict[int, Set[str]] = {}
        self.hot_lines: Set[int] = set()
        self.guarded: Dict[int, str] = {}
        self.holds: Dict[int, Tuple[str, ...]] = {}
        for i, text in enumerate(self.lines, start=1):
            if "#" not in text:
                continue
            m = _DISABLE_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.disable.setdefault(i, set()).update(rules)
            if _HOT_RE.search(text):
                self.hot_lines.add(i)
            m = _GUARDED_RE.search(text)
            if m:
                self.guarded[i] = m.group(1)
            m = _HOLDS_RE.search(text)
            if m:
                self.holds[i] = tuple(p.strip() for p in m.group(1).split(","))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    @staticmethod
    def def_lines(node: ast.AST) -> List[int]:
        """Lines where a function/class-level directive may sit: the def
        line itself, each decorator line, and the line directly above the
        first of those (a full-line comment)."""
        lines = [node.lineno]
        for dec in getattr(node, "decorator_list", []):
            lines.append(dec.lineno)
        lines.append(min(lines) - 1)
        return lines

    def is_hot_def(self, node: ast.AST) -> bool:
        return any(ln in self.hot_lines for ln in self.def_lines(node))

    def holds_for_def(self, node: ast.AST) -> Set[str]:
        out: Set[str] = set()
        for ln in self.def_lines(node):
            out.update(self.holds.get(ln, ()))
        return out

    def suppressed(self, finding: Finding) -> bool:
        rules = self.disable.get(finding.line)
        if not rules:
            return False
        return finding.rule in rules or "all" in rules

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        lineno = getattr(node_or_line, "lineno", node_or_line)
        return Finding(
            rule=rule,
            path=self.path,
            line=int(lineno),
            message=message,
            snippet=self.line_text(int(lineno)),
        )


# --------------------------------------------------------------------------
# Rule protocol. File rules run once per file; project rules run once over
# the whole file set (kernel-contract needs the package view).
# --------------------------------------------------------------------------
class Rule:
    name = "rule"
    description = ""

    def check_file(self, ctx: FileContext) -> List[Finding]:  # pragma: no cover
        return []


class ProjectRule(Rule):
    def check_project(self, ctxs: Sequence[FileContext]) -> List[Finding]:  # pragma: no cover
        return []


def all_rules() -> List[Rule]:
    from .rules import REGISTRY

    return [cls() for cls in REGISTRY]


# --------------------------------------------------------------------------
# Baseline: grandfathered findings with justifications, matched by
# (rule, normalized path, stripped line text) so line-number churn does not
# invalidate entries. Stale entries (matching nothing) fail the run.
# --------------------------------------------------------------------------
def norm_path(path: str) -> str:
    p = path.replace("\\", "/")
    if "src/" in p:
        p = p[p.rindex("src/") + len("src/"):]
    return p.lstrip("./")


@dataclasses.dataclass
class BaselineEntry:
    rule: str
    file: str
    snippet: str
    justification: str

    def matches(self, f: Finding) -> bool:
        return (
            self.rule == f.rule
            and norm_path(self.file) == norm_path(f.path)
            and self.snippet.strip() == f.snippet
        )


@dataclasses.dataclass
class Baseline:
    path: Optional[str]
    entries: List[BaselineEntry]

    def split(self, findings: Sequence[Finding]):
        """Partition findings into (fresh, baselined) and return the stale
        baseline entries that matched nothing."""
        used = [False] * len(self.entries)
        fresh: List[Finding] = []
        baselined: List[Finding] = []
        for f in findings:
            hit = False
            for i, e in enumerate(self.entries):
                if e.matches(f):
                    used[i] = True
                    hit = True
            (baselined if hit else fresh).append(f)
        stale = [e for i, e in enumerate(self.entries) if not used[i]]
        return fresh, baselined, stale


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)), "baseline.json")


def load_baseline(path: Optional[str]) -> Baseline:
    if path is None or not os.path.exists(path):
        return Baseline(path=path, entries=[])
    with open(path, "r", encoding="utf-8") as fh:
        raw = json.load(fh)
    entries = []
    for e in raw.get("entries", []):
        if not str(e.get("justification", "")).strip():
            raise ValueError(
                f"baseline entry for {e.get('file')} rule={e.get('rule')} "
                "has no justification — every grandfathered finding must say why"
            )
        entries.append(
            BaselineEntry(
                rule=str(e["rule"]),
                file=str(e["file"]),
                snippet=str(e["snippet"]),
                justification=str(e["justification"]),
            )
        )
    return Baseline(path=path, entries=entries)


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]  # post-suppression, pre-baseline (fresh + baselined)
    fresh: List[Finding]
    baselined: List[Finding]
    stale_baseline: List[BaselineEntry]
    parse_errors: List[Tuple[str, str]]

    @property
    def failed(self) -> bool:
        return bool(self.fresh or self.stale_baseline or self.parse_errors)


def collect_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs if d not in {"__pycache__", ".git", ".venv"}
                )
                for name in sorted(files):
                    if name.endswith(".py"):
                        out.append(os.path.join(root, name))
        elif p.endswith(".py"):
            out.append(p)
    # De-dup while preserving order
    seen: Set[str] = set()
    uniq = []
    for p in out:
        ap = os.path.abspath(p)
        if ap not in seen:
            seen.add(ap)
            uniq.append(p)
    return uniq


def _load_context(path: str) -> Tuple[Optional[FileContext], Optional[str]]:
    try:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError, ValueError) as exc:
        return None, f"{exc}"
    return FileContext(path, source, tree), None


def run_analysis(
    paths: Sequence[str],
    rules: Optional[Sequence[Rule]] = None,
    baseline: Optional[Baseline] = None,
) -> AnalysisResult:
    rules = list(rules) if rules is not None else all_rules()
    baseline = baseline if baseline is not None else Baseline(None, [])
    files = collect_files(paths)
    ctxs: List[FileContext] = []
    parse_errors: List[Tuple[str, str]] = []
    for path in files:
        ctx, err = _load_context(path)
        if ctx is None:
            parse_errors.append((path, err or "parse error"))
        else:
            ctxs.append(ctx)

    findings: List[Finding] = []
    by_path = {c.path: c for c in ctxs}
    for rule in rules:
        raw: List[Finding] = []
        for ctx in ctxs:
            raw.extend(rule.check_file(ctx))
        if isinstance(rule, ProjectRule):
            raw.extend(rule.check_project(ctxs))
        for f in raw:
            ctx = by_path.get(f.path)
            if ctx is not None and ctx.suppressed(f):
                continue
            findings.append(f)

    findings.sort(key=lambda f: (norm_path(f.path), f.line, f.rule))
    fresh, baselined, stale = baseline.split(findings)
    return AnalysisResult(
        findings=findings,
        fresh=fresh,
        baselined=baselined,
        stale_baseline=stale,
        parse_errors=parse_errors,
    )


# --------------------------------------------------------------------------
# Reporters
# --------------------------------------------------------------------------
def render_text(result: AnalysisResult, verbose: bool = False) -> str:
    out: List[str] = []
    for path, err in result.parse_errors:
        out.append(f"{path}: [parse-error] {err}")
    for f in result.fresh:
        out.append(f"{f.path}:{f.line}: [{f.rule}] {f.message}")
    if verbose:
        for f in result.baselined:
            out.append(f"{f.path}:{f.line}: [{f.rule}] (baselined) {f.message}")
    for e in result.stale_baseline:
        out.append(
            f"{e.file}: [stale-baseline] entry for rule '{e.rule}' "
            f"(snippet {e.snippet!r}) no longer matches any finding — "
            "remove it from baseline.json (the baseline only shrinks)"
        )
    n_fresh, n_base = len(result.fresh), len(result.baselined)
    out.append(
        f"reprolint: {n_fresh} finding(s), {n_base} baselined, "
        f"{len(result.stale_baseline)} stale baseline entr(ies), "
        f"{len(result.parse_errors)} parse error(s)"
    )
    return "\n".join(out)


def render_json(result: AnalysisResult) -> str:
    payload = {
        "version": 1,
        "findings": [
            {
                "rule": f.rule,
                "file": f.path,
                "line": f.line,
                "message": f.message,
                "snippet": f.snippet,
                "baselined": f in result.baselined,
            }
            for f in result.findings
        ],
        "stale_baseline": [dataclasses.asdict(e) for e in result.stale_baseline],
        "parse_errors": [{"file": p, "error": e} for p, e in result.parse_errors],
        "counts": {
            "fresh": len(result.fresh),
            "baselined": len(result.baselined),
            "stale_baseline": len(result.stale_baseline),
            "parse_errors": len(result.parse_errors),
        },
        "failed": result.failed,
    }
    return json.dumps(payload, indent=2, sort_keys=True)
