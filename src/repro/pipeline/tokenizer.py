"""Event -> token bridge: renders stored events as token sequences for LM
training ("next-event prediction" — the situational-awareness analytic the
LLCySA platform exists to serve).

Token layout per event (fixed width, field-tagged):
    [BOS_EVENT] [TIME_BUCKET tok] [field0 tok] [field1 tok] ...
Field tokens are offset-partitioned per field so a single vocab covers all
dictionaries: tok(field f, code c) = base_f + (c % field_span).

This is deliberately simple — the LM substrate cares about shapes and
throughput, not linguistics — but it is a REAL pipeline: batches drawn
here come out of the sharded store via time-range scans, i.e. training
consumes exactly what ingest produced.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

from ..core import keypack
from ..core.scan import scan_events
from ..core.store import EventStore


@dataclass
class EventTokenizer:
    store: EventStore
    vocab_size: int
    time_buckets: int = 256

    def __post_init__(self):
        n_fields = self.store.schema.n_fields
        reserved = 2 + self.time_buckets  # BOS, PAD, time tokens
        span = (self.vocab_size - reserved) // n_fields
        if span < 16:
            raise ValueError("vocab too small for field spans")
        self.bos = 0
        self.pad = 1
        self.time_base = 2
        self.field_span = span
        self.field_base = [reserved + i * span for i in range(n_fields)]
        self.tokens_per_event = 2 + n_fields  # BOS + time + fields

    def encode_block(self, ts: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """(n,), (n, F) -> (n, tokens_per_event) int32."""
        n, f = cols.shape
        out = np.empty((n, self.tokens_per_event), dtype=np.int32)
        out[:, 0] = self.bos
        tb = (ts * self.time_buckets // max(int(keypack.TS_MAX), 1)) % self.time_buckets
        out[:, 1] = self.time_base + tb
        for j in range(f):
            out[:, 2 + j] = self.field_base[j] + (cols[:, j] % self.field_span)
        return out

    def sequences(
        self,
        t_start: int,
        t_stop: int,
        seq_len: int,
        batch: int,
        seed: int = 0,
    ) -> Iterator[np.ndarray]:
        """Yield (batch, seq_len) int32 token batches from a store time
        range, tiling events into fixed-length sequences."""
        rng = np.random.default_rng(seed)
        buf = np.empty((0,), dtype=np.int32)
        need = batch * seq_len
        while True:
            for blk in scan_events(self.store, t_start, t_stop):
                toks = self.encode_block(blk.ts(), blk.cols).reshape(-1)
                buf = np.concatenate([buf, toks])
                while buf.size >= need:
                    chunk, buf = buf[:need], buf[need:]
                    yield chunk.reshape(batch, seq_len)
            if buf.size == 0:
                # Store had no events in range at all: synthesize padding
                # batches rather than spinning (keeps smoke tests simple).
                yield np.full((batch, seq_len), self.pad, dtype=np.int32)
