"""Ingest worker pool — paper §II: "Upon receiving a filename and metadata,
the ingest worker reads lines from the file, parsing the data into entries
to be stored in the event, index and aggregate tables."

Workers are threads (the paper's are Python processes over JNI; the
orchestration structure is identical). Each worker owns a BatchWriter and a
queue partition; it heartbeats its lease while parsing, completes the task
after the writer flush, and exits when the queue drains. The pool is
elastic: workers can be added/removed mid-run, and a killed worker's lease
expires and its file re-queues (tested in tests/test_pipeline.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core.ingest import BatchWriter, IngestMetrics, check_shard_guidance
from ..core.store import EventStore
from .queue import FileTask, MasterIngestQueue
from .sources import parse_web_proxy_lines


@dataclass
class WorkerReport:
    name: str
    files: int = 0
    rows: int = 0
    metrics: IngestMetrics = field(default_factory=IngestMetrics)


class _Worker(threading.Thread):
    def __init__(
        self,
        name: str,
        pool: "IngestWorkerPool",
        partition: int,
        batch_rows: int,
        heartbeat_every: int = 1024,  # lines between heartbeats; must keep
        # heartbeat period well under the lease timeout or a live worker's
        # file gets re-queued (at-least-once => duplicate ingest)
    ):
        super().__init__(name=name, daemon=True)
        self.pool = pool
        self.partition = partition
        self.report = WorkerReport(name)
        self.writer = BatchWriter(pool.store, batch_rows=batch_rows, metrics=self.report.metrics)
        self.heartbeat_every = heartbeat_every
        self.stop_flag = threading.Event()
        self.die_silently = threading.Event()  # test hook: simulate a crash

    def run(self) -> None:
        q = self.pool.queue
        while not self.stop_flag.is_set():
            task = q.claim(self.name, self.partition)
            if task is None:
                if self.pool.closed.is_set() and q.drained():
                    break
                time.sleep(0.01)
                continue
            if self.die_silently.is_set():
                return  # crash mid-lease: no complete(), lease will expire
            try:
                q.heartbeat(self.name, task.task_id)  # before any slow work
                with open(task.path) as f:
                    lines = f.readlines()
                nbytes = sum(len(l) for l in lines)
                for i in range(0, len(lines), self.heartbeat_every):
                    chunk = lines[i : i + self.heartbeat_every]
                    ts, cols = parse_web_proxy_lines(chunk)
                    self.writer.add(ts, cols, nbytes=sum(len(l) for l in chunk))
                    q.heartbeat(self.name, task.task_id)
                self.writer.flush()
                q.complete(self.name, task.task_id)
                self.report.files += 1
                self.report.rows += len(lines)
            except Exception:  # noqa: BLE001 — a failed file must re-queue
                # Leave the lease to expire; the task re-runs elsewhere.
                time.sleep(0.01)
        self.writer.close()


class IngestWorkerPool:
    """Elastic pool of ingest workers over a master queue."""

    def __init__(
        self,
        store: EventStore,
        n_workers: int,
        batch_rows: int = 4096,
        lease_timeout_s: float = 30.0,
        enforce_shard_guidance: bool = True,
    ):
        if enforce_shard_guidance and not check_shard_guidance(store.n_shards, n_workers):
            raise ValueError(
                f"paper guidance violated: n_shards={store.n_shards} < "
                f"n_clients/2={n_workers / 2} (pass enforce_shard_guidance="
                f"False to override)"
            )
        self.store = store
        self.queue = MasterIngestQueue(max(n_workers, 1), lease_timeout_s=lease_timeout_s)
        self.closed = threading.Event()
        self._workers: List[_Worker] = []
        self._batch_rows = batch_rows
        for _ in range(n_workers):
            self.add_worker()

    def add_worker(self) -> str:
        w = _Worker(
            f"ingest-{len(self._workers)}", self, partition=len(self._workers),
            batch_rows=self._batch_rows,
        )
        self._workers.append(w)
        w.start()
        return w.name

    def submit_file(self, path: str, source: str = "web_proxy") -> int:
        return self.queue.submit(FileTask(path, source))

    def kill_worker(self, idx: int) -> None:
        """Test hook: simulate a node failure (worker dies mid-lease)."""
        self._workers[idx].die_silently.set()

    def drain(self, timeout_s: float = 300.0) -> List[WorkerReport]:
        """Close submissions, wait for the queue to drain, join workers."""
        self.closed.set()
        deadline = time.monotonic() + timeout_s
        while not self.queue.drained():
            if time.monotonic() > deadline:
                raise TimeoutError("ingest drain timeout")
            self.queue.expire_now()
            time.sleep(0.02)
        for w in self._workers:
            w.stop_flag.set()
        for w in self._workers:
            w.join(timeout=10)
        return [w.report for w in self._workers]
