"""Synthetic web-proxy log source — the paper's experimental data (§IV):
"web traffic captured from web proxy server log files. Each event
occurrence represents a single HTTP request and has dozens of attributes."

The generator emits raw text lines (tab-separated) so ingest workers do
real parsing work — the paper attributes the 1.1 MB/s-per-client ceiling to
client-side costs, so the reproduction must actually pay them.

Domain popularity follows a Zipf law, giving the paper's Query A/B/C
selectivity tiers (most popular / somewhat popular / unpopular domain).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

FIELDS = [
    "src_ip",
    "dst_ip",
    "domain",
    "url_path",
    "method",
    "status",
    "user_agent",
    "content_type",
    "bytes_out",
    "bytes_in",
    "referer",
    "scheme",
]

_METHODS = ["GET", "POST", "PUT", "HEAD"]
_STATUS = ["200", "304", "404", "500", "302"]
_AGENTS = [f"agent/{i}.0" for i in range(12)]
_CTYPES = ["text/html", "application/json", "image/png", "text/css", "video/mp4"]


@dataclass
class SyntheticWebProxySource:
    n_domains: int = 2000
    zipf_a: float = 1.3
    seed: int = 7

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._domains = np.asarray(
            [f"d{i:05d}.example.com" for i in range(self.n_domains)]
        )
        # Zipf popularity over a fixed domain universe.
        ranks = np.arange(1, self.n_domains + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self._p = p / p.sum()

    def domain_by_popularity(self, quantile: float) -> str:
        """Domain at a popularity quantile: 0.0 = most popular (the paper's
        Query A), ~0.5 = somewhat popular (B), ~0.99 = unpopular (C)."""
        idx = min(int(quantile * (self.n_domains - 1)), self.n_domains - 1)
        return str(self._domains[idx])

    def gen_lines(self, n: int, t_start: int, t_stop: int) -> List[str]:
        """n raw log lines with timestamps uniform in [t_start, t_stop]."""
        rng = self._rng
        ts = np.sort(rng.integers(t_start, t_stop + 1, n))
        dom = rng.choice(self._domains, p=self._p, size=n)
        src = rng.integers(0, 1 << 16, n)
        dst = rng.integers(0, 1 << 16, n)
        rows = []
        methods = rng.choice(_METHODS, size=n, p=[0.78, 0.15, 0.02, 0.05])
        status = rng.choice(_STATUS, size=n, p=[0.8, 0.08, 0.07, 0.02, 0.03])
        agents = rng.choice(_AGENTS, size=n)
        ctypes = rng.choice(_CTYPES, size=n)
        b_out = rng.integers(64, 4096, n)
        b_in = rng.integers(128, 1 << 20, n)
        paths = rng.integers(0, 4000, n)
        for i in range(n):
            rows.append(
                "\t".join(
                    (
                        str(ts[i]),
                        f"10.{(src[i] >> 8) & 255}.{src[i] & 255}.{i % 251}",
                        f"93.{(dst[i] >> 8) & 255}.{dst[i] & 255}.7",
                        str(dom[i]),
                        f"/p/{paths[i]}",
                        str(methods[i]),
                        str(status[i]),
                        str(agents[i]),
                        str(ctypes[i]),
                        str(b_out[i]),
                        str(b_in[i]),
                        f"https://{dom[i]}/r",
                        "https",
                    )
                )
            )
        return rows

    def write_files(
        self, directory: str, n_files: int, lines_per_file: int, t_start: int, t_stop: int
    ) -> List[str]:
        """Stage files on the 'central filesystem' (paper §II)."""
        os.makedirs(directory, exist_ok=True)
        paths = []
        span = (t_stop - t_start) // max(n_files, 1)
        for i in range(n_files):
            p = os.path.join(directory, f"webproxy_{i:05d}.log")
            lo = t_start + i * span
            with open(p, "w") as f:
                f.write("\n".join(self.gen_lines(lines_per_file, lo, lo + span)) + "\n")
            paths.append(p)
        return paths


def parse_web_proxy_line(line: str) -> Tuple[int, Dict[str, str]]:
    """Parse one raw line -> (ts, field values). The real client-side work."""
    parts = line.rstrip("\n").split("\t")
    ts = int(parts[0])
    return ts, dict(zip(FIELDS, parts[1:]))


def parse_web_proxy_lines(
    lines: Sequence[str],
) -> Tuple[np.ndarray, Dict[str, List[str]]]:
    """Bulk parse -> (ts array, columnar field values)."""
    ts = np.empty(len(lines), dtype=np.int64)
    cols: Dict[str, List[str]] = {f: [] for f in FIELDS}
    for i, line in enumerate(lines):
        parts = line.rstrip("\n").split("\t")
        ts[i] = int(parts[0])
        for f, v in zip(FIELDS, parts[1:]):
            cols[f].append(v)
    return ts, cols
