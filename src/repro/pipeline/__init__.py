"""The big-data pipeline around the store (paper §II): staged source files,
a master ingest process feeding a partitioned queue, parallel ingest
workers, and the event->token bridge that feeds LM training.

Fault-tolerance features (beyond-paper, required at 1000-node scale):
lease-based work claims with heartbeats, straggler re-queue, elastic worker
pools, and idempotent file-grained retry.
"""
from .queue import FileTask, MasterIngestQueue  # noqa: F401
from .sources import SyntheticWebProxySource, parse_web_proxy_line  # noqa: F401
from .workers import IngestWorkerPool  # noqa: F401
from .tokenizer import EventTokenizer  # noqa: F401
