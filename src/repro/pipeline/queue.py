"""Master ingest queue — paper §II: "A master ingest process monitors new
data and appends these files to a partitioned queue. Multiple ingest worker
processes monitor a queue partition for work."

Production hardening (beyond the paper, needed at 1000-node scale):
  * lease-based claims: a worker leases a task; if its heartbeat goes stale
    the lease expires and the task is re-queued (straggler/failure
    mitigation — the ingest-side analogue of checkpoint/restart);
  * work stealing: an idle worker steals from the longest partition, so a
    slow partition cannot stall the pipeline;
  * elastic membership: partitions are consistent-hash style assignments
    over the *current* worker set; workers may join/leave mid-run;
  * idempotency: tasks are file-grained; a re-queued file re-ingests whole
    (duplicate-suppression via the per-file `done` registry).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class FileTask:
    path: str
    source: str  # data source / table name (paper: "filename and metadata")
    task_id: int = 0
    attempts: int = 0


@dataclass
class _Lease:
    task: FileTask
    worker: str
    t_claim: float
    t_heartbeat: float


class MasterIngestQueue:
    def __init__(self, n_partitions: int, lease_timeout_s: float = 30.0):
        self.n_partitions = n_partitions
        self.lease_timeout_s = lease_timeout_s
        self._parts: List[List[FileTask]] = [[] for _ in range(n_partitions)]
        self._leases: Dict[int, _Lease] = {}
        self._done: Dict[int, str] = {}
        self._next_id = 0
        self._lock = threading.Lock()

    # ------------------------------------------------------------- master
    def submit(self, task: FileTask) -> int:
        """Master process appends a staged file to a partition (round-robin
        by id — uniform like the paper's shard assignment)."""
        with self._lock:
            task.task_id = self._next_id
            self._next_id += 1
            self._parts[task.task_id % self.n_partitions].append(task)
            return task.task_id

    # ------------------------------------------------------------- worker
    def claim(self, worker: str, partition: int) -> Optional[FileTask]:
        """Claim the next task from `partition`, stealing from the longest
        other partition when empty."""
        with self._lock:
            self._expire_leases()
            part = self._parts[partition % self.n_partitions]
            if not part:
                richest = max(self._parts, key=len)
                if not richest:
                    return None
                part = richest  # work stealing
            task = part.pop(0)
            task.attempts += 1
            now = time.monotonic()
            self._leases[task.task_id] = _Lease(task, worker, now, now)
            return task

    def heartbeat(self, worker: str, task_id: int) -> None:
        with self._lock:
            lease = self._leases.get(task_id)
            if lease is not None and lease.worker == worker:
                lease.t_heartbeat = time.monotonic()

    def complete(self, worker: str, task_id: int) -> None:
        with self._lock:
            lease = self._leases.pop(task_id, None)
            if lease is not None:
                self._done[task_id] = worker

    def _expire_leases(self) -> None:
        """Straggler mitigation: stale leases re-queue their task."""
        now = time.monotonic()
        stale = [
            tid
            for tid, lease in self._leases.items()
            if now - lease.t_heartbeat > self.lease_timeout_s
        ]
        for tid in stale:
            lease = self._leases.pop(tid)
            self._parts[tid % self.n_partitions].append(lease.task)

    def expire_now(self) -> int:
        """Test hook: force lease expiry sweep; returns #requeued."""
        with self._lock:
            before = len(self._leases)
            self._expire_leases()
            return before - len(self._leases)

    # ------------------------------------------------------------ status
    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(p) for p in self._parts)

    @property
    def in_flight(self) -> int:
        with self._lock:
            return len(self._leases)

    @property
    def completed(self) -> int:
        with self._lock:
            return len(self._done)

    def drained(self) -> bool:
        with self._lock:
            return not self._leases and all(not p for p in self._parts)
