"""Partition-rule trees: cfg + mesh -> PartitionSpec pytrees.

Axis conventions (DESIGN.md): batch shards over dp = ('pod','data') (or
('data',) single-pod); tensor/expert parallelism over 'model'. Rules are
divisibility-guarded: anything that does not divide evenly over 'model'
replicates (the Megatron "don't shard what doesn't divide" fallback) —
qwen1.5's 20 heads on a 16-way model axis is the live example.

KV caches: kv-head sharding over 'model' when kv_heads divides; otherwise
the cache SEQUENCE dim shards over 'model' and GSPMD synthesizes the
flash-decoding-style partial-softmax collectives (measured in §Roofline).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import ssm as ssm_mod

PyTree = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Dict:
    """PartitionSpec tree mirroring init_params' structure."""
    nm = model_axis_size(mesh)
    hd = cfg.head_dim_
    heads_div = _div(cfg.n_heads * hd, nm) and _div(cfg.n_heads, nm)
    kv_div = _div(cfg.n_kv_heads, nm)
    ff_div = _div(cfg.d_ff, nm)
    vocab_div = _div(cfg.vocab_size, nm)
    experts_div = _div(cfg.n_experts, nm)

    def attn_specs(kind: str) -> Dict:
        s = {
            "norm": P(None),
            "wq": P(None, "model") if heads_div else P(None, None),
            "wk": P(None, "model") if kv_div else P(None, None),
            "wv": P(None, "model") if kv_div else P(None, None),
            "wo": P("model", None) if heads_div else P(None, None),
        }
        if cfg.qkv_bias:
            s["bq"] = P("model") if heads_div else P(None)
            s["bk"] = P("model") if kv_div else P(None)
            s["bv"] = P("model") if kv_div else P(None)
        if cfg.qk_norm:
            s["q_norm"] = P(None)
            s["k_norm"] = P(None)
        if cfg.sandwich_norm:
            s["post_norm"] = P(None)
        if kind == "cross":
            s["gate_attn"] = P()
            s["gate_mlp"] = P()
        return s

    def mlp_specs() -> Dict:
        s: Dict[str, Any] = {"mlp_norm": P(None)}
        if cfg.n_experts:
            e = "model" if experts_div else None
            s["moe"] = {
                "router": P(None, None),
                "wi_gate": P(e, None, None),
                "wi_up": P(e, None, None),
                "wo": P(e, None, None),
            }
        elif cfg.mlp_type == "glu":
            s["wi_gate"] = P(None, "model") if ff_div else P(None, None)
            s["wi_up"] = P(None, "model") if ff_div else P(None, None)
            s["wo_mlp"] = P("model", None) if ff_div else P(None, None)
        else:
            s["wi"] = P(None, "model") if ff_div else P(None, None)
            s["wo_mlp"] = P("model", None) if ff_div else P(None, None)
        if cfg.sandwich_norm:
            s["post_mlp_norm"] = P(None)
        return s

    def ssm_specs() -> Dict:
        spec = ssm_mod.spec_from_cfg(cfg)
        din_div = _div(spec.d_inner, nm) and _div(spec.n_heads, nm)
        m = "model" if din_div else None
        return {
            "norm": P(None),
            "ssm": {
                "in_z": P(None, m),
                "in_x": P(None, m),
                "in_B": P(None, None),
                "in_C": P(None, None),
                "in_dt": P(None, m),
                "conv_x_w": P(None, m),
                "conv_x_b": P(m),
                "conv_B_w": P(None, None),
                "conv_B_b": P(None),
                "conv_C_w": P(None, None),
                "conv_C_b": P(None),
                "dt_bias": P(m),
                "A_log": P(m),
                "D": P(m),
                "norm": P(m),
                "out_proj": P(m, None),
            },
        }

    def layer_specs(kind: str) -> Dict:
        if kind in ("ssm", "ssm_shared_attn"):
            return ssm_specs()
        return {**attn_specs(kind), **mlp_specs()}

    def add_group_dim(tree):
        return jax.tree_util.tree_map(
            lambda p: P(None, *p), tree, is_leaf=lambda x: isinstance(x, P)
        )

    specs: Dict[str, Any] = {
        "final_norm": P(None),
        "groups": tuple(add_group_dim(layer_specs(k)) for k in cfg.layer_pattern),
    }
    if cfg.embed_input:
        specs["embed"] = P("model", None) if vocab_div else P(None, None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model") if vocab_div else P(None, None)
    if cfg.shared_attn_heads:
        sa_div = _div(cfg.shared_attn_heads, nm) and _div(cfg.shared_attn_kv_heads, nm)
        sff_div = _div(cfg.shared_attn_d_ff, nm)
        m = "model" if sa_div else None
        f = "model" if sff_div else None
        specs["shared_attn"] = {
            "norm": P(None),
            "wq": P(None, m),
            "wk": P(None, m),
            "wv": P(None, m),
            "wo": P(m, None),
            "mlp_norm": P(None),
            "wi_gate": P(None, f),
            "wi_up": P(None, f),
            "wo_mlp": P(f, None),
        }
    return specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> Dict:
    """Specs for train/serve input batches (keys optional per family)."""
    dp = dp_axes(mesh)
    bspec = dp if _div(global_batch, dp_size(mesh)) else None
    out = {
        "inputs": P(bspec, None),
        "targets": P(bspec, None),
        "embeds": P(bspec, None, None),
        "vision_states": P(bspec, None, None),
    }
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> Tuple:
    """Specs mirroring init_caches' structure (tuple per pattern pos)."""
    nm = model_axis_size(mesh)
    dp = dp_axes(mesh)
    b = dp if _div(global_batch, dp_size(mesh)) else None
    kv_div = _div(cfg.n_kv_heads, nm)
    per_pos = []
    for kind in cfg.layer_pattern:
        if kind in ("ssm", "ssm_shared_attn"):
            spec = ssm_mod.spec_from_cfg(cfg)
            h_div = _div(spec.n_heads, nm)
            c: Dict[str, Any] = {
                "state": P(None, b, "model" if h_div else None, None, None),
                "conv": P(None, b, None, None),
            }
            if kind == "ssm_shared_attn":
                sa_kv_div = _div(cfg.shared_attn_kv_heads, nm)
                c["sa"] = {
                    "k": P(None, b, None, "model", None) if sa_kv_div else P(None, b, "model", None, None),
                    "v": P(None, b, None, "model", None) if sa_kv_div else P(None, b, "model", None, None),
                }
            per_pos.append(c)
        elif kind == "cross":
            s = P(None, b, None, "model", None) if kv_div else P(None, b, None, None, None)
            per_pos.append({"k": s, "v": s})
        else:
            s = (
                P(None, b, None, "model", None)
                if kv_div
                else P(None, b, "model", None, None)  # sequence-sharded cache
            )
            per_pos.append({"k": s, "v": s})
    return tuple(per_pos)


def zero1_specs(param_spec_tree, shapes, mesh: Mesh):
    """ZeRO-1: additionally shard optimizer-state leaves over dp on the
    first replicated axis that divides. Applied to Adam m/v (f32), which
    dominate training memory. `shapes`: ShapeDtypeStruct tree matching the
    spec tree."""
    dps = dp_size(mesh)
    dp = dp_axes(mesh)

    def upgrade(spec: P, x) -> P:
        shape = x.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (p, dim) in enumerate(zip(parts, shape)):
            if p is None and dim > 0 and dim % dps == 0:
                parts[i] = dp
                return P(*parts)
        return spec

    return jax.tree_util.tree_map(
        upgrade, param_spec_tree, shapes, is_leaf=lambda x: isinstance(x, P)
    )


def to_named(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )
