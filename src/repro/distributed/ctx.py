"""Trace-time sharding-constraint context.

Model code stays mesh-agnostic; the launcher wraps tracing in
`sharding_context(mesh, rules)` and the model calls `constrain(name, x)` at
the few points where GSPMD needs a hint (activation residual stream, MoE
expert buffers, loss logits chunks). Outside a context these are no-ops, so
smoke tests and single-device runs never touch mesh machinery.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_sharding_ctx", default=None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: Dict[str, P]):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(name: str, x):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh() -> Optional[Mesh]:
    """The mesh being traced under, if any (used by modules that switch to
    explicit shard_map implementations, e.g. MoE dispatch)."""
    ctx = _CTX.get()
    return None if ctx is None else ctx[0]


def default_rules(
    cfg, mesh: Mesh, global_batch: int, seq_parallel: bool = False, seq_len: int = 0
) -> Dict[str, P]:
    """Standard rule set built from the same divisibility logic as
    sharding.py. seq_parallel: Megatron-style sequence parallelism — the
    residual stream (and hence the scan's saved activation stacks) lives
    S-sharded over 'model' between blocks; GSPMD turns the TP all-reduces
    into all-gather/reduce-scatter pairs around each block."""
    from .sharding import dp_axes, dp_size, model_axis_size

    dp = dp_axes(mesh)
    b = dp if global_batch % dp_size(mesh) == 0 else None
    nm = model_axis_size(mesh)
    vocab_ok = cfg.vocab_size % nm == 0
    experts_ok = cfg.n_experts and cfg.n_experts % nm == 0
    sp = seq_parallel and seq_len > 0 and seq_len % nm == 0
    rules = {
        "activations": P(b, "model" if sp else None, None),
        "logits_chunk": P(b, None, "model" if vocab_ok else None),
        "microbatch_2d": P(b, None),
        "microbatch_3d": P(b, None, None),
    }
    if experts_ok:
        rules["moe_buf"] = P("model", None, None)
    if any(k.startswith("ssm") for k in cfg.layer_pattern):
        from ..models.ssm import spec_from_cfg

        spec = spec_from_cfg(cfg)
        if spec.n_heads % nm == 0 and spec.d_inner % nm == 0:
            rules["ssm_x4"] = P(b, None, "model", None)
            rules["ssm_heads3"] = P(b, None, "model")
    return rules
