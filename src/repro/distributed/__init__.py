"""Distribution layer: mesh axis conventions, partition-rule trees for
params / optimizer state / caches / batches, activation-constraint hooks,
and gradient compression."""
from .sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    dp_axes,
    param_specs,
    zero1_specs,
)
