"""repro — a JAX reproduction of "Evaluating Accumulo Performance for a
Scalable Cyber Data Processing Pipeline" (Sawyer & O'Gwynn, 2014), grown into
a multi-pod training/serving framework whose data pipeline IS the paper's
system.

x64 note: the store's packed row keys are 53–63 bit integers (the TPU-native
adaptation of Accumulo's lexicographic byte keys), so we enable x64 globally.
All model code pins dtypes explicitly (bf16/f32/int32); tests assert no f64
leaks into model params, activations, or lowered HLO.
"""
from jax import config as _config

_config.update("jax_enable_x64", True)

__version__ = "1.0.0"
