"""`python -m repro.serve_db` — a long-running serve daemon.

The paper's serving experiments are one-shot benchmark sweeps; this
entrypoint runs the same plane as a *deployment*: background writers
feeding a sharded DistIngestPlane, N client sessions streaming queries
through the fair scheduler, a Prometheus pull endpoint (`/metrics`), the
flight recorder armed, and the SLO watchdog holding the paper's latency
objective — on breach it drops an incident bundle (flight-recorder
trace + metrics snapshot) into the incident directory.

Two early stdout lines are machine-readable (CI's incident smoke keys
on them, flushed before any long work):

    METRICS_URL=http://127.0.0.1:<port>/metrics
    INCIDENT_DIR=<path>

Exit code 0 on a clean run (incidents are an observability outcome, not
a failure). The default SLOs are loose; CI induces a breach by passing
an absurdly tight --ttfr-slo.
"""
from __future__ import annotations

import argparse
import sys
import threading
import time
from typing import List

import numpy as np

T_SPAN = 2 * 3600

_DOMAINS = ["a.com", "b.com", "c.com", "rare.net"]
_DOMAIN_P = [0.6, 0.25, 0.13, 0.02]
_SCHEMES = ("scan", "batched_scan", "index", "batched_index")


def _gen(rng, n: int):
    ts = np.sort(rng.integers(0, T_SPAN, n))
    vals = {
        "domain": rng.choice(_DOMAINS, p=_DOMAIN_P, size=n).tolist(),
        "method": rng.choice(["GET", "POST"], size=n).tolist(),
        "status": rng.choice(["200", "404"], size=n, p=[0.8, 0.2]).tolist(),
    }
    return ts, vals


def _parse(argv) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve_db",
        description="long-running serve daemon: writers + sessions + "
        "Prometheus endpoint + flight recorder + SLO watchdog",
    )
    ap.add_argument("--rows", type=int, default=6_000, help="seed rows")
    ap.add_argument("--sessions", type=int, default=4, help="query sessions")
    ap.add_argument("--writers", type=int, default=2, help="background writers")
    ap.add_argument("--duration", type=float, default=10.0, help="run seconds")
    ap.add_argument("--port", type=int, default=0, help="/metrics port (0=ephemeral)")
    ap.add_argument("--incident-dir", default="incidents", help="bundle directory")
    ap.add_argument("--groups", type=int, default=2, help="plane tablet groups")
    ap.add_argument("--tablets-per-device", type=int, default=4)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--window", type=float, default=10.0, help="SLO window seconds")
    ap.add_argument("--tick", type=float, default=0.25, help="watchdog tick seconds")
    ap.add_argument("--cooldown", type=float, default=30.0, help="per-rule cooldown")
    ap.add_argument("--flight-window", type=float, default=30.0)
    ap.add_argument(
        "--ttfr-slo", type=float, default=2.0,
        help="p99 time-to-first-result bound (seconds)",
    )
    ap.add_argument(
        "--lock-wait-slo", type=float, default=5.0,
        help="plane-lock acquire-wait seconds per window",
    )
    ap.add_argument(
        "--stall-slo", type=float, default=1.0,
        help="worst compaction increment (seconds, gauge)",
    )
    ap.add_argument(
        "--blocked-slo", type=float, default=5.0,
        help="writer blocked-seconds per window",
    )
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv)
    # Imports after argparse so `--help` stays instant (no jax init).
    from ..core import EventStore, web_proxy_schema
    from ..core.dist_ingest import DistBatchWriter, DistIngestPlane
    from ..launch.mesh import make_dev_mesh
    from ..obs import (
        WatchRule, Watchdog, counter_delta_rule, flight_enable, gauge_rule,
        get_registry, lock_wait_rule, serve_prometheus,
    )
    from . import QueryService, ttfr_event_probe
    from .session import QuerySession  # noqa: F401 (re-export sanity)

    rng = np.random.default_rng(args.seed)
    ts, vals = _gen(rng, args.rows)
    store = EventStore(web_proxy_schema(), n_shards=4)
    store.ingest(ts, vals)
    store.flush_all()
    store.compact_all()
    mesh = make_dev_mesh(1, 1)
    # Capacity sized for seed + everything the writers can append during
    # the run (each writer is budgeted to at most re-send the seed).
    cap = 2 * args.rows * (1 + max(args.writers, 1))
    plane = DistIngestPlane.for_store(
        store, mesh, capacity=cap,
        tablets_per_device=args.tablets_per_device,
        n_groups=args.groups,
        mem_rows=512, max_runs=4, append_rows=256,
    )
    flight_enable()
    endpoint = serve_prometheus(port=args.port)
    print(f"METRICS_URL={endpoint.url}", flush=True)
    print(f"INCIDENT_DIR={args.incident_dir}", flush=True)

    svc = QueryService(store, plane, compaction_interval=0.01)
    reg = get_registry()
    watchdog = Watchdog(
        [
            WatchRule(
                "ttfr_p99", ttfr_event_probe(), args.ttfr_slo,
                window_s=args.window, agg="p99", cooldown_s=args.cooldown,
                help="p99 time-to-first-result over the window",
            ),
            lock_wait_rule(
                "plane_lock_wait", "plane_lock", args.lock_wait_slo,
                window_s=args.window, cooldown_s=args.cooldown,
            ),
            gauge_rule(
                "compact_increment_stall",
                reg.gauge(
                    "compactor_max_increment_seconds",
                    "longest single compact_step device hold",
                ),
                args.stall_slo, cooldown_s=args.cooldown,
            ),
            counter_delta_rule(
                "writer_blocked", plane._m_blocked, args.blocked_slo,
                window_s=args.window, cooldown_s=args.cooldown,
            ),
        ],
        incident_dir=args.incident_dir,
        interval_s=args.tick,
        flight_window_s=args.flight_window,
    ).start()

    stop = threading.Event()
    served = [0] * args.sessions

    def writer_loop(wid: int) -> None:
        w = DistBatchWriter(store, plane, batch_rows=512, writer_id=wid)
        budget = args.rows  # bound memory: at most one seed re-send
        wrng = np.random.default_rng(args.seed + 1000 + wid)
        while not stop.is_set() and budget > 0:
            n = min(256, budget)
            bts, bvals = _gen(wrng, n)
            w.add(bts, bvals)
            budget -= n
            stop.wait(0.05)
        w.close()

    def session_loop(i: int) -> None:
        s = svc.session(f"daemon-{i}")
        srng = np.random.default_rng(args.seed + i)
        try:
            while not stop.is_set():
                scheme = _SCHEMES[srng.integers(len(_SCHEMES))]
                t0 = int(srng.integers(0, T_SPAN // 2))
                t1 = t0 + int(srng.integers(T_SPAN // 8, T_SPAN // 2))
                from ..core import Eq

                tree = Eq("domain", _DOMAINS[srng.integers(len(_DOMAINS))])
                try:
                    s.submit(scheme, t0, t1, tree).drain(timeout=60.0)
                    served[i] += 1
                except RuntimeError:
                    break  # service closed under us: clean shutdown race
        finally:
            if not s.closed:
                s.close()

    threads: List[threading.Thread] = [
        threading.Thread(target=writer_loop, args=(w,), name=f"writer-{w}", daemon=True)
        for w in range(args.writers)
    ] + [
        threading.Thread(target=session_loop, args=(i,), name=f"client-{i}", daemon=True)
        for i in range(args.sessions)
    ]
    for t in threads:
        t.start()
    deadline = time.perf_counter() + args.duration
    while time.perf_counter() < deadline:
        time.sleep(0.1)
    stop.set()
    for t in threads:
        t.join(timeout=90.0)
    watchdog.stop()
    svc.close()
    endpoint.stop()
    incidents = [i for i in watchdog.incidents() if i.get("kind") == "incident"]
    print(
        f"daemon: {sum(served)} queries over {args.sessions} sessions, "
        f"{args.writers} writers, {len(incidents)} incident(s)",
        flush=True,
    )
    for inc in incidents:
        print(f"INCIDENT={inc['bundle']} rule={inc['rule']}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
