"""Fair per-batch interleaving of many sessions' queries on one device.

The unit of scheduling is ONE adaptive batch (core/dist_query.QueryRun /
core/query.HostQueryRun step): the paper's Alg-2 already decomposes a
query into latency-bounded batches, so fairness costs nothing extra —
the scheduler just decides WHOSE batch runs next under the device lock.

Two policies compose:

  pick      time-to-first-result first: a query that has not delivered
            its first batch preempts every continuing stream (the paper's
            responsiveness metric is time to the INITIAL result set);
            within each class, FIFO round-robin across sessions.
  quantum   how many consecutive batches one turn may run before the
            device goes back to the queue — governed by the shared Alg-1
            law (core/batching.py::alg1_next_k): turns that run hot
            shrink toward one batch (interactive fairness), fast turns
            grow geometrically (amortize dispatch overhead when queues
            are short). This is the same admission policy generalized
            from core/batching.py (range batches) and serving/batcher.py
            (LM admission rounds).

The scheduler is pure bookkeeping — it owns no threads and runs no device
programs; the QueryService dispatcher drains it. Its waits measure QUERY
contention only: ingest appends never enter this queue (writers hold
per-tablet-group plane locks, not the device lock), so on a sharded
plane `max_first_turn_wait` keeps bounding first-result stalls by one
compaction increment regardless of how many writers are live.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from ..core.batching import alg1_next_k
from ..obs import get_registry
from .session import QuerySession, StreamingQuery


@dataclass
class TurnQuantum:
    """Alg-1 turn sizing: k = batches per turn, adapted so one turn's
    wall time stays inside [t_min, t_max] seconds."""

    k0: float = 1.0
    c: float = 1.5
    t_min: float = 0.02
    t_max: float = 0.25
    max_batches: int = 8

    def __post_init__(self):
        self._k = float(self.k0)

    @property
    def k(self) -> float:
        return self._k

    def budget(self) -> int:
        return max(1, min(int(round(self._k)), self.max_batches))

    def update(self, runtime: float, batches: int) -> None:
        k_next = alg1_next_k(self._k, runtime, batches, self.c, self.t_max, self.t_min)
        self._k = float(min(max(k_next, 1.0), self.max_batches))


@dataclass
class QueryEntry:
    """One submitted query's place in the scheduler. `run` (a QueryRun or
    HostQueryRun) is built lazily by the dispatcher under the device lock
    — planning reads densities off the mesh, which is device work, and it
    counts toward the session's time-to-first-result like any other
    serving cost. ready_at: when this entry last became runnable (queue
    wait accrues from here to batch execution)."""

    session: QuerySession
    stream: StreamingQuery
    stats: object = None
    run: object = None
    ready_at: float = 0.0
    popped_at: float = 0.0  # when pop_turn released it (profile: splits
    # admission into scheduler-queue wait vs device-lock acquire)
    seq: int = 0
    kw: dict = field(default_factory=dict)


class FairScheduler:
    """Thread-safe runnable queue with TTFR priority (see module
    docstring). has_pending()/ttfr_waiting() are the coordination points
    for the background compactor and the turn preemption check."""

    def __init__(self, quantum: Optional[TurnQuantum] = None):
        self.quantum = quantum or TurnQuantum()
        self._fresh: deque = deque()  # guarded-by: _cv — no first batch yet
        self._cont: deque = deque()  # guarded-by: _cv — continuing, round-robin
        self._closed = False  # guarded-by: _cv
        self._cv = threading.Condition()
        # Per-turn instrumentation ring (starvation guard): the service
        # logs every served turn here — `first` marks a session's
        # first-result turn, whose `wait_s` is the stall the incremental
        # compactor must bound (no first result may park behind more
        # than ~one compaction increment). Bounded so a long-lived
        # service never grows it without limit.
        self.turn_log: deque = deque(maxlen=4096)  # guarded-by: _cv
        # Registry mirror of the turn log: the ring keeps its exact
        # per-turn records (the starvation guard reads waits from it, and
        # clear() between bench rounds must keep working), while the
        # histograms feed repro.obs.metrics_snapshot() with the turn/wait
        # distributions across the whole process lifetime.
        reg = get_registry()
        self._m_turns = reg.counter("serve_turns_total", "served turns, by first/continuing")
        self._m_turn_s = reg.histogram("serve_turn_seconds", "wall time of one served turn")
        self._m_wait_s = reg.histogram(
            "serve_first_wait_seconds", "queue wait of first-result turns"
        )

    # ------------------------------------------------------- enqueue side
    def submit(self, entry: QueryEntry) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError("QueryService closed")
            self._fresh.append(entry)
            self._cv.notify()

    def requeue(self, entry: QueryEntry) -> None:
        """Put a not-yet-done query back after its turn (it has delivered
        at least one batch by then, so it continues in the fair ring)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("QueryService closed")
            self._cont.append(entry)
            self._cv.notify()

    def close(self) -> list:
        """Reject all future submits (a client racing service shutdown
        gets a RuntimeError instead of a stream that never terminates)
        and hand back everything still queued so the service can error
        the streams out."""
        with self._cv:
            self._closed = True
            out = list(self._fresh) + list(self._cont)
            self._fresh.clear()
            self._cont.clear()
            return out

    # ------------------------------------------------------ dispatcher side
    def pop_turn(
        self, timeout: Optional[float] = None, on_pop=None
    ) -> Optional[QueryEntry]:
        """Next query to serve, or None on timeout. Fresh queries (no
        first result yet) always preempt continuing streams. `on_pop`
        runs under the condition variable BEFORE the entry leaves the
        queue — the service marks itself in-flight there, so the
        compactor can never observe a popped-but-unstarted turn as
        idle."""
        with self._cv:
            if not self._fresh and not self._cont:
                self._cv.wait(timeout=timeout)
            entry = None
            if self._fresh:
                entry = self._fresh.popleft()
            elif self._cont:
                entry = self._cont.popleft()
            if entry is not None:
                entry.popped_at = time.perf_counter()
                if on_pop is not None:
                    on_pop()
            return entry

    def log_turn(
        self, session_id: int, seq: int, wait_s: float, batches: int, turn_s: float
    ) -> None:
        """Record one served turn (called by the service after every
        turn, including zero-batch empty-plan turns). seq is the entry's
        sequence number WHEN THE TURN STARTED: 0 marks a first-result
        turn, the one the starvation guard bounds."""
        with self._cv:
            self.turn_log.append(
                {
                    "session": int(session_id),
                    "first": seq == 0,
                    "wait_s": float(wait_s),
                    "batches": int(batches),
                    "turn_s": float(turn_s),
                    "t": time.perf_counter(),
                }
            )
        self._m_turns.inc(first=seq == 0)
        self._m_turn_s.observe(turn_s)
        if seq == 0:
            self._m_wait_s.observe(wait_s)

    def max_first_turn_wait(self) -> float:
        """Worst queue wait of any first-result turn in the log — the
        starvation-guard statistic (tests + the concurrency bench assert
        it stays under the compaction increment bound)."""
        with self._cv:
            waits = [t["wait_s"] for t in self.turn_log if t["first"]]
            return max(waits) if waits else 0.0

    def has_pending(self) -> bool:
        with self._cv:
            return bool(self._fresh or self._cont)

    def ttfr_waiting(self) -> bool:
        """True when some query is still waiting for its FIRST batch —
        the dispatcher cuts the current turn short then (preemption at
        batch granularity keeps worst-case TTFR ~ one batch per waiting
        session, which is what bounds the no-starvation criterion)."""
        with self._cv:
            return bool(self._fresh)
