"""Client sessions of the query-serving plane.

The paper measures query performance as "latency of the client receiving
initial result sets" — plural clients, concurrently, against a database
that is simultaneously ingesting (§IV-B, §V). A `QuerySession` is one
such client's handle on the shared `QueryService`: it submits paper-style
queries (any of the four §IV-B schemes, or a scan-time aggregation) and
receives STREAMING result batches — the first `ResultBatch` arrives as
soon as the query's first adaptive batch completes on the device, not
after the full time range.

Threading model: client threads only touch their session's queues; all
device work happens on the service dispatcher, which interleaves
per-session batches fairly (scheduler.py). `StreamingQuery.results()`
blocks on the queue, so a client iterating a stream consumes results at
exactly the rate its fair share of the device produces them.

Not to be confused with `repro.serving` (the LM continuous-batching serve
engine): this package serves *database queries* over the distributed
store. Both admission policies share the paper's Alg-1 law
(core/batching.py::alg1_next_k).
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from .profile import QueryProfile

_DONE = object()  # stream sentinel


@dataclass
class ResultBatch:
    """One adaptive batch's results as delivered to a session.

    Distributed sessions carry the exact global `count` plus the
    per-tablet top-k newest rows (ts, cols) — BatchScanner semantics;
    host-path sessions carry the raw RowBlocks instead (`blocks`), with
    `count` the matched-row total. wait_s is the time this batch's query
    spent runnable-but-waiting for the device before the batch executed
    (queue wait — the concurrency cost the benchmarks plot); device_s is
    the batch's execution time."""

    seq: int
    lo: float
    hi: float
    count: int
    ts: Optional[np.ndarray] = None
    cols: Optional[np.ndarray] = None
    blocks: Optional[list] = None
    device_s: float = 0.0
    wait_s: float = 0.0


class StreamingQuery:
    """Handle on one submitted query: a thread-safe stream of ResultBatch
    plus per-query telemetry (time-to-first-result, queue wait) and a
    :class:`~repro.serve_db.profile.QueryProfile` decomposing the TTFR
    into serve-path stages (filled in by the dispatcher as the query
    moves; complete once the first result is delivered)."""

    def __init__(self, qid: int, scheme: str, t_start: int, t_stop: int, tree):
        self.qid = qid
        self.scheme = scheme
        self.t_start = t_start
        self.t_stop = t_stop
        self.tree = tree
        self.profile = QueryProfile(qid, scheme)
        self.submitted_at = time.perf_counter()
        self.first_result_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.rows = 0
        self.batches = 0
        self.queue_wait_s = 0.0
        self._q: "queue.Queue" = queue.Queue()
        self._error: Optional[BaseException] = None

    # ------------------------------------------------- dispatcher side
    def _deliver(self, rb: ResultBatch) -> None:
        now = time.perf_counter()
        if self.first_result_at is None:
            self.first_result_at = now
        self.rows += rb.count
        self.batches += 1
        self.queue_wait_s += rb.wait_s
        self._q.put(rb)

    def _finish(self, error: Optional[BaseException] = None) -> None:
        self._error = error
        self.finished_at = time.perf_counter()
        self._q.put(_DONE)

    # ------------------------------------------------------ client side
    def results(self, timeout: Optional[float] = 60.0):
        """Yield ResultBatch as the scheduler produces them; returns when
        the query completes. Raises the dispatcher-side error, if any."""
        while True:
            item = self._q.get(timeout=timeout)
            if item is _DONE:
                if self._error is not None:
                    raise self._error
                return
            yield item

    def drain(self, timeout: Optional[float] = 60.0) -> List[ResultBatch]:
        """Block until completion; return every batch in delivery order."""
        return list(self.results(timeout=timeout))

    def count(self, timeout: Optional[float] = 60.0) -> int:
        """Block until completion; return the total matching-row count."""
        return sum(rb.count for rb in self.results(timeout=timeout))

    # -------------------------------------------------------- telemetry
    @property
    def first_result_s(self) -> Optional[float]:
        """Time-to-first-result: submit -> first batch delivered (the
        paper's Table I metric, per session)."""
        if self.first_result_at is None:
            return None
        return self.first_result_at - self.submitted_at

    @property
    def total_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class QuerySession:
    """One client of the QueryService. Sessions are cheap; open one per
    concurrent client thread (like one Accumulo BatchScanner per client).
    backend="dist" executes on the shared mesh plane; backend="host" runs
    the same queries through the host QueryProcessor under the same fair
    scheduler — the live oracle dist sessions are validated against."""

    _next_qid = itertools.count()

    def __init__(self, service, session_id: int, name: str = "", backend: str = "dist"):
        if backend not in ("dist", "host"):
            raise ValueError(f"unknown session backend: {backend!r}")
        self.service = service
        self.session_id = session_id
        self.name = name or f"session-{session_id}"
        self.backend = backend
        self.queries: List[StreamingQuery] = []  # guarded-by: _lock
        self.closed = False
        self._lock = threading.Lock()

    def submit(
        self,
        scheme: str,
        t_start: int,
        t_stop: int,
        tree=None,
        stats=None,
    ) -> StreamingQuery:
        """Submit one query (paper scheme by name: scan / batched_scan /
        index / batched_index) and return its result stream immediately.
        The scheduler delivers the first batch as soon as it completes."""
        if self.closed:
            raise RuntimeError(f"{self.name} is closed")
        sq = StreamingQuery(next(QuerySession._next_qid), scheme, t_start, t_stop, tree)
        with self._lock:
            self.queries.append(sq)
        self.service._enqueue(self, sq, stats=stats)
        return sq

    def submit_aggregate(
        self, spec, t_start: int, t_stop: int, tree=None, stats=None
    ) -> StreamingQuery:
        """Scan-time aggregation (the iterator stack's terminal combiner):
        one turn, one ResultBatch whose blocks hold the AggregateResult
        and whose count is the matched-row total."""
        if self.closed:
            raise RuntimeError(f"{self.name} is closed")
        sq = StreamingQuery(
            next(QuerySession._next_qid), "aggregate", t_start, t_stop, (spec, tree)
        )
        with self._lock:
            self.queries.append(sq)
        self.service._enqueue(self, sq, stats=stats)
        return sq

    def submit_density(
        self, field: str, value: str, t_start: int, t_stop: int
    ) -> StreamingQuery:
        """Planner-style density read (aggregate-table count for one
        field=value over the bucketed range): one turn, one ResultBatch
        whose count is the density."""
        if self.closed:
            raise RuntimeError(f"{self.name} is closed")
        sq = StreamingQuery(
            next(QuerySession._next_qid), "density", t_start, t_stop, (field, value)
        )
        with self._lock:
            self.queries.append(sq)
        self.service._enqueue(self, sq)
        return sq

    def close(self) -> None:
        """Report final telemetry into the plane and detach — the service
        drops its handle, so per-connection sessions don't accumulate.
        In-flight queries finish normally (the scheduler owns them)."""
        if self.closed:
            return
        self.closed = True
        self.service._report_session(self)
        self.service._forget_session(self)

    # -------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, float]:
        """The per-session half of the one reporting structure: surfaced
        by DistIngestPlane.telemetry()["sessions"] next to the per-writer
        blocked-seconds (see QueryService._report_session)."""
        with self._lock:
            qs = list(self.queries)
        ttfr = [q.first_result_s for q in qs if q.first_result_s is not None]
        return {
            "queries": float(len(qs)),
            "batches": float(sum(q.batches for q in qs)),
            "rows": float(sum(q.rows for q in qs)),
            "queue_wait_s": float(sum(q.queue_wait_s for q in qs)),
            "first_result_s_max": float(max(ttfr)) if ttfr else 0.0,
            "first_result_s_mean": float(np.mean(ttfr)) if ttfr else 0.0,
        }
