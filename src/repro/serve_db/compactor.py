"""Background compaction, off the query path.

Major compaction is the only point where LSM runs fold into the base
(PR 4 made publish() a pure snapshot), and until now nothing scheduled it
besides ingest-tripped thresholds — the ROADMAP follow-up this module
closes. The `BackgroundCompactor` drives `DistIngestPlane.compact()` from
a maintenance thread, under two hard rules:

  1. NEVER while a session batch is in flight or runnable work is queued
     — it takes the service device lock non-blocking and re-checks the
     scheduler under it, so a query always wins the race;
  2. only when the plane actually has unfolded state
     (`plane.has_unfolded()` — exact from the host fill mirrors, free).

Folds are attributed in `plane.telemetry()["fold_events"]["background"]`;
the query path never appears in fold_events at all (reads cannot fold by
construction), which is what the CI smoke and the concurrency benchmark
assert. Queries stay exact either way — the fold only moves rows between
levels (tests/test_serve_db.py: an in-flight session's pinned snapshot is
untouched by a concurrent fold, because compaction programs never donate
published buffers).

A major compaction costs SECONDS of device time at scale, so fold TIMING
is everything. Two-mode hysteresis decides WHEN folding starts:

  urgent   run-slot debt (`plane.fold_debt()`) reached `min_debt`: fold
           at the next momentary idle gap, before ingest exhausts the
           slots and trips a BLOCKING major in some writer's flush (and
           stalls publishes behind the plane lock);
  drain    any unfolded state at all, but only after the serve plane has
           been continuously idle for `idle_grace_s` — a live feed
           constantly re-dirties the memtable, and folding every tiny
           delta would park multi-second majors in front of the very
           next query.

Incremental mode (`incremental=True`, the default) decides how folding
PROCEEDS once started: instead of one non-preemptible `compact()` that
holds the device for the whole k-way fold, the compactor interleaves
`plane.compact_step()` increments — one bounded 2-way merge (top run
slot -> base, all families in lockstep) per device-lock hold — and
re-checks the scheduler after EVERY increment. A query submitted mid-
major preempts at the next increment boundary and reads the (fully
consistent) partially-folded LSM, so the worst stall any session's first
result can park behind is ONE increment, not one major. `increments` /
`max_increment_s` instrument exactly that bound; the starvation-guard
test and the CI smoke assert against them.

SHARDED PLANES (n_groups > 1). The compactor is oblivious to sharding by
design: `plane.fold_debt()` reports the WORST group's run-slot debt (the
one closest to tripping a blocking major in some writer), and every
`plane.compact_step()` ranks groups by (debt, has_unfolded) and folds one
increment in the most-indebted group under THAT group's lock only — so a
background fold in group 2 never stalls writers appending to groups 0, 1
or 3, and the one-increment stall bound the starvation guard asserts is
now also a one-GROUP stall. `compact()` (non-incremental mode) still
drains every group before returning.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Optional

from ..obs import get_registry

_compactor_seq = itertools.count()


class BackgroundCompactor:
    """Maintenance thread: fold the plane's unfolded runs whenever the
    serve plane is idle (see module docstring for the urgent/drain
    hysteresis and the incremental/preemptible fold mode). `folds`
    counts completed drains that actually folded something; in
    incremental mode `increments` counts the bounded compact_step calls
    they decomposed into and `max_increment_s` the longest single
    device-lock hold (the stall bound)."""

    def __init__(
        self,
        plane,
        service=None,
        interval: float = 0.02,
        min_debt: int = 2,
        idle_grace_s: float = 0.25,
        incremental: bool = True,
    ):
        self.plane = plane
        self.service = service  # None: free-running (no query plane to yield to)
        self.interval = float(interval)
        self.min_debt = int(min_debt)
        self.idle_grace_s = float(idle_grace_s)
        self.incremental = bool(incremental)
        # Counters live on the default metrics registry (labelled per
        # compactor instance); the legacy attribute names below remain as
        # property views so tests/benches read — and benches reset — the
        # same names as before.
        self._label = f"c{next(_compactor_seq)}"
        reg = get_registry()
        self._m_counts = reg.counter(
            "compactor_events_total",
            "background-compactor events by kind "
            "(folds/passes/increments/preempted/skipped_busy)",
        )
        self._m_max_inc = reg.gauge(
            "compactor_max_increment_seconds", "longest single compact_step device hold"
        )
        self._draining = False  # an incremental drain is mid-flight
        self._last_busy = time.perf_counter()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # --------------------------------------------------- legacy metric views
    def _count(self, kind: str) -> int:
        return int(self._m_counts.value(kind=kind, compactor=self._label))

    def _set_count(self, kind: str, v: int) -> None:
        self._m_counts.set_value(v, kind=kind, compactor=self._label)

    folds = property(lambda s: s._count("folds"), lambda s, v: s._set_count("folds", v))
    passes = property(lambda s: s._count("passes"), lambda s, v: s._set_count("passes", v))
    increments = property(
        lambda s: s._count("increments"), lambda s, v: s._set_count("increments", v)
    )
    preempted = property(
        lambda s: s._count("preempted"), lambda s, v: s._set_count("preempted", v)
    )
    skipped_busy = property(
        lambda s: s._count("skipped_busy"), lambda s, v: s._set_count("skipped_busy", v)
    )

    @property
    def max_increment_s(self) -> float:
        return self._m_max_inc.value(compactor=self._label)

    @max_increment_s.setter
    def max_increment_s(self, v: float) -> None:
        self._m_max_inc.set_value(v, compactor=self._label)

    def start(self) -> "BackgroundCompactor":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="serve-db-compactor", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------ internals
    def _idle_fold(self) -> None:
        """One tick: fold iff the serve plane is quiescent RIGHT NOW and
        the urgent/drain hysteresis says the fold is worth its stall."""
        svc = self.service
        if svc is not None and svc.busy():
            self._last_busy = time.perf_counter()
        if not self.plane.has_unfolded():
            return
        urgent = self.plane.fold_debt() >= self.min_debt
        idle_for = time.perf_counter() - self._last_busy
        if not urgent and idle_for < self.idle_grace_s:
            return
        if svc is None:
            passes = self.plane.compact(source="background")
            if passes:
                self.folds += 1
                self.passes += passes
            return
        if self.incremental:
            self._incremental_drain(svc)
            return
        if svc.busy():
            self.skipped_busy += 1
            return
        # Non-blocking: if a session batch grabbed the device between the
        # busy() check and here, the query wins and we try next tick.
        if not svc._device_lock.acquire(blocking=False, owner="fold_increment"):
            self.skipped_busy += 1
            return
        try:
            if svc.busy():  # re-check under the lock (submit raced us)
                self.skipped_busy += 1
                return
            passes = self.plane.compact(source="background")
            if passes:
                self.folds += 1
                self.passes += passes
        finally:
            svc._device_lock.release()

    def _incremental_drain(self, svc) -> None:
        """Interleave bounded compact_step increments with session turns:
        the device lock is held for ONE increment at a time, and the
        scheduler is re-checked before every increment, so a query
        submitted mid-major preempts at the next increment boundary. The
        drain resumes on later ticks — any prefix of increments leaves a
        consistent LSM, an interrupted major is just lower fold debt.
        On a sharded plane each compact_step targets the currently
        most-indebted tablet group (re-ranked every increment), holding
        only that group's lock on the plane side."""
        progressed = False
        while not self._stop.is_set():
            if svc.busy():
                if progressed:
                    self.preempted += 1  # a query cut this drain short
                else:
                    self.skipped_busy += 1
                return
            # Non-blocking: if a session batch grabbed the device between
            # the busy() check and here, the query wins.
            if not svc._device_lock.acquire(blocking=False, owner="fold_increment"):
                self.skipped_busy += 1
                return
            try:
                if svc.busy():  # re-check under the lock (submit raced us)
                    self.skipped_busy += 1
                    return
                t0 = time.perf_counter()
                ran = self.plane.compact_step(source="background")
                dt = time.perf_counter() - t0
            finally:
                svc._device_lock.release()
            if not ran:
                break  # drained (or raced another folder): complete below
            progressed = True
            self._draining = True
            self.increments += 1
            self.passes += 1
            self.max_increment_s = max(self.max_increment_s, dt)
            if not self.plane.has_unfolded():
                break  # this increment finished the drain
        if self._draining and not self.plane.has_unfolded():
            self._draining = False
            self.folds += 1  # one completed (possibly multi-tick) drain

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            self._idle_fold()
