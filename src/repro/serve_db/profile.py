"""Query latency anatomy: where one query's time-to-first-result went.

The paper evaluates serving as "latency of the client receiving initial
result sets" — one number. A regression in that number is useless for
diagnosis until it is decomposed along the serve path, so every
:class:`~repro.serve_db.session.StreamingQuery` carries a
:class:`QueryProfile` whose stages tile the TTFR interval end to end:

    submit ──admission──▶ turn start ──plan──▶ (density fence inside)
           ──device_step──▶ batch arrays on host ──epilogue──▶
           ──deliver──▶ first ResultBatch stamped

- **admission** — submit() to the first turn starting on the dispatcher
  (scheduler queue wait + device-lock acquire; ``admission_queue_s``
  sub-splits the scheduler-queue part using the pop timestamp).
- **plan** — lazy run construction under the device lock (snapshot sync,
  plan_query, jit-step cache lookups), MINUS the density reads.
- **density_fence** — the planner's aggregate-tablet density reads (the
  fenced device wait the paper's follower queries pay).
- **device_step** — the device-program section of executed batches
  (dispatch + materialization inside scan_range/scan_index_range).
- **epilogue** — host remainder of a step: top-k merges, valid-row
  filtering, batcher/stats bookkeeping.
- **deliver** — handing the batch to the session stream up to the
  instant ``first_result_at`` is stamped.

First-result stages (``*_first``) sum to the measured TTFR to within
clock-read slack — benchmarks/bench_query_concurrency.py asserts the sum
lands within 5% — while the totals keep accumulating over the query's
remaining batches.

Aggregation: committed profiles observe into two default-registry
histograms, ``query_profile_seconds{stage=,scheme=}`` and
``query_profile_ttfr_seconds{scheme=}``, each carrying a **trace-id
exemplar** (``q<qid>``, the id also stamped on the query's serve-plane
spans) for the worst observation — so a p99 blip in the histogram points
straight at a pullable trace in the flight recorder.

Threading: a profile is written only by the service dispatcher (one
thread steps any given query) and read by clients after delivery — the
result queue's put/get pair is the happens-before edge, same as every
other StreamingQuery field. The module-level TTFR event buffer feeding
the SLO watchdog is the one shared structure, locked inside
:class:`_TTFREvents`.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from ..obs import get_registry

__all__ = ["QueryProfile", "STAGES", "note_ttfr", "ttfr_event_probe"]

STAGES = ("admission", "plan", "density_fence", "device_step", "epilogue", "deliver")


class QueryProfile:
    """Per-query stage clock (see module docstring). ``*_acc_s`` fields
    are accumulators the execution layer (core/dist_query) adds into
    while a step or plan is running on the dispatcher thread; the
    service snapshots their deltas around each stage boundary."""

    __slots__ = (
        "qid", "scheme", "trace_id",
        "admission_s", "admission_queue_s", "plan_s", "density_fence_s",
        "device_step_s", "epilogue_s", "deliver_s",
        "ttfr_s",
        "density_acc_s", "device_acc_s",
        "steps_total", "device_total_s", "epilogue_total_s",
        "deliver_total_s", "committed",
    )

    def __init__(self, qid: int, scheme: str) -> None:
        self.qid = qid
        self.scheme = scheme
        self.trace_id = f"q{qid}"
        # First-result stages (tile the TTFR interval).
        self.admission_s = 0.0
        self.admission_queue_s = 0.0  # scheduler-queue part of admission
        self.plan_s = 0.0
        self.density_fence_s = 0.0
        self.device_step_s = 0.0
        self.epilogue_s = 0.0
        self.deliver_s = 0.0
        self.ttfr_s: Optional[float] = None
        # Execution-layer accumulators (device sections add in here).
        self.density_acc_s = 0.0
        self.device_acc_s = 0.0
        # Whole-query totals (keep growing after the first result).
        self.steps_total = 0
        self.device_total_s = 0.0
        self.epilogue_total_s = 0.0
        self.deliver_total_s = 0.0
        self.committed = False

    # ------------------------------------------------- dispatcher-side
    def note_step(self, device_s: float, epilogue_s: float, first: bool) -> None:
        self.steps_total += 1
        self.device_total_s += device_s
        self.epilogue_total_s += epilogue_s
        if first:
            self.device_step_s = device_s
            self.epilogue_s = epilogue_s

    def note_deliver(self, deliver_s: float, first: bool) -> None:
        self.deliver_total_s += deliver_s
        if first:
            self.deliver_s = deliver_s

    def commit(self, ttfr_s: float, registry=None) -> None:
        """Publish this profile once its first result is out: stage
        histograms + the TTFR histogram (worst-observation trace-id
        exemplars) on the default registry, and the TTFR event buffer the
        watchdog's sliding p99 reads."""
        if self.committed:
            return
        self.committed = True
        self.ttfr_s = ttfr_s
        reg = registry if registry is not None else get_registry()
        h = reg.histogram(
            "query_profile_seconds",
            "TTFR anatomy per stage (first-result stages tile the TTFR)",
        )
        for stage, v in self.stages().items():
            h.observe(v, exemplar=self.trace_id, stage=stage, scheme=self.scheme)
        reg.histogram(
            "query_profile_ttfr_seconds", "measured end-to-end TTFR"
        ).observe(ttfr_s, exemplar=self.trace_id, scheme=self.scheme)
        note_ttfr(ttfr_s)

    # ------------------------------------------------------ client-side
    def stages(self) -> Dict[str, float]:
        """The six first-result stages, in timeline order."""
        return {
            "admission": self.admission_s,
            "plan": self.plan_s,
            "density_fence": self.density_fence_s,
            "device_step": self.device_step_s,
            "epilogue": self.epilogue_s,
            "deliver": self.deliver_s,
        }

    def breakdown_sum_s(self) -> float:
        """Sum of the first-result stages — within 5% of the measured
        TTFR (bench_query_concurrency asserts this at 4 sessions)."""
        return float(sum(self.stages().values()))

    def as_dict(self) -> Dict[str, float]:
        out = {f"{k}_s": v for k, v in self.stages().items()}
        out.update(
            admission_queue_s=self.admission_queue_s,
            ttfr_s=self.ttfr_s if self.ttfr_s is not None else float("nan"),
            steps_total=float(self.steps_total),
            device_total_s=self.device_total_s,
            epilogue_total_s=self.epilogue_total_s,
            deliver_total_s=self.deliver_total_s,
        )
        return out


class _TTFREvents:
    """Bounded ring of committed (t, ttfr_s) observations — the event
    source behind the watchdog's sliding-window TTFR p99 rule."""

    def __init__(self, maxlen: int = 4096) -> None:
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=maxlen)  # guarded-by: _lock
        self._seq = 0  # guarded-by: _lock

    def note(self, ttfr_s: float) -> None:
        with self._lock:
            self._seq += 1
            self._events.append((self._seq, time.perf_counter(), float(ttfr_s)))

    def since(self, seq: int) -> Tuple[int, List[Tuple[float, float]]]:
        """Events newer than ``seq`` as (t, value) pairs, plus the new
        high-water mark."""
        with self._lock:
            fresh = [(t, v) for s, t, v in self._events if s > seq]
            return self._seq, fresh


_ttfr_events = _TTFREvents()


def note_ttfr(ttfr_s: float) -> None:
    _ttfr_events.note(ttfr_s)


def ttfr_event_probe() -> Callable[[], List[Tuple[float, float]]]:
    """An event probe for ``obs.WatchRule(agg="p99")``: each call drains
    the TTFR observations committed since the previous call."""
    state = {"seq": 0}

    def probe() -> List[Tuple[float, float]]:
        state["seq"], fresh = _ttfr_events.since(state["seq"])
        return fresh

    return probe
