"""QueryService — N concurrent client sessions over ONE shared mesh plane.

The paper's query experiments put plural clients against tablet servers
that are simultaneously ingesting; the D4M follow-up (arXiv:1406.4923)
scales by multiplying client *processes* against shared servers. This
module is that serving layer for the repro: one `QueryService` owns one
`DistIngestPlane` + `DistQueryProcessor` (and a host `QueryProcessor`
twin for oracle sessions) and serves any number of `QuerySession`s, each
streaming result batches as they complete.

Architecture (one box per thread):

    client threads        dispatcher thread          compactor thread
    ──────────────        ─────────────────          ────────────────
    session.submit ─────▶ FairScheduler.pop_turn
    stream.results ◀───── step one adaptive batch    idle? plane.compact
      (queue.get)         under _device_lock ◀─────── (non-blocking try)
                          deliver ResultBatch

Device work is serialized by `_device_lock` (one host process drives the
mesh; concurrency is about FAIRNESS of interleaving, not parallel
dispatch — same regime as the paper's single-cluster experiments). The
scheduler picks whose batch runs next (TTFR priority + round-robin,
scheduler.py); the Alg-1 turn quantum bounds how long any session can
hold the device. Background compaction (compactor.py) runs ONLY when no
batch is in flight and none is queued — the query path never folds,
which `plane.telemetry()["fold_events"]` proves.

Every query run is pinned to the publish() snapshot it started on
(core/dist_query.QueryRun), so a fold or a concurrent publish can never
change an in-flight session's results — sessions see a consistent LSM
state per query, and fresh ingest becomes visible at the next query.

`_device_lock` serializes QUERY work only. Ingest never takes it: on a
sharded plane (`DistIngestPlane(n_groups=G)`) writers append under
per-tablet-group locks, so W `DistBatchWriter`s feed the plane live
while sessions stream — the paper's "query under ingest" regime — and
the only cross-plane coupling left is the compactor's non-blocking
device-lock probe before each fold increment. Snapshot pinning is
unchanged for composite stores: publish() composes per-group zero-copy
snapshots (each group's gens ride along under its own key), and a run
pinned to a composite sees every group frozen at its own generation.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, Optional

from ..core.dist_query import DistQueryProcessor, QueryRun
from ..core.query import HostBatch, HostQueryRun, QueryProcessor
from ..obs import OwnedLock, span
from .compactor import BackgroundCompactor
from .scheduler import FairScheduler, QueryEntry, TurnQuantum
from .session import QuerySession, ResultBatch, StreamingQuery

SCHEME_FLAGS = {
    "scan": dict(use_index=False, batched=False),
    "batched_scan": dict(use_index=False, batched=True),
    "index": dict(use_index=True, batched=False),
    "batched_index": dict(use_index=True, batched=True),
}


class _OneShotRun:
    """Adapter: a single-dispatch query (aggregate / density) as a
    one-step run, so the scheduler treats it like any other turn. The
    whole dispatch is charged to the profile's device section (both
    adapted paths — aggregate_range, agg_count — are single fenced
    device programs; their host epilogues are the remainder of the
    step, which the service books separately)."""

    def __init__(self, fn, profile=None):
        self._fn = fn
        self._profile = profile
        self._done = False

    @property
    def done(self) -> bool:
        return self._done

    def step(self):
        t0 = time.perf_counter()
        out = self._fn()
        if self._profile is not None:
            self._profile.device_acc_s += time.perf_counter() - t0
        self._done = True
        return out


class QueryService:
    """See module docstring. `start=True` (default) launches the
    dispatcher and the background compactor immediately; use as a context
    manager to guarantee shutdown in tests/benchmarks."""

    def __init__(
        self,
        store,
        plane,
        top_k: int = 128,
        w: float = 10.0,
        quantum: Optional[TurnQuantum] = None,
        compaction_interval: float = 0.02,
        compactor: bool = True,
        incremental_compaction: bool = True,
        start: bool = True,
    ):
        self.store = store
        self.plane = plane
        self.proc = DistQueryProcessor(store, plane=plane, top_k=top_k, w=w)
        self.host_proc = QueryProcessor(store, w=w)
        self.scheduler = FairScheduler(quantum)
        # OwnedLock: every hold is attributed to an owner class
        # (session_turn / density_read / fold_increment) so the occupancy
        # report (repro.obs.occupancy_snapshot) breaks down exactly where
        # the TTFR-governing serialization point's time goes.
        self._device_lock = OwnedLock("device_lock")
        self._stop = threading.Event()
        # Turns in flight on the dispatcher. Written ONLY under the
        # scheduler's condition variable (pop_turn's on_pop hook and the
        # dispatcher's decrement), so busy() can never miss a popped-but-
        # unstarted turn.
        self._in_flight = 0  # guarded-by: scheduler._cv
        self._sessions: Dict[int, QuerySession] = {}
        self._next_sid = itertools.count()
        self._dispatcher: Optional[threading.Thread] = None
        self.compactor = (
            BackgroundCompactor(
                plane, self, interval=compaction_interval,
                incremental=incremental_compaction,
            )
            if compactor
            else None
        )
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "QueryService":
        if self.scheduler._closed:
            raise RuntimeError("QueryService cannot be restarted after close()")
        if self._dispatcher is None:
            self._stop.clear()
            self._dispatcher = threading.Thread(
                target=self._dispatch_loop, name="serve-db-dispatcher", daemon=True
            )
            self._dispatcher.start()
            if self.compactor is not None:
                self.compactor.start()
        return self

    def close(self) -> None:
        """Drain nothing, stop everything: pending queries error out on
        their streams; sessions' final telemetry lands in the plane."""
        self._stop.set()
        if self._dispatcher is not None:
            self._dispatcher.join()
            self._dispatcher = None
        if self.compactor is not None:
            self.compactor.stop()
        # Closing the scheduler rejects any submit that raced past
        # _enqueue's liveness check, and hands back everything queued —
        # no stream is ever left hanging without a terminal item.
        for entry in self.scheduler.close():
            entry.stream._finish(error=RuntimeError("QueryService closed"))
        for s in list(self._sessions.values()):
            s.close()

    def __enter__(self) -> "QueryService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- sessions
    def session(self, name: str = "", backend: str = "dist") -> QuerySession:
        sid = next(self._next_sid)
        s = QuerySession(self, sid, name=name, backend=backend)
        self._sessions[sid] = s
        return s

    def busy(self) -> bool:
        """True while any session batch is in flight or runnable — the
        compactor's keep-out signal. The pop-side increments _in_flight
        under the scheduler's condition variable, so there is no instant
        where a popped-but-unstarted turn reads as idle. The read here is
        deliberately lock-free: busy() is an advisory poll (the compactor
        re-checks under the device lock before folding), and an int read
        is atomic under the GIL — baselined in analysis/baseline.json."""
        return self._in_flight > 0 or self.scheduler.has_pending()

    def wait_idle(self, timeout: float = 60.0) -> bool:
        """Spin until no work is queued or in flight (benchmark epilogue:
        lets the background compactor take the device)."""
        deadline = time.perf_counter() + timeout
        while self.busy():
            if time.perf_counter() > deadline:
                return False
            time.sleep(0.001)
        return True

    # ------------------------------------------------------------- internals
    def _enqueue(self, session: QuerySession, sq: StreamingQuery, stats=None) -> None:
        if self._dispatcher is None:
            raise RuntimeError("QueryService is not running (start() it first)")
        self.scheduler.submit(
            QueryEntry(
                session=session, stream=sq, stats=stats,
                ready_at=time.perf_counter(),
            )
        )

    def _report_session(self, session: QuerySession) -> None:
        self.plane.record_session(session.session_id, session.telemetry())

    def _forget_session(self, session: QuerySession) -> None:
        """Called by QuerySession.close(): the service drops its handle so
        long-lived deployments (one session per client connection) don't
        accumulate dead sessions."""
        self._sessions.pop(session.session_id, None)

    def _build_run(self, entry: QueryEntry):
        sq = entry.stream
        backend = entry.session.backend
        if sq.scheme == "aggregate":
            spec, tree = sq.tree  # (AggregateSpec, tree) packed by submit

            def agg():
                if backend == "host":
                    return self.host_proc.aggregate(
                        spec, sq.t_start, sq.t_stop, tree, stats=entry.stats
                    )
                return self.proc.aggregate_range(
                    spec, tree, sq.t_start, sq.t_stop, stats=entry.stats
                )

            def fn():
                res = agg()
                return ResultBatch(
                    seq=0, lo=sq.t_start, hi=sq.t_stop,
                    count=int(res.counts.sum()), blocks=[res],
                )

            return _OneShotRun(fn, profile=sq.profile)
        if sq.scheme == "density":
            field_, value = sq.tree  # (field, value) packed by submit
            src = self.store if backend == "host" else self.proc

            def fn():
                d = src.agg_count(field_, value, sq.t_start, sq.t_stop)
                return ResultBatch(
                    seq=0, lo=sq.t_start, hi=sq.t_stop, count=int(d)
                )

            return _OneShotRun(fn, profile=sq.profile)
        flags = SCHEME_FLAGS[sq.scheme]
        if backend == "host":
            return HostQueryRun(
                self.host_proc, sq.t_start, sq.t_stop, sq.tree,
                stats=entry.stats, **flags,
            )
        return QueryRun(
            self.proc, sq.tree, sq.t_start, sq.t_stop,
            stats=entry.stats, profile=sq.profile, **flags,
        )

    @staticmethod
    def _as_result(entry: QueryEntry, blk, wait_s: float, device_s: float) -> ResultBatch:
        if isinstance(blk, ResultBatch):  # one-shot runs build their own
            blk.wait_s, blk.device_s = wait_s, device_s
            return blk
        if isinstance(blk, HostBatch):
            return ResultBatch(
                seq=entry.seq, lo=blk.lo, hi=blk.hi, count=blk.rows,
                blocks=blk.blocks, device_s=device_s, wait_s=wait_s,
            )
        return ResultBatch(  # DistBatch
            seq=entry.seq, lo=blk.lo, hi=blk.hi, count=blk.count,
            ts=blk.ts, cols=blk.cols, device_s=device_s, wait_s=wait_s,
        )

    # reprolint: hot-path — every session batch flows through this turn
    def _run_turn(self, entry: QueryEntry) -> None:
        t0 = time.perf_counter()
        # Queue wait = runnable -> device acquired. Run construction and
        # batch execution below are SERVING cost (they count toward TTFR
        # but not toward wait_s — the contention signal must not absorb
        # planning or compile time).
        wait_s = t0 - entry.ready_at
        # Captured before serving mutates them: the scheduler's turn log
        # keys the starvation guard on first-result turns (seq0 == 0)
        # and their queue wait — the stall incremental compaction bounds.
        seq0, wait0 = entry.seq, wait_s
        # TTFR anatomy (profile.py): the stage boundaries below are read
        # off ONE thread's clock, back to back, so the first-result
        # stages tile the measured TTFR (bench asserts the sum is within
        # 5%). Admission closes when this turn starts.
        prof = entry.stream.profile
        if entry.stream.first_result_at is None:
            prof.admission_s = t0 - entry.stream.submitted_at
            if entry.popped_at:
                prof.admission_queue_s = entry.popped_at - entry.stream.submitted_at
        if entry.run is None:
            # Built here, on the dispatcher, under the device lock:
            # planning reads densities off the mesh (device work), and it
            # counts toward this query's time-to-first-result like every
            # other serving cost. For the occupancy books this stretch of
            # the hold is density/planning work, not batch stepping.
            tp0 = time.perf_counter()
            with self._device_lock.reowner("density_read"):
                with span(
                    "serve.plan", cat="serve",
                    session=entry.session.session_id, scheme=entry.stream.scheme,
                ):
                    entry.run = self._build_run(entry)
            # plan = run construction minus the density reads the
            # execution layer accumulated inside it (the fenced d_i
            # lookups are their own stage — the paper's follower cost).
            prof.density_fence_s = prof.density_acc_s
            prof.plan_s = (time.perf_counter() - tp0) - prof.density_fence_s
            if entry.run.done:  # provably-empty plan: zero batches
                entry.stream._finish()
                self._report_session(entry.session)
                self.scheduler.log_turn(
                    entry.session.session_id, seq0, wait0, 0,
                    time.perf_counter() - t0,
                )
                return
        quantum = self.scheduler.quantum
        budget = quantum.budget()
        served = 0
        while served < budget and not entry.run.done:
            first = entry.stream.first_result_at is None
            dev0 = prof.device_acc_s
            start = time.perf_counter()
            blk = entry.run.step()
            end = time.perf_counter()
            if blk is None:
                break
            # Device section accumulated by the execution layer during
            # step(); everything else in the step is host epilogue
            # (top-k merges, valid-row filters, batcher bookkeeping).
            dev = prof.device_acc_s - dev0
            prof.note_step(dev, (end - start) - dev, first)
            td0 = time.perf_counter()
            with span("serve.deliver", cat="serve", session=entry.session.session_id):
                entry.stream._deliver(self._as_result(entry, blk, wait_s, end - start))
            if first:
                # deliver closes at the first_result_at stamp _deliver
                # just wrote — the same instant TTFR is measured against.
                prof.note_deliver(entry.stream.first_result_at - td0, True)
                prof.commit(entry.stream.first_result_s)
            else:
                prof.note_deliver(time.perf_counter() - td0, False)
            wait_s = 0.0  # later batches of this turn never waited
            entry.seq += 1
            served += 1
            if self.scheduler.ttfr_waiting():
                break  # someone's FIRST result is pending: yield the device
        quantum.update(time.perf_counter() - t0, served)
        self.scheduler.log_turn(
            entry.session.session_id, seq0, wait0, served,
            time.perf_counter() - t0,
        )
        if entry.run.done:
            entry.stream._finish()
            self._report_session(entry.session)
        else:
            entry.ready_at = time.perf_counter()  # runnable again from now
            self.scheduler.requeue(entry)

    # reprolint: hot-path
    def _dispatch_loop(self) -> None:
        def mark():
            # Runs inside pop_turn, which calls it while HOLDING the
            # scheduler condition variable — statically invisible to the
            # lexical guarded-by check, hence the targeted suppression.
            self._in_flight += 1  # reprolint: disable=guarded-by

        while not self._stop.is_set():
            entry = self.scheduler.pop_turn(timeout=0.02, on_pop=mark)
            if entry is None:
                continue
            try:
                with self._device_lock.hold("session_turn"):
                    with span(
                        "serve.turn", cat="serve",
                        session=entry.session.session_id,
                        qid=entry.stream.qid,
                    ):
                        self._run_turn(entry)
            except BaseException as e:  # deliver, don't kill the dispatcher
                entry.stream._finish(error=e)
            finally:
                # Decrement under the cv like the increment: -= on an int
                # is a read-modify-write, and a torn update would wedge
                # busy() permanently true (compactor starves) or false
                # (fold races a turn) — found by reprolint's guarded-by
                # rule on the plane's shared counters.
                with self.scheduler._cv:
                    self._in_flight -= 1
