"""Concurrent query-serving plane: many client sessions streaming result
batches over ONE shared DistIngestPlane, with background compaction off
the query path. See docs/serving_db.md. (The LM serve engine lives in
repro.serving — different workload, same Alg-1 admission law.)"""
from .compactor import BackgroundCompactor  # noqa: F401
from .profile import QueryProfile, ttfr_event_probe  # noqa: F401
from .scheduler import FairScheduler, QueryEntry, TurnQuantum  # noqa: F401
from .service import QueryService  # noqa: F401
from .session import QuerySession, ResultBatch, StreamingQuery  # noqa: F401
