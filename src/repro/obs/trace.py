"""Span tracing with parent linkage and device-time fencing.

Spans are cheap context managers::

    with span("query.step", cat="query", session=sid) as sp:
        out = step_fn(...)
        sp.fence(out)          # block_until_ready; accrues device time
        sp.set(rows=int(n))    # attach results post-hoc

Tracing is OFF by default. When disabled, :func:`span` returns a shared
singleton whose ``__enter__``/``__exit__``/``fence``/``set`` are no-ops —
the total disabled cost is one global load, one attribute check, and a
function call, which the overhead gate in tests/test_obs.py bounds at
< 2% of a scan microbench step.

Parent linkage is thread-local: the innermost open span on the current
thread is the parent of the next one opened. Records accumulate in a
bounded deque and export to Chrome trace-event JSON via
repro.obs.export.chrome_trace (loadable in Perfetto).

SAMPLING: ``enable(sample=1/N)`` keeps every Nth ROOT span (per-process
deterministic counter) and drops the rest; children always follow their
root's fate, so sampled traces contain only complete trees — never a
child whose parent is missing. Sampled-out spans cost one thread-local
read and return a no-op singleton whose ``fence`` passes values through
WITHOUT blocking (same contract as disabled tracing), keeping always-on
tracing affordable under sustained serve-plane load.
"""

from __future__ import annotations

import functools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional

from .flight import get_flight

__all__ = [
    "Tracer",
    "clear",
    "disable",
    "enable",
    "enabled",
    "get_tracer",
    "span",
    "traced",
]


class _NullSpan:
    """Singleton returned while tracing is disabled; every verb no-ops."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> None:
        return None

    def fence(self, x: object) -> object:
        return x

    def set(self, **kw: object) -> None:
        return None


_NULL = _NullSpan()


class _DropSpan:
    """Returned for sampled-out spans. Tracks a thread-local drop depth so
    every span opened UNDER a dropped root is dropped too (a sampled
    trace never contains an orphaned child). fence() passes through
    without blocking, like the disabled-tracing singleton."""

    __slots__ = ("tracer",)

    def __init__(self, tracer: "Tracer") -> None:
        self.tracer = tracer

    def __enter__(self) -> "_DropSpan":
        tls = self.tracer._tls
        tls.drop_depth = getattr(tls, "drop_depth", 0) + 1
        return self

    def __exit__(self, *exc: object) -> None:
        self.tracer._tls.drop_depth -= 1

    def fence(self, x: object) -> object:
        return x

    def set(self, **kw: object) -> None:
        return None


class _Span:
    __slots__ = ("tracer", "name", "cat", "args", "sid", "parent", "tid", "t0", "fence_s")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: Dict[str, Any]) -> None:
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.sid = 0
        self.parent = 0
        self.tid = 0
        self.t0 = 0.0
        self.fence_s = 0.0

    def __enter__(self) -> "_Span":
        tr = self.tracer
        self.sid = tr._next_sid()
        stack = tr._stack()
        self.parent = stack[-1].sid if stack else 0
        self.tid = threading.get_ident()
        tr._note_thread(self.tid)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: object) -> None:
        t1 = time.perf_counter()
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._record(self, t1 - self.t0)

    def fence(self, x: object) -> object:
        """Block until a jax value is ready; the wait is charged to this
        span as device time. Works on pytrees; passes through non-jax
        values untouched."""
        t0 = time.perf_counter()
        try:
            import jax

            jax.block_until_ready(x)
        except Exception:
            pass
        self.fence_s += time.perf_counter() - t0
        return x

    def set(self, **kw: object) -> None:
        self.args.update(kw)


class Tracer:
    def __init__(self, maxlen: int = 65536) -> None:
        self.enabled = False
        self.sample_n = 1  # keep every Nth root span (1 = keep all)
        self.records: Deque[Dict[str, Any]] = deque(maxlen=maxlen)
        self.epoch = time.perf_counter()
        self._sid = 0
        self._root_count = 0
        self._sid_lock = threading.Lock()
        self._tls = threading.local()
        self._threads: Dict[int, str] = {}
        self._threads_lock = threading.Lock()
        self._drop = _DropSpan(self)
        self._flight = get_flight()

    # -- internals -------------------------------------------------------
    def _next_sid(self) -> int:
        with self._sid_lock:
            self._sid += 1
            return self._sid

    def set_sample(self, sample: Optional[float]) -> None:
        """sample = fraction of root spans to keep (1/N); None or >= 1
        keeps everything. Resets the root counter, so every enable()
        starts a fresh deterministic period (the first root is always
        kept) and tests can assert exactly which roots survive."""
        with self._sid_lock:
            self._root_count = 0
        if sample is None or sample >= 1:
            self.sample_n = 1
        elif sample <= 0:
            raise ValueError(f"sample must be in (0, 1]: {sample}")
        else:
            self.sample_n = max(1, int(round(1.0 / sample)))

    def _sample_root(self) -> bool:
        with self._sid_lock:
            self._root_count += 1
            return self._root_count % self.sample_n == 1

    def _stack(self) -> List[_Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def _note_thread(self, tid: int) -> None:
        if tid not in self._threads:
            with self._threads_lock:
                self._threads[tid] = threading.current_thread().name

    def _record(self, sp: _Span, dur: float) -> None:
        rec = {
            "name": sp.name,
            "cat": sp.cat,
            "sid": sp.sid,
            "parent": sp.parent,
            "tid": sp.tid,
            "t0": sp.t0 - self.epoch,
            "dur": dur,
            "args": sp.args,
        }
        if sp.fence_s:
            rec["fence_s"] = sp.fence_s
        self.records.append(rec)
        # Forward every kept record to the flight recorder (its window
        # stays continuous whether tracing is on or off); sids share a
        # namespace inside a dump — flight-native sids start far above
        # the tracer counter, so linkage never collides.
        fr = self._flight
        if fr.enabled:
            fr.record(
                sp.name, sp.cat, sp.sid, sp.parent, sp.tid,
                sp.t0, dur, sp.fence_s, sp.args,
            )

    # -- public ----------------------------------------------------------
    def span(self, name: str, cat: str = "", **args: object):
        if not self.enabled:
            return _NULL
        if self.sample_n > 1:
            if getattr(self._tls, "drop_depth", 0) > 0:
                return self._dropped(name, cat, args)  # child of dropped root
            if not self._stack() and not self._sample_root():
                return self._dropped(name, cat, args)  # root not sampled
        return _Span(self, name, cat, dict(args))

    def _dropped(self, name: str, cat: str, args: Dict[str, Any]):
        """A span the sampler rejects: normally the cheap drop singleton,
        but when the flight recorder is on it records there anyway — the
        flight window is bounded by TIME, not rate, so sampling must not
        punch holes in it. The flight span maintains the tracer's
        drop-depth exactly like the singleton, so children still follow
        their root's fate in the sampled trace."""
        fr = self._flight
        if fr.enabled:
            return fr.span(name, cat, dict(args) if args else None, drop_tls=self._tls)
        return self._drop

    def add_complete(
        self,
        name: str,
        t0: float,
        dur: float,
        cat: str = "",
        tid: Optional[int] = None,
        **args: object,
    ) -> None:
        """Record a span retroactively from (start, duration) timestamps
        measured elsewhere — used for lock-hold segments, which are timed
        by OwnedLock whether or not tracing was on when they began. The
        flight recorder receives these too (when enabled), so incident
        dumps carry lock tracks even with tracing off."""
        fr = self._flight
        if fr.enabled:
            fr.record_complete(
                name, cat, tid if tid is not None else threading.get_ident(),
                t0, dur, dict(args),
            )
        if not self.enabled:
            return
        if tid is None:
            tid = threading.get_ident()
        self._note_thread(tid)
        self.records.append(
            {
                "name": name,
                "cat": cat,
                "sid": self._next_sid(),
                "parent": 0,
                "tid": tid,
                "t0": t0 - self.epoch,
                "dur": dur,
                "args": dict(args),
            }
        )

    def clear(self) -> None:
        self.records.clear()
        self.epoch = time.perf_counter()

    def thread_names(self) -> Dict[int, str]:
        with self._threads_lock:
            return dict(self._threads)


_tracer = Tracer()


def get_tracer() -> Tracer:
    return _tracer


def span(name: str, cat: str = "", **args: object):
    """Open a span on the global tracer (no-op singleton when disabled;
    drop singleton when sampled out — see module docstring). When the
    FLIGHT RECORDER is enabled, a disabled tracer yields a recording
    flight span instead of the null singleton: the last-N-seconds window
    exists whether or not anyone turned tracing on."""
    if not _tracer.enabled:
        fr = _tracer._flight
        if fr.enabled:
            return fr.span(name, cat, dict(args) if args else None)
        return _NULL
    return _tracer.span(name, cat, **args)


def traced(name: Optional[str] = None, cat: str = "") -> Callable:
    """Decorator form of :func:`span`."""

    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a: object, **kw: object):
            if not _tracer.enabled:
                return fn(*a, **kw)
            with _tracer.span(label, cat):
                return fn(*a, **kw)

        return wrapper

    return deco


def enable(sample: Optional[float] = None) -> None:
    """Turn tracing on. ``sample=1/N`` keeps every Nth root span (children
    follow their root); omitted or >= 1 keeps everything."""
    _tracer.set_sample(sample)
    _tracer.enabled = True


def disable() -> None:
    _tracer.enabled = False
    _tracer.set_sample(None)


def enabled() -> bool:
    return _tracer.enabled


def clear() -> None:
    _tracer.clear()
