"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

Every metric carries an optional label set (keyword arguments at
observation time). Internally each metric keeps one cell per distinct
label tuple, so ``counter.inc(rows, writer="3")`` and
``counter.inc(rows, writer="7")`` accumulate independently while
``counter.total()`` sums across all cells.

Design constraints (see docs/observability.md):

- Thread-safe: every mutation takes the metric's lock. Cells are plain
  floats/ints, so a hold is a few hundred nanoseconds.
- Near-zero cost when disabled: each metric checks its registry's
  ``enabled`` flag before doing anything else; a disabled ``inc`` is an
  attribute load and a branch.
- Registries are cheap and independent: a `DistIngestPlane` owns a
  private registry so two planes in one process never share cells, while
  process-wide metrics (writer flush counters, serve-turn histograms)
  live on the default registry from :func:`get_registry`.
"""

from __future__ import annotations

import threading
import weakref
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "all_registries",
    "get_registry",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Metric:
    """Shared plumbing for all metric kinds."""

    kind = "metric"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str = "") -> None:
        self.registry = registry
        self.name = name
        self.help = help
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        return self.registry.enabled


class Counter(_Metric):
    """Monotonic (by convention) float accumulator per label set."""

    kind = "counter"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str = "") -> None:
        super().__init__(registry, name, help)
        self._cells: Dict[LabelKey, float] = {}

    def inc(self, v: float = 1.0, **labels: object) -> None:
        if not self.registry.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            self._cells[key] = self._cells.get(key, 0.0) + v

    def set_value(self, v: float, **labels: object) -> None:
        """Overwrite a cell. Exists for back-compat shims (benches zero
        out counters between rounds); new code should prefer inc/reset."""
        key = _label_key(labels)
        with self._lock:
            self._cells[key] = float(v)

    def value(self, **labels: object) -> float:
        key = _label_key(labels)
        with self._lock:
            return self._cells.get(key, 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._cells.values())

    def cells(self) -> Dict[LabelKey, float]:
        with self._lock:
            return dict(self._cells)

    def reset(self, **labels: object) -> None:
        with self._lock:
            if labels:
                self._cells.pop(_label_key(labels), None)
            else:
                self._cells.clear()


class Gauge(Counter):
    """A counter whose value may move in both directions; ``set`` is the
    primary verb."""

    kind = "gauge"

    def set(self, v: float, **labels: object) -> None:
        if not self.registry.enabled:
            return
        self.set_value(v, **labels)

    def max(self, v: float, **labels: object) -> None:
        """Keep the running maximum (compactor's max_increment_s)."""
        if not self.registry.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            cur = self._cells.get(key)
            if cur is None or v > cur:
                self._cells[key] = float(v)


class Histogram(_Metric):
    """Fixed-bucket histogram; per label set it keeps bucket counts plus
    sum/count/min/max so means and extrema survive bucketing."""

    kind = "histogram"

    DEFAULT_EDGES = (
        0.0001,
        0.00025,
        0.0005,
        0.001,
        0.0025,
        0.005,
        0.01,
        0.025,
        0.05,
        0.1,
        0.25,
        0.5,
        1.0,
        2.5,
        5.0,
        10.0,
    )

    def __init__(
        self,
        registry: "MetricsRegistry",
        name: str,
        help: str = "",
        edges: Optional[Sequence[float]] = None,
    ) -> None:
        super().__init__(registry, name, help)
        e = tuple(float(x) for x in (edges if edges is not None else self.DEFAULT_EDGES))
        if list(e) != sorted(e):
            raise ValueError(f"histogram edges must be sorted: {e}")
        self.edges = e
        # cell: [bucket_counts(len(edges)+1), sum, count, min, max,
        #        exemplar (trace_id, value) | None]
        self._cells: Dict[LabelKey, List] = {}

    def _bucket_index(self, v: float) -> int:
        # First bucket whose upper edge is >= v; values above the last
        # edge land in the overflow bucket. Half-open on the left:
        # bucket i covers (edges[i-1], edges[i]].
        lo, hi = 0, len(self.edges)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.edges[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(
        self, v: float, exemplar: Optional[str] = None, **labels: object
    ) -> None:
        """Record one value. ``exemplar`` attaches a trace id to the cell
        (kept policy: the exemplar of the WORST observation so far — the
        one an SLO investigation wants to pull from the flight recorder);
        it rides along in snapshot()/metrics_snapshot, not in the
        Prometheus 0.0.4 text (which has no exemplar syntax)."""
        if not self.registry.enabled:
            return
        v = float(v)
        key = _label_key(labels)
        idx = self._bucket_index(v)
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                # cell: [buckets, sum, count, min, max, exemplar]
                cell = [[0] * (len(self.edges) + 1), 0.0, 0, v, v, None]
                self._cells[key] = cell
            cell[0][idx] += 1
            cell[1] += v
            cell[2] += 1
            if v < cell[3]:
                cell[3] = v
            if v > cell[4]:
                cell[4] = v
            if exemplar is not None and (cell[5] is None or v >= cell[5][1]):
                cell[5] = (str(exemplar), v)

    def snapshot(self, **labels: object) -> Optional[Dict[str, object]]:
        key = _label_key(labels)
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                return None
            out = {
                "buckets": list(cell[0]),
                "sum": cell[1],
                "count": cell[2],
                "min": cell[3],
                "max": cell[4],
            }
            if cell[5] is not None:
                out["exemplar"] = {"trace_id": cell[5][0], "value": cell[5][1]}
            return out

    def count(self, **labels: object) -> int:
        snap = self.snapshot(**labels)
        return 0 if snap is None else int(snap["count"])

    def sum(self, **labels: object) -> float:
        snap = self.snapshot(**labels)
        return 0.0 if snap is None else float(snap["sum"])

    def max_value(self, **labels: object) -> float:
        snap = self.snapshot(**labels)
        return 0.0 if snap is None else float(snap["max"])

    def cells(self) -> Dict[LabelKey, Dict[str, object]]:
        with self._lock:
            keys = list(self._cells.keys())
        out = {}
        for key in keys:
            labels = dict(key)
            snap = self.snapshot(**labels)
            if snap is not None:
                out[key] = snap
        return out

    def reset(self, **labels: object) -> None:
        with self._lock:
            if labels:
                self._cells.pop(_label_key(labels), None)
            else:
                self._cells.clear()


_ALL: "weakref.WeakSet[MetricsRegistry]" = weakref.WeakSet()
_ALL_LOCK = threading.Lock()


class MetricsRegistry:
    """A named bag of metrics. Creating a metric twice with the same
    name returns the existing instance (kind must match)."""

    def __init__(self, name: str = "default", enabled: bool = True) -> None:
        self.name = name
        self.enabled = enabled
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()
        with _ALL_LOCK:
            _ALL.add(self)

    def _get_or_make(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as {m.kind}, wanted {cls.kind}"
                    )
                return m
            m = cls(self, name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_make(Counter, name, help)  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_make(Gauge, name, help)  # type: ignore[return-value]

    def histogram(
        self, name: str, help: str = "", edges: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get_or_make(Histogram, name, help, edges=edges)  # type: ignore[return-value]

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def reset(self) -> None:
        for m in self.metrics():
            m.reset()  # type: ignore[attr-defined]

    def snapshot(self) -> Dict[str, object]:
        """Plain-data dump of every metric in this registry."""
        out: Dict[str, object] = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                cells = {
                    ",".join(f"{k}={v}" for k, v in key) or "__all__": snap
                    for key, snap in m.cells().items()
                }
                out[m.name] = {"kind": m.kind, "edges": list(m.edges), "cells": cells}
            else:
                cells = {
                    ",".join(f"{k}={v}" for k, v in key) or "__all__": val
                    for key, val in m.cells().items()  # type: ignore[attr-defined]
                }
                out[m.name] = {"kind": m.kind, "cells": cells}
        return out


_default: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry."""
    global _default
    with _default_lock:
        if _default is None:
            _default = MetricsRegistry("default")
        return _default


def all_registries() -> List[MetricsRegistry]:
    with _ALL_LOCK:
        regs = list(_ALL)
    return sorted(regs, key=lambda r: r.name)
