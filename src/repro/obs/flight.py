"""Flight recorder: a bounded, always-available ring of recent spans.

Tracing (`obs.enable()`) answers "show me everything that happened while
I was watching"; the flight recorder answers the question an incident
actually poses — "what happened in the last N seconds, given that nobody
was watching". It keeps a FIXED-SIZE per-thread ring buffer of completed
span records, independent of ``trace.enable()`` and of span sampling:

- **Bounded memory.** Each thread owns one preallocated ring of
  ``per_thread`` slots; the oldest record is overwritten in place. No
  allocation grows with uptime.
- **Lock-free append.** The hot path touches only its own thread's ring
  (a thread-local lookup, a slot store, an index increment) — no lock,
  no cross-thread cache traffic. The creation of a thread's ring is the
  only synchronized step, paid once per thread.
- **Independent of tracing.** With tracing disabled, ``obs.span(...)``
  returns a recording flight span instead of the null singleton; with
  tracing enabled, every record the tracer keeps is forwarded here, and
  spans the SAMPLER would drop are still captured (the flight window has
  no sampling — its bound is time, not rate).
- **dump(window_s)** composes a Perfetto-valid Chrome trace of the last
  N seconds (same event shape as ``export.chrome_trace``); parent links
  that point outside the window are cleared so the dump always validates
  (``export.validate_chrome_trace``).

Cost is gated like disabled spans: tests/test_obs.py bounds the
flight-enabled span cost at < 2% of a scan microbench step, the same
budget the disabled-tracing gate enforces.

``fence()`` on a flight span passes values through WITHOUT blocking —
the same contract as disabled tracing, so enabling the recorder never
changes hot-path synchronization behavior.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "FlightRecorder",
    "flight_clear",
    "flight_disable",
    "flight_dump",
    "flight_enable",
    "flight_enabled",
    "get_flight",
]

# Flight sids live far above any plausible tracer sid so the two
# namespaces never collide inside one dump (tracer sids are a per-process
# counter from 1; flight sids are per-ring blocks starting here).
_SID_BASE = 1 << 40
_RING_STRIDE = 1 << 28  # max records one ring can ever number


class _Ring:
    """One thread's record ring. Only its owner thread writes; dump()
    readers take a point-in-time copy of the slot list (safe under the
    GIL — a torn read can at worst observe one record twice or miss the
    very newest, never corrupt one)."""

    __slots__ = ("slots", "i", "cap", "sid_base", "seq", "stack", "tid", "name")

    def __init__(self, cap: int, ring_index: int, tid: int, name: str) -> None:
        self.cap = cap
        self.slots: List[Optional[Tuple]] = [None] * cap
        self.i = 0
        self.sid_base = _SID_BASE + ring_index * _RING_STRIDE
        self.seq = 0
        self.stack: List[int] = []  # open flight-span sids, innermost last
        self.tid = tid
        self.name = name


class _FlightSpan:
    """Recording span used when the tracer is off (or sampled this span
    out). Parent linkage is per-ring: the innermost open flight span on
    this thread is the parent. When standing in for a sampled-out tracer
    span, it also maintains the tracer's thread-local drop depth so
    children keep following their root's fate (`drop_tls`)."""

    __slots__ = ("fr", "ring", "name", "cat", "args", "sid", "parent", "t0", "drop_tls")

    def __init__(self, fr: "FlightRecorder", name: str, cat: str,
                 args: Optional[Dict[str, Any]], drop_tls=None) -> None:
        self.fr = fr
        self.name = name
        self.cat = cat
        self.args = args
        self.drop_tls = drop_tls
        self.ring = None
        self.sid = 0
        self.parent = 0
        self.t0 = 0.0

    # reprolint: hot-path — flight append must stay sync-free
    def __enter__(self) -> "_FlightSpan":
        ring = self.fr._ring()
        self.ring = ring
        ring.seq += 1
        self.sid = ring.sid_base + ring.seq
        self.parent = ring.stack[-1] if ring.stack else 0
        ring.stack.append(self.sid)
        tls = self.drop_tls
        if tls is not None:
            tls.drop_depth = getattr(tls, "drop_depth", 0) + 1
        self.t0 = time.perf_counter()
        return self

    # reprolint: hot-path — flight append must stay sync-free
    def __exit__(self, *exc: object) -> None:
        t1 = time.perf_counter()
        ring = self.ring
        if ring.stack and ring.stack[-1] == self.sid:
            ring.stack.pop()
        ring.slots[ring.i % ring.cap] = (
            self.name, self.cat, self.sid, self.parent, ring.tid,
            self.t0, t1 - self.t0, 0.0, self.args,
        )
        ring.i += 1
        tls = self.drop_tls
        if tls is not None:
            tls.drop_depth -= 1

    def fence(self, x: object) -> object:
        """Pass-through WITHOUT blocking (disabled-tracing contract): the
        recorder never adds a device sync to a hot path."""
        return x

    def set(self, **kw: object) -> None:
        if self.args is None:
            self.args = dict(kw)
        else:
            self.args.update(kw)


class FlightRecorder:
    def __init__(self, per_thread: int = 8192) -> None:
        self.enabled = False
        self.per_thread = per_thread
        self._tls = threading.local()
        self._rings: Dict[int, _Ring] = {}  # guarded-by: _rings_lock
        self._next_ring = 0  # guarded-by: _rings_lock
        self._rings_lock = threading.Lock()

    # ------------------------------------------------------------ hot path
    def _ring(self) -> _Ring:
        ring = getattr(self._tls, "ring", None)
        if ring is None:
            ring = self._make_ring()
        return ring

    def _make_ring(self) -> _Ring:
        tid = threading.get_ident()
        with self._rings_lock:
            self._next_ring += 1
            ring = _Ring(
                self.per_thread, self._next_ring, tid,
                threading.current_thread().name,
            )
            # A reused OS thread id keeps its newest ring in the registry
            # (the old thread is gone; its open-span stack died with it).
            self._rings[tid] = ring
        self._tls.ring = ring
        return ring

    def span(self, name: str, cat: str = "",
             args: Optional[Dict[str, Any]] = None, drop_tls=None) -> _FlightSpan:
        return _FlightSpan(self, name, cat, args, drop_tls=drop_tls)

    # reprolint: hot-path — forwarded tracer records append sync-free too
    def record(self, name: str, cat: str, sid: int, parent: int, tid: int,
               t0: float, dur: float, fence_s: float,
               args: Optional[Dict[str, Any]]) -> None:
        """Append one completed record with caller-supplied identity —
        the tracer forwards every record it keeps through here, so the
        flight window stays continuous whether or not tracing is on."""
        ring = self._ring()
        ring.slots[ring.i % ring.cap] = (
            name, cat, sid, parent, tid, t0, dur, fence_s, args,
        )
        ring.i += 1

    # reprolint: hot-path
    def record_complete(self, name: str, cat: str, tid: int, t0: float,
                        dur: float, args: Optional[Dict[str, Any]]) -> None:
        """Retroactive parentless record with a fresh flight sid (the
        lock-hold add_complete path)."""
        ring = self._ring()
        ring.seq += 1
        ring.slots[ring.i % ring.cap] = (
            name, cat, ring.sid_base + ring.seq, 0, tid, t0, dur, 0.0, args,
        )
        ring.i += 1

    # ------------------------------------------------------------- control
    def enable(self, per_thread: Optional[int] = None) -> None:
        if per_thread is not None and per_thread != self.per_thread:
            self.per_thread = int(per_thread)
            self.clear()
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._rings_lock:
            self._rings.clear()
        # Live threads drop their ring lazily: _ring() re-registers a
        # fresh one on next append (self._tls is per-thread, so clear()
        # can only reset its OWN thread's cached ring eagerly).
        self._tls.ring = None

    # --------------------------------------------------------------- dump
    def records(self, window_s: Optional[float] = None) -> List[Dict[str, Any]]:
        """Snapshot of retained records (all threads), oldest first,
        optionally filtered to spans that END within the last window_s
        seconds."""
        cut = None if window_s is None else time.perf_counter() - window_s
        out: List[Dict[str, Any]] = []
        with self._rings_lock:
            rings = list(self._rings.values())
        for ring in rings:
            slots = list(ring.slots)  # point-in-time copy
            i, cap = ring.i, ring.cap
            order = range(i - cap, i) if i > cap else range(i)
            for j in order:
                rec = slots[j % cap]
                if rec is None:
                    continue
                name, cat, sid, parent, tid, t0, dur, fence_s, args = rec
                if cut is not None and (t0 + dur) < cut:
                    continue
                out.append(
                    {
                        "name": name, "cat": cat, "sid": sid,
                        "parent": parent, "tid": tid, "t0": t0,
                        "dur": dur, "fence_s": fence_s,
                        "args": {} if args is None else dict(args),
                    }
                )
        out.sort(key=lambda r: r["t0"])
        return out

    def dump(self, window_s: float = 30.0) -> Dict[str, Any]:
        """Chrome trace doc of the last ``window_s`` seconds across every
        thread — the incident artifact. Parent sids that fell out of the
        window are cleared (oldest-evicted rings and the window cut can
        both orphan a child), so the result always passes
        ``export.validate_chrome_trace``."""
        recs = self.records(window_s=window_s)
        with self._rings_lock:
            threads = {r.tid: r.name for r in self._rings.values()}
        events: List[Dict[str, Any]] = []
        for tid, name in sorted(threads.items()):
            events.append(
                {"ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                 "args": {"name": name}}
            )
        kept = {r["sid"] for r in recs}
        base = min((r["t0"] for r in recs), default=0.0)
        for r in recs:
            args = dict(r["args"])
            args["sid"] = r["sid"]
            if r["parent"] and r["parent"] in kept:
                args["parent"] = r["parent"]
            if r["fence_s"]:
                args["device_fence_us"] = round(r["fence_s"] * 1e6, 3)
            events.append(
                {
                    "ph": "X",
                    "name": r["name"],
                    "cat": r["cat"] or "span",
                    "pid": 1,
                    "tid": r["tid"],
                    "ts": round((r["t0"] - base) * 1e6, 3),
                    "dur": round(r["dur"] * 1e6, 3),
                    "args": args,
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}


_flight = FlightRecorder()


def get_flight() -> FlightRecorder:
    return _flight


def flight_enable(per_thread: Optional[int] = None) -> None:
    """Turn the flight recorder on (independent of trace.enable())."""
    _flight.enable(per_thread=per_thread)


def flight_disable() -> None:
    _flight.disable()


def flight_enabled() -> bool:
    return _flight.enabled


def flight_clear() -> None:
    _flight.clear()


def flight_dump(window_s: float = 30.0) -> Dict[str, Any]:
    """Chrome trace of the last ``window_s`` seconds (see
    :meth:`FlightRecorder.dump`)."""
    return _flight.dump(window_s)
