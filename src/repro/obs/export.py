"""Exporters: JSON metrics snapshot, Chrome trace-event file, terminal
summary table.

- :func:`metrics_snapshot` / :func:`write_metrics_json` — one JSON doc
  merging every registry plus lock occupancy, with the same
  ``schema_version`` discipline as benchmarks/BENCH_query_concurrency.json.
- :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome trace-event
  format (``{"traceEvents": [...]}`` with "X" complete events, µs
  timestamps), loadable at https://ui.perfetto.dev.
- :func:`to_prometheus_text` — Prometheus text exposition format
  (version 0.0.4): HELP/TYPE headers, one sample line per label cell,
  histograms as cumulative ``_bucket{le=...}`` series plus ``_sum`` /
  ``_count``. Serve it from any HTTP handler to scrape the plane.
- :func:`serve_prometheus` — a daemon-thread HTTP pull endpoint serving
  that text at ``/metrics``, so a real Prometheus server can scrape a
  live plane without any in-process glue.
- :func:`summary` — a plain-text table for terminal use.
"""

from __future__ import annotations

import json
import re
import threading
from typing import Any, Dict, List, Optional

from .occupancy import occupancy_snapshot
from .registry import all_registries
from .trace import get_tracer

__all__ = [
    "chrome_trace",
    "metrics_snapshot",
    "serve_prometheus",
    "summary",
    "to_prometheus_text",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_metrics_json",
]

SNAPSHOT_SCHEMA_VERSION = 1


def metrics_snapshot() -> Dict[str, Any]:
    registries = {}
    for reg in all_registries():
        snap = reg.snapshot()
        if not snap:
            continue
        if reg.name in registries:
            # Two registries with the same name (e.g. two planes named
            # identically): suffix to keep both visible.
            i = 2
            while f"{reg.name}#{i}" in registries:
                i += 1
            registries[f"{reg.name}#{i}"] = snap
        else:
            registries[reg.name] = snap
    return {
        "schema_version": SNAPSHOT_SCHEMA_VERSION,
        "kind": "obs_metrics_snapshot",
        "registries": registries,
        "lock_occupancy": occupancy_snapshot(),
    }


def write_metrics_json(path: str) -> Dict[str, Any]:
    snap = metrics_snapshot()
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return snap


def chrome_trace() -> Dict[str, Any]:
    tr = get_tracer()
    events: List[Dict[str, Any]] = []
    for tid, name in sorted(tr.thread_names().items()):
        events.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": 1,
                "tid": tid,
                "args": {"name": name},
            }
        )
    for rec in list(tr.records):
        args = dict(rec["args"])
        args["sid"] = rec["sid"]
        if rec["parent"]:
            args["parent"] = rec["parent"]
        if "fence_s" in rec:
            args["device_fence_us"] = round(rec["fence_s"] * 1e6, 3)
        events.append(
            {
                "ph": "X",
                "name": rec["name"],
                "cat": rec["cat"] or "span",
                "pid": 1,
                "tid": rec["tid"],
                "ts": round(rec["t0"] * 1e6, 3),
                "dur": round(rec["dur"] * 1e6, 3),
                "args": args,
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str) -> Dict[str, Any]:
    doc = chrome_trace()
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Return a list of schema problems (empty == valid). Used by both
    tests/test_obs.py and the CI observability smoke."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    sids = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev or "tid" not in ev:
            problems.append(f"event {i}: missing name/pid/tid")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
                problems.append(f"event {i}: ts/dur not numeric")
            elif dur < 0:
                problems.append(f"event {i}: negative dur")
            sid = ev.get("args", {}).get("sid")
            if sid is not None:
                sids.add(sid)
    for i, ev in enumerate(events):
        if ev.get("ph") != "X":
            continue
        parent = ev.get("args", {}).get("parent")
        if parent is not None and parent not in sids:
            problems.append(f"event {i}: parent sid {parent} not present")
    return problems


_PROM_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _PROM_NAME_BAD.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _prom_escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _prom_labels(key, extra: Optional[str] = None) -> str:
    """Render a registry LabelKey (sorted (k, v) tuple) as {k="v",...};
    `extra` is a pre-rendered pair appended last (the histogram `le`)."""
    parts = [f'{_prom_name(k)}="{_prom_escape(v)}"' for k, v in key]
    if extra is not None:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _prom_val(v: float) -> str:
    f = float(v)
    if f != f:  # NaN
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus_text(registry=None) -> str:
    """Render metrics in the Prometheus text exposition format (0.0.4).

    With `registry` given, exports that one registry; with None, exports
    every live registry (metric names deduped first-wins, matching the
    Prometheus rule that a name appears in one HELP/TYPE group only —
    duplicate names across planes keep only the first registry's cells,
    same precedence as :func:`metrics_snapshot`'s name suffixing).

    Counters export as-is (names are already `_total`-style by repo
    convention), gauges as gauges, histograms as cumulative
    `_bucket{le="..."}` series plus `_sum` and `_count` — the registry's
    per-bucket counts are partial sums, so the cumulative series here is
    exact, including the `+Inf` overflow bucket.
    """
    from .registry import Histogram

    regs = [registry] if registry is not None else all_registries()
    lines: List[str] = []
    seen: set = set()
    for reg in regs:
        for m in reg.metrics():
            name = _prom_name(m.name)
            if name in seen:
                continue
            seen.add(name)
            cells = m.cells()
            if not cells:
                continue
            if m.help:
                lines.append(f"# HELP {name} {_prom_escape(m.help)}")
            lines.append(f"# TYPE {name} {'histogram' if m.kind == 'histogram' else m.kind}")
            if isinstance(m, Histogram):
                for key in sorted(cells):
                    cell = cells[key]
                    cum = 0
                    for edge, n in zip(m.edges, cell["buckets"]):
                        cum += n
                        le = f'le="{_prom_val(edge)}"'
                        lines.append(f"{name}_bucket{_prom_labels(key, le)} {cum}")
                    inf_le = 'le="+Inf"'
                    lines.append(f"{name}_bucket{_prom_labels(key, inf_le)} {cell['count']}")
                    lines.append(f"{name}_sum{_prom_labels(key)} {_prom_val(cell['sum'])}")
                    lines.append(f"{name}_count{_prom_labels(key)} {cell['count']}")
            else:
                for key in sorted(cells):
                    lines.append(f"{name}{_prom_labels(key)} {_prom_val(cells[key])}")
    return "\n".join(lines) + "\n" if lines else ""


class _PrometheusEndpoint:
    """Handle returned by :func:`serve_prometheus`. Context-manager and
    explicit ``stop()`` both shut the server down; the serving thread is
    a daemon so a forgotten handle never blocks interpreter exit."""

    def __init__(self, server, thread: threading.Thread, host: str) -> None:
        self._server = server
        self._thread = thread
        self.host = host
        self.port = server.server_address[1]
        self.url = f"http://{host}:{self.port}/metrics"

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "_PrometheusEndpoint":
        return self

    def __exit__(self, *exc: object) -> None:
        self.stop()


def serve_prometheus(registry=None, port: int = 0, host: str = "127.0.0.1") -> _PrometheusEndpoint:
    """Start a daemon-thread HTTP server exposing :func:`to_prometheus_text`
    at ``/metrics`` (any other path 404s). ``port=0`` binds an ephemeral
    port; read it back from the returned handle's ``.port`` / ``.url``.
    Scoped to one registry when given, every live registry otherwise —
    the text is rendered fresh per scrape, so no state is cached.

    Concurrency contract (tests hammer this from many threads during
    live ingest): the text is rendered from per-cell locked snapshots,
    so every histogram cell a scrape sees is internally consistent
    (cumulative buckets monotone, +Inf bucket == count) even while
    writers observe concurrently; a scraper that disconnects mid-write
    is swallowed (no traceback, no dead handler thread); and stop()
    closes the listening socket before returning, so the port is
    immediately rebindable."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self) -> None:  # noqa: N802 - http.server API
            try:
                if self.path.split("?", 1)[0] != "/metrics":
                    self.send_error(404, "only /metrics is served")
                    return
                # Render BEFORE the status line: a mid-render failure
                # must produce a clean 500, not a half-sent 200.
                body = to_prometheus_text(registry).encode("utf-8")
            except (BrokenPipeError, ConnectionResetError):
                return  # scraper gone; nothing to answer
            except Exception as e:  # defensive: never kill the endpoint
                try:
                    self.send_error(500, f"metrics render failed: {e}")
                except OSError:
                    pass
                return
            try:
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
                )
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            except (BrokenPipeError, ConnectionResetError, OSError):
                pass  # scraper disconnected mid-write; drop silently

        def log_message(self, format: str, *args: object) -> None:
            pass  # scrapes are high-frequency; keep stderr quiet

    class _Server(ThreadingHTTPServer):
        def handle_error(self, request, client_address) -> None:
            pass  # per-connection errors are handled in do_GET; no stderr spew

    server = _Server((host, port), _Handler)
    server.daemon_threads = True
    thread = threading.Thread(
        target=server.serve_forever, name="prometheus-scrape", daemon=True
    )
    thread.start()
    return _PrometheusEndpoint(server, thread, host)


def _fmt_labels(key: str) -> str:
    return "" if key == "__all__" else f"{{{key}}}"


def summary(width: int = 78) -> str:
    """Terminal summary: lock occupancy first (the headline), then every
    non-empty metric."""
    lines: List[str] = []
    occ = occupancy_snapshot()
    if occ:
        lines.append("== lock occupancy ==")
        for name, snap in sorted(occ.items()):
            total = float(snap["total_held_s"])
            lines.append(
                f"{name}: held {total * 1e3:.1f} ms over {snap['acquisitions']} acquisitions"
            )
            by = snap["by_owner_s"]
            for owner, secs in sorted(by.items(), key=lambda kv: -kv[1]):
                frac = (secs / total * 100.0) if total > 0 else 0.0
                n = snap["acq_by_owner"].get(owner, 0)
                lines.append(f"  {owner:<16} {secs * 1e3:>10.1f} ms  {frac:>5.1f}%  (n={n})")
    for reg in all_registries():
        snap = reg.snapshot()
        if not snap:
            continue
        lines.append(f"== registry: {reg.name} ==")
        for mname in sorted(snap):
            m = snap[mname]
            if m["kind"] == "histogram":
                for key, cell in sorted(m["cells"].items()):
                    mean = cell["sum"] / cell["count"] if cell["count"] else 0.0
                    lines.append(
                        f"{mname}{_fmt_labels(key)}: n={cell['count']} "
                        f"mean={mean * 1e3:.2f}ms min={cell['min'] * 1e3:.2f}ms "
                        f"max={cell['max'] * 1e3:.2f}ms"
                    )
            else:
                for key, val in sorted(m["cells"].items()):
                    if isinstance(val, float) and val == int(val):
                        val = int(val)
                    lines.append(f"{mname}{_fmt_labels(key)}: {val}")
    return "\n".join(lines)
