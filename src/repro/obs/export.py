"""Exporters: JSON metrics snapshot, Chrome trace-event file, terminal
summary table.

- :func:`metrics_snapshot` / :func:`write_metrics_json` — one JSON doc
  merging every registry plus lock occupancy, with the same
  ``schema_version`` discipline as benchmarks/BENCH_query_concurrency.json.
- :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome trace-event
  format (``{"traceEvents": [...]}`` with "X" complete events, µs
  timestamps), loadable at https://ui.perfetto.dev.
- :func:`summary` — a plain-text table for terminal use.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .occupancy import occupancy_snapshot
from .registry import all_registries
from .trace import get_tracer

__all__ = [
    "chrome_trace",
    "metrics_snapshot",
    "summary",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_metrics_json",
]

SNAPSHOT_SCHEMA_VERSION = 1


def metrics_snapshot() -> Dict[str, Any]:
    registries = {}
    for reg in all_registries():
        snap = reg.snapshot()
        if not snap:
            continue
        if reg.name in registries:
            # Two registries with the same name (e.g. two planes named
            # identically): suffix to keep both visible.
            i = 2
            while f"{reg.name}#{i}" in registries:
                i += 1
            registries[f"{reg.name}#{i}"] = snap
        else:
            registries[reg.name] = snap
    return {
        "schema_version": SNAPSHOT_SCHEMA_VERSION,
        "kind": "obs_metrics_snapshot",
        "registries": registries,
        "lock_occupancy": occupancy_snapshot(),
    }


def write_metrics_json(path: str) -> Dict[str, Any]:
    snap = metrics_snapshot()
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return snap


def chrome_trace() -> Dict[str, Any]:
    tr = get_tracer()
    events: List[Dict[str, Any]] = []
    for tid, name in sorted(tr.thread_names().items()):
        events.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": 1,
                "tid": tid,
                "args": {"name": name},
            }
        )
    for rec in list(tr.records):
        args = dict(rec["args"])
        args["sid"] = rec["sid"]
        if rec["parent"]:
            args["parent"] = rec["parent"]
        if "fence_s" in rec:
            args["device_fence_us"] = round(rec["fence_s"] * 1e6, 3)
        events.append(
            {
                "ph": "X",
                "name": rec["name"],
                "cat": rec["cat"] or "span",
                "pid": 1,
                "tid": rec["tid"],
                "ts": round(rec["t0"] * 1e6, 3),
                "dur": round(rec["dur"] * 1e6, 3),
                "args": args,
            }
        )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str) -> Dict[str, Any]:
    doc = chrome_trace()
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Return a list of schema problems (empty == valid). Used by both
    tests/test_obs.py and the CI observability smoke."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents key"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    sids = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev or "tid" not in ev:
            problems.append(f"event {i}: missing name/pid/tid")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
                problems.append(f"event {i}: ts/dur not numeric")
            elif dur < 0:
                problems.append(f"event {i}: negative dur")
            sid = ev.get("args", {}).get("sid")
            if sid is not None:
                sids.add(sid)
    for i, ev in enumerate(events):
        if ev.get("ph") != "X":
            continue
        parent = ev.get("args", {}).get("parent")
        if parent is not None and parent not in sids:
            problems.append(f"event {i}: parent sid {parent} not present")
    return problems


def _fmt_labels(key: str) -> str:
    return "" if key == "__all__" else f"{{{key}}}"


def summary(width: int = 78) -> str:
    """Terminal summary: lock occupancy first (the headline), then every
    non-empty metric."""
    lines: List[str] = []
    occ = occupancy_snapshot()
    if occ:
        lines.append("== lock occupancy ==")
        for name, snap in sorted(occ.items()):
            total = float(snap["total_held_s"])
            lines.append(
                f"{name}: held {total * 1e3:.1f} ms over {snap['acquisitions']} acquisitions"
            )
            by = snap["by_owner_s"]
            for owner, secs in sorted(by.items(), key=lambda kv: -kv[1]):
                frac = (secs / total * 100.0) if total > 0 else 0.0
                n = snap["acq_by_owner"].get(owner, 0)
                lines.append(f"  {owner:<16} {secs * 1e3:>10.1f} ms  {frac:>5.1f}%  (n={n})")
    for reg in all_registries():
        snap = reg.snapshot()
        if not snap:
            continue
        lines.append(f"== registry: {reg.name} ==")
        for mname in sorted(snap):
            m = snap[mname]
            if m["kind"] == "histogram":
                for key, cell in sorted(m["cells"].items()):
                    mean = cell["sum"] / cell["count"] if cell["count"] else 0.0
                    lines.append(
                        f"{mname}{_fmt_labels(key)}: n={cell['count']} "
                        f"mean={mean * 1e3:.2f}ms min={cell['min'] * 1e3:.2f}ms "
                        f"max={cell['max'] * 1e3:.2f}ms"
                    )
            else:
                for key, val in sorted(m["cells"].items()):
                    if isinstance(val, float) and val == int(val):
                        val = int(val)
                    lines.append(f"{mname}{_fmt_labels(key)}: {val}")
    return "\n".join(lines)
