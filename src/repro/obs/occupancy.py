"""Device-lock occupancy attribution.

The serialization points that govern TTFR in this system are two plain
``threading.Lock`` objects: the `DistIngestPlane` plane lock and the
`QueryService` device lock. :class:`OwnedLock` is a drop-in wrapper that
tags every hold with an *owner class* (``session_turn``,
``fold_increment``, ``publish_seal``, ``ingest_append``,
``density_read``, ...) and accounts the held wall time per owner, so an
occupancy report answers exactly the paper's attribution question: of
the time the device was serialized, which stage owned it?

Accounting invariant: a hold is partitioned into contiguous segments,
one per owner (``reowner`` splits a hold mid-way, e.g. a serve turn that
discovers it must first build the run does its planning/density reads
under ``density_read`` and only then re-owns as ``session_turn``).
Per-owner seconds therefore sum to ``total_held`` *exactly* — the 5%
tolerance in the acceptance criteria covers only the test's independent
wall-clock re-measurement, not the books.

Besides HELD time, every lock also books ACQUIRE-WAIT time: the wall
seconds a would-be holder spent inside ``acquire`` before getting the
lock, per owner class (``total_wait_s`` / ``wait_by_owner_s`` in the
snapshot). Held time answers "who serialized the device"; wait time
answers "who was serialized BEHIND whom" — the sharded ingest plane's
contention columns (``lock_group_*`` in bench_ingest_scaling) and the
disjoint-group overlap tests read exactly this: writers on different
tablet groups must show ~zero wait on each other's group locks, while
the single-lock baseline's waits are the cost the sharding removed.

API mirrors ``threading.Lock`` (acquire/release/context manager) so all
existing ``with plane._lock:`` call sites keep working; unattributed
holds are charged to ``unknown``, which CI asserts is absent on the
instrumented paths.
"""

from __future__ import annotations

import threading
import time
import weakref
from contextlib import contextmanager
from typing import Dict, List, Optional

from . import trace as _trace

__all__ = ["OwnedLock", "all_locks", "occupancy_snapshot"]

_LOCKS: "weakref.WeakSet[OwnedLock]" = weakref.WeakSet()
_LOCKS_LOCK = threading.Lock()


class OwnedLock:
    """A ``threading.Lock`` with per-owner held-time attribution."""

    def __init__(self, name: str) -> None:
        self.name = name
        self._lock = threading.Lock()
        # _slock guards the books (owner tallies + current-hold state);
        # it is only ever held for a few arithmetic ops.
        self._slock = threading.Lock()
        self.total_held = 0.0
        self.total_wait = 0.0
        self.acquisitions = 0
        self.by_owner: Dict[str, float] = {}
        self.acq_by_owner: Dict[str, int] = {}
        self.wait_by_owner: Dict[str, float] = {}
        self._hold_t0: Optional[float] = None
        self._seg_t0: Optional[float] = None
        self._owner: Optional[str] = None
        self._owner_tid: int = 0
        with _LOCKS_LOCK:
            _LOCKS.add(self)

    # -- core protocol ---------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1, owner: str = "unknown") -> bool:
        t_wait = time.perf_counter()
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            now = time.perf_counter()
            with self._slock:
                waited = now - t_wait
                self.total_wait += waited
                self.wait_by_owner[owner] = self.wait_by_owner.get(owner, 0.0) + waited
                self.acquisitions += 1
                self._hold_t0 = now
                self._seg_t0 = now
                self._owner = owner
                self._owner_tid = threading.get_ident()
        return ok

    def release(self) -> None:
        now = time.perf_counter()
        with self._slock:
            self._charge_segment(now)
            if self._hold_t0 is not None:
                self.total_held += now - self._hold_t0
            t0, tid, owner = self._hold_t0, self._owner_tid, self._owner
            self._hold_t0 = None
            self._seg_t0 = None
            self._owner = None
        self._lock.release()
        if t0 is not None and _trace._tracer.enabled:
            _trace._tracer.add_complete(
                f"lock/{self.name}", t0, now - t0, cat="lock", tid=tid, owner=owner or "unknown"
            )

    def _charge_segment(self, now: float) -> None:
        # caller holds _slock
        if self._seg_t0 is None or self._owner is None:
            return
        dt = now - self._seg_t0
        self.by_owner[self._owner] = self.by_owner.get(self._owner, 0.0) + dt
        self.acq_by_owner[self._owner] = self.acq_by_owner.get(self._owner, 0) + 1
        self._seg_t0 = now

    def __enter__(self) -> "OwnedLock":
        self.acquire()
        return self

    def __exit__(self, *exc: object) -> None:
        self.release()

    def locked(self) -> bool:
        return self._lock.locked()

    # -- attribution verbs ----------------------------------------------
    @contextmanager
    def hold(self, owner: str):
        """``with lock.hold("ingest_append"):`` — acquire with an owner."""
        self.acquire(owner=owner)
        try:
            yield self
        finally:
            self.release()

    @contextmanager
    def reowner(self, owner: str):
        """Re-attribute the *current* hold to ``owner`` for the duration
        of the block, then restore the previous owner. Must be called by
        the holding thread."""
        now = time.perf_counter()
        with self._slock:
            prev = self._owner
            self._charge_segment(now)
            self._owner = owner
        try:
            yield self
        finally:
            now = time.perf_counter()
            with self._slock:
                self._charge_segment(now)
                self._owner = prev

    # -- reporting -------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        now = time.perf_counter()
        with self._slock:
            by_owner = dict(self.by_owner)
            total = self.total_held
            # A snapshot taken mid-hold still balances: fold the open
            # segment into both sides.
            if self._hold_t0 is not None:
                total += now - self._hold_t0
                if self._owner is not None and self._seg_t0 is not None:
                    by_owner[self._owner] = by_owner.get(self._owner, 0.0) + (now - self._seg_t0)
            return {
                "name": self.name,
                "total_held_s": total,
                "total_wait_s": self.total_wait,
                "acquisitions": self.acquisitions,
                "by_owner_s": by_owner,
                "acq_by_owner": dict(self.acq_by_owner),
                "wait_by_owner_s": dict(self.wait_by_owner),
            }

    def reset(self) -> None:
        with self._slock:
            self.total_held = 0.0
            self.total_wait = 0.0
            self.acquisitions = 0
            self.by_owner.clear()
            self.acq_by_owner.clear()
            self.wait_by_owner.clear()


def all_locks() -> List[OwnedLock]:
    with _LOCKS_LOCK:
        locks = list(_LOCKS)
    return sorted(locks, key=lambda l: l.name)


def occupancy_snapshot() -> Dict[str, Dict[str, object]]:
    """Per-lock occupancy, aggregated by lock name (two planes created
    with the same name merge their books in the report)."""
    out: Dict[str, Dict[str, object]] = {}
    for lk in all_locks():
        snap = lk.snapshot()
        cur = out.get(lk.name)
        if cur is None:
            out[lk.name] = snap
        else:
            cur["total_held_s"] = float(cur["total_held_s"]) + float(snap["total_held_s"])
            cur["total_wait_s"] = float(cur["total_wait_s"]) + float(snap["total_wait_s"])
            cur["acquisitions"] = int(cur["acquisitions"]) + int(snap["acquisitions"])
            for k, v in snap["by_owner_s"].items():  # type: ignore[union-attr]
                cur["by_owner_s"][k] = cur["by_owner_s"].get(k, 0.0) + v  # type: ignore[index]
            for k, v in snap["acq_by_owner"].items():  # type: ignore[union-attr]
                cur["acq_by_owner"][k] = cur["acq_by_owner"].get(k, 0) + v  # type: ignore[index]
            for k, v in snap["wait_by_owner_s"].items():  # type: ignore[union-attr]
                cur["wait_by_owner_s"][k] = cur["wait_by_owner_s"].get(k, 0.0) + v  # type: ignore[index]
    return out
