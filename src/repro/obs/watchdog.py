"""SLO watchdog: declarative sliding-window rules over the obs plane.

The paper's serving claim is a latency *objective* (initial result sets
arrive fast, even under ingest); this module is the piece that holds a
long-running deployment to it. A :class:`Watchdog` thread evaluates
declarative :class:`WatchRule`s on a fixed tick; each rule aggregates a
probe over a sliding time window (p99 of TTFR events, max per-group lock
acquire-wait delta, the compactor's worst increment, per-writer blocked
seconds) and compares against a threshold. On breach it

- bumps ``watchdog_incidents_total{rule=...}`` on the default registry,
- writes an **incident bundle** to the incident directory:
  ``incident.json`` (rule, value, threshold, window), ``trace.json``
  (the flight recorder's last-N-seconds dump — the evidence that is
  normally gone by the time anyone looks), and ``metrics.json``
  (a full ``export.metrics_snapshot``),

then holds its fire for ``cooldown_s`` so a sustained breach produces a
bundle per cooldown period, not per tick.

Probe shapes, by ``agg``:

- ``"p99"`` / ``"max"`` — *event* probes: callable returning an iterable
  of ``(t, value)`` samples produced since the last call (t =
  ``time.perf_counter()``); the watchdog windows and aggregates them.
- ``"delta"`` — *cumulative* probes: callable returning a monotonic
  total (lock wait seconds, blocked seconds); the value is the increase
  over the window.
- ``"gauge"`` — instantaneous probes: callable returning the current
  value (the compactor's max-increment gauge).

Rule construction helpers for the common lock/counter probes live here;
the TTFR event source lives with the serve plane
(`repro.serve_db.profile.ttfr_event_probe`) — obs stays import-free of
serve_db.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .flight import get_flight
from .occupancy import occupancy_snapshot
from .registry import get_registry

__all__ = [
    "WatchRule",
    "Watchdog",
    "counter_delta_rule",
    "gauge_rule",
    "lock_wait_rule",
]

_AGGS = ("p99", "max", "delta", "gauge")


class WatchRule:
    """One declarative SLO: ``agg(probe, window_s) > threshold`` is a
    breach. See module docstring for the probe shape per ``agg``."""

    def __init__(
        self,
        name: str,
        probe: Callable[[], Any],
        threshold: float,
        window_s: float = 30.0,
        agg: str = "p99",
        cooldown_s: float = 30.0,
        help: str = "",
    ) -> None:
        if agg not in _AGGS:
            raise ValueError(f"agg must be one of {_AGGS}: {agg!r}")
        self.name = name
        self.probe = probe
        self.threshold = float(threshold)
        self.window_s = float(window_s)
        self.agg = agg
        self.cooldown_s = float(cooldown_s)
        self.help = help

    def describe(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "agg": self.agg,
            "threshold": self.threshold,
            "window_s": self.window_s,
            "cooldown_s": self.cooldown_s,
            "help": self.help,
        }


def _p99(values: List[float]) -> float:
    if not values:
        return 0.0
    vs = sorted(values)
    # Nearest-rank p99 (the convention bench_query_concurrency uses).
    rank = max(0, min(len(vs) - 1, int(round(0.99 * (len(vs) - 1)))))
    return vs[rank]


class Watchdog:
    """Evaluate rules every ``interval_s`` on a daemon thread; write
    incident bundles on breach. Use as a context manager or call
    start()/stop()."""

    def __init__(
        self,
        rules: Iterable[WatchRule],
        incident_dir: str = "incidents",
        interval_s: float = 0.25,
        flight_window_s: float = 30.0,
        registry=None,
    ) -> None:
        self.rules = list(rules)
        self.incident_dir = incident_dir
        self.interval_s = float(interval_s)
        self.flight_window_s = float(flight_window_s)
        self._flight = get_flight()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        # Per-rule sliding sample window and breach bookkeeping. Written
        # by the watchdog thread, read by incidents()/values() callers.
        self._windows: Dict[str, deque] = {  # guarded-by: _lock
            r.name: deque() for r in self.rules
        }
        self._last_fire: Dict[str, float] = {}  # guarded-by: _lock
        self._incidents: List[Dict[str, Any]] = []  # guarded-by: _lock
        self._values: Dict[str, float] = {}  # guarded-by: _lock
        reg = registry if registry is not None else get_registry()
        self._m_incidents = reg.counter(
            "watchdog_incidents_total", "SLO breaches, by rule"
        )
        self._m_value = reg.gauge(
            "watchdog_rule_value", "last windowed value per rule"
        )
        self._m_breached = reg.gauge(
            "watchdog_rule_breached", "1 while the rule's window is in breach"
        )
        self._m_ticks = reg.counter(
            "watchdog_ticks_total", "watchdog evaluation passes"
        )

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "Watchdog":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="slo-watchdog", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self) -> "Watchdog":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.stop()

    # ---------------------------------------------------------- evaluation
    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.tick()

    def tick(self) -> None:
        """One evaluation pass (public so tests can drive the watchdog
        synchronously, without the thread)."""
        now = time.perf_counter()
        self._m_ticks.inc()
        for rule in self.rules:
            try:
                value = self._evaluate(rule, now)
            except Exception as e:  # a broken probe must not kill the loop
                self._m_value.set(float("nan"), rule=rule.name)
                self._note_probe_error(rule, e)
                continue
            breached = value > rule.threshold
            self._m_value.set(value, rule=rule.name)
            self._m_breached.set(1.0 if breached else 0.0, rule=rule.name)
            if breached and self._cooldown_ok(rule, now):
                self._incident(rule, value, now)

    def _evaluate(self, rule: WatchRule, now: float) -> float:
        with self._lock:
            win = self._windows[rule.name]
        if rule.agg in ("p99", "max"):
            events = list(rule.probe() or ())
            with self._lock:
                win.extend(events)
                cut = now - rule.window_s
                while win and win[0][0] < cut:
                    win.popleft()
                values = [v for _, v in win]
            value = _p99(values) if rule.agg == "p99" else (max(values) if values else 0.0)
        elif rule.agg == "delta":
            total = float(rule.probe())
            with self._lock:
                win.append((now, total))
                cut = now - rule.window_s
                while len(win) > 1 and win[0][0] < cut:
                    win.popleft()
                value = total - win[0][1]
        else:  # gauge
            value = float(rule.probe())
            with self._lock:
                win.append((now, value))
                cut = now - rule.window_s
                while win and win[0][0] < cut:
                    win.popleft()
        with self._lock:
            self._values[rule.name] = value
        return value

    def _cooldown_ok(self, rule: WatchRule, now: float) -> bool:
        with self._lock:
            last = self._last_fire.get(rule.name)
            if last is not None and (now - last) < rule.cooldown_s:
                return False
            self._last_fire[rule.name] = now
            return True

    def _note_probe_error(self, rule: WatchRule, e: Exception) -> None:
        with self._lock:
            self._incidents.append(
                {"rule": rule.name, "error": repr(e), "kind": "probe_error"}
            )

    # ------------------------------------------------------------ incident
    def _incident(self, rule: WatchRule, value: float, now: float) -> None:
        from .export import metrics_snapshot  # late: export imports trace

        self._m_incidents.inc(rule=rule.name)
        with self._lock:
            seq = sum(1 for i in self._incidents if i.get("kind") != "probe_error")
        bundle_dir = os.path.join(
            self.incident_dir, f"{seq:04d}_{rule.name}"
        )
        record: Dict[str, Any] = {
            "kind": "incident",
            "rule": rule.name,
            "value": value,
            "threshold": rule.threshold,
            "window_s": rule.window_s,
            "agg": rule.agg,
            "wallclock": time.time(),
            "bundle": bundle_dir,
            **{"describe": rule.describe()},
        }
        try:
            os.makedirs(bundle_dir, exist_ok=True)
            with open(os.path.join(bundle_dir, "incident.json"), "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
            with open(os.path.join(bundle_dir, "trace.json"), "w") as f:
                json.dump(self._flight.dump(self.flight_window_s), f)
                f.write("\n")
            with open(os.path.join(bundle_dir, "metrics.json"), "w") as f:
                json.dump(metrics_snapshot(), f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            record["write_error"] = repr(e)
        with self._lock:
            self._incidents.append(record)

    # ------------------------------------------------------------- queries
    def incidents(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._incidents)

    def values(self) -> Dict[str, float]:
        """Last windowed value per rule (the watchdog's own view of the
        system, mirrored on watchdog_rule_value)."""
        with self._lock:
            return dict(self._values)


# ------------------------------------------------------- rule constructors
def lock_wait_rule(
    name: str,
    lock_prefix: str,
    threshold_s: float,
    window_s: float = 30.0,
    cooldown_s: float = 30.0,
) -> WatchRule:
    """Acquire-wait seconds accrued over the window, summed across every
    OwnedLock whose name starts with ``lock_prefix`` (e.g. "plane_lock"
    covers plane_lock + plane_lock_g<i> on a sharded plane)."""

    def probe() -> float:
        snap = occupancy_snapshot()
        return sum(
            float(s["total_wait_s"])
            for lname, s in snap.items()
            if lname.startswith(lock_prefix)
        )

    return WatchRule(
        name, probe, threshold_s, window_s=window_s, agg="delta",
        cooldown_s=cooldown_s,
        help=f"acquire-wait delta over {window_s:.0f}s on {lock_prefix}*",
    )


def counter_delta_rule(
    name: str,
    counter,
    threshold: float,
    window_s: float = 30.0,
    cooldown_s: float = 30.0,
) -> WatchRule:
    """Increase of a registry Counter's total over the window (per-writer
    blocked-seconds, fold events, ...)."""

    def probe() -> float:
        return float(counter.total())

    return WatchRule(
        name, probe, threshold, window_s=window_s, agg="delta",
        cooldown_s=cooldown_s, help=f"delta of {counter.name} over window",
    )


def gauge_rule(
    name: str,
    gauge,
    threshold: float,
    cooldown_s: float = 30.0,
    **labels: object,
) -> WatchRule:
    """Instantaneous gauge vs threshold (compaction increment stall:
    compactor_max_increment_seconds)."""

    def probe() -> float:
        return float(gauge.value(**labels))

    return WatchRule(
        name, probe, threshold, window_s=1.0, agg="gauge",
        cooldown_s=cooldown_s, help=f"gauge {gauge.name} vs threshold",
    )
