"""repro.obs — unified observability plane.

Three layers (see docs/observability.md):

- registry: typed counters/gauges/histograms with label sets
- trace: spans with parent linkage and block_until_ready device fencing
- occupancy: per-owner held-time attribution on the device locks

plus exporters (JSON snapshot, Chrome/Perfetto trace, terminal table).
"""

from .occupancy import OwnedLock, all_locks, occupancy_snapshot
from .registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    all_registries,
    get_registry,
)
from .trace import (
    Tracer,
    clear,
    disable,
    enable,
    enabled,
    get_tracer,
    span,
    traced,
)
from .export import (
    chrome_trace,
    metrics_snapshot,
    serve_prometheus,
    summary,
    to_prometheus_text,
    validate_chrome_trace,
    write_chrome_trace,
    write_metrics_json,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "OwnedLock",
    "Tracer",
    "all_locks",
    "all_registries",
    "chrome_trace",
    "clear",
    "disable",
    "enable",
    "enabled",
    "get_registry",
    "get_tracer",
    "metrics_snapshot",
    "occupancy_snapshot",
    "serve_prometheus",
    "span",
    "summary",
    "to_prometheus_text",
    "traced",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_metrics_json",
]
