"""repro.obs — unified observability plane.

Three layers (see docs/observability.md):

- registry: typed counters/gauges/histograms with label sets
- trace: spans with parent linkage and block_until_ready device fencing
- occupancy: per-owner held-time attribution on the device locks

plus exporters (JSON snapshot, Chrome/Perfetto trace, terminal table).
"""

from .flight import (
    FlightRecorder,
    flight_clear,
    flight_disable,
    flight_dump,
    flight_enable,
    flight_enabled,
    get_flight,
)
from .occupancy import OwnedLock, all_locks, occupancy_snapshot
from .registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    all_registries,
    get_registry,
)
from .trace import (
    Tracer,
    clear,
    disable,
    enable,
    enabled,
    get_tracer,
    span,
    traced,
)
from .export import (
    chrome_trace,
    metrics_snapshot,
    serve_prometheus,
    summary,
    to_prometheus_text,
    validate_chrome_trace,
    write_chrome_trace,
    write_metrics_json,
)
from .watchdog import (
    WatchRule,
    Watchdog,
    counter_delta_rule,
    gauge_rule,
    lock_wait_rule,
)

__all__ = [
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "OwnedLock",
    "Tracer",
    "WatchRule",
    "Watchdog",
    "all_locks",
    "all_registries",
    "chrome_trace",
    "clear",
    "counter_delta_rule",
    "disable",
    "enable",
    "enabled",
    "flight_clear",
    "flight_disable",
    "flight_dump",
    "flight_enable",
    "flight_enabled",
    "gauge_rule",
    "get_flight",
    "get_registry",
    "get_tracer",
    "lock_wait_rule",
    "metrics_snapshot",
    "occupancy_snapshot",
    "serve_prometheus",
    "span",
    "summary",
    "to_prometheus_text",
    "traced",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_metrics_json",
]
