"""Fault-tolerant checkpointing: atomic sharded save/restore + async saver.

Layout (one step):
    <dir>/step_000123.tmp-<nonce>/   written here first
        manifest.json                tree structure, shapes, dtypes
        arr_00000.npy ...            leaves in tree order
    <dir>/step_000123/               atomic rename on completion

Restart safety: a crash mid-write leaves only a .tmp dir, which restore
ignores and the next save garbage-collects. `keep` bounds disk usage.
Multi-host note: on a real pod each host writes its addressable shards
under host_<i>/ (the manifest records the process index); this container
exercises the single-process path, and tests cover crash-mid-write,
resume-bitwise-equality, and keep-GC.

The async saver moves (device->host + serialize + rename) off the training
thread; train loops call .wait() before overwriting params in-place (JAX
arrays are immutable, so in practice only ordering with step N+1's save
matters).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _tree_paths(tree: PyTree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


_WIDENED = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray):
    """np.save cannot serialize ml_dtypes (bfloat16, fp8); store the raw
    bits under an integer view and record the logical dtype."""
    name = str(arr.dtype)
    if name in _WIDENED:
        return arr.view(_WIDENED[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _WIDENED:
        import ml_dtypes

        return arr.view(np.dtype(logical_dtype))
    return arr


def save_checkpoint(directory: str, step: int, tree: PyTree, *, process_index: int = 0) -> Path:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:08d}"
    tmp = d / f"step_{step:08d}.tmp-{os.getpid()}-{time.time_ns()}"
    tmp.mkdir(parents=True)
    leaves, treedef = _tree_paths(tree)
    manifest = {
        "step": step,
        "process_index": process_index,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        stored, logical = _to_storable(arr)
        np.save(tmp / f"arr_{i:05d}.npy", stored)
        manifest["leaves"].append({"shape": list(arr.shape), "dtype": logical})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic on POSIX
    return final


def list_checkpoints(directory: str) -> List[Tuple[int, Path]]:
    d = Path(directory)
    if not d.exists():
        return []
    out = []
    for p in sorted(d.iterdir()):
        if p.is_dir() and p.name.startswith("step_") and ".tmp-" not in p.name:
            if (p / "manifest.json").exists():
                out.append((int(p.name.split("_")[1]), p))
    return out


def restore_checkpoint(directory: str, like: PyTree, step: Optional[int] = None) -> Tuple[int, PyTree]:
    """Restore the latest (or a specific) step into the structure of
    `like` (shapes/dtypes verified). Returns (step, tree)."""
    ckpts = list_checkpoints(directory)
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    if step is not None:
        matches = [c for c in ckpts if c[0] == step]
        if not matches:
            raise FileNotFoundError(f"step {step} not found under {directory}")
        step_found, path = matches[0]
    else:
        step_found, path = ckpts[-1]
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _tree_paths(like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}"
        )
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = _from_storable(
            np.load(path / f"arr_{i:05d}.npy"), manifest["leaves"][i]["dtype"]
        )
        want = np.asarray(ref)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {want.shape}")
        if arr.dtype != want.dtype:
            arr = arr.astype(want.dtype)
        new_leaves.append(arr)
    return step_found, jax.tree_util.tree_unflatten(treedef, new_leaves)


def gc_checkpoints(directory: str, keep: int) -> None:
    ckpts = list_checkpoints(directory)
    for _, path in ckpts[:-keep] if keep > 0 else []:
        shutil.rmtree(path, ignore_errors=True)
    # Sweep orphaned tmp dirs (crashed writers).
    d = Path(directory)
    if d.exists():
        for p in d.iterdir():
            if ".tmp-" in p.name:
                shutil.rmtree(p, ignore_errors=True)


class CheckpointManager:
    """Async checkpoint writer with keep-K GC and crash recovery."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = str(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        self.wait()
        # Pull to host synchronously (cheap vs serialize) so the caller may
        # donate/overwrite device buffers immediately.
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                gc_checkpoints(self.directory, self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: PyTree) -> Tuple[int, PyTree]:
        return restore_checkpoint(self.directory, like)

    def latest_step(self) -> Optional[int]:
        ckpts = list_checkpoints(self.directory)
        return ckpts[-1][0] if ckpts else None
