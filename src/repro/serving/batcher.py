"""The paper's adaptive batching (Alg 1), transplanted to request
scheduling — the beyond-paper application promised in DESIGN.md.

Mapping: a query's time range -> the serving request queue; batch result
count k_i -> requests admitted per scheduling round; batch runtime T_i ->
the round's wall time (prefill + decode iterations). The update law IS
core/batching.py's `alg1_next_k` (k'=ck, clamp via rate so the estimated
round time stays within [T_min, T_max]) — keeping admission latency-aware:
when rounds run hot (slow model / long prompts) admission shrinks toward
interactive latencies; when rounds are fast it grows geometrically to
throughput-optimal batches. The database serve plane's scheduler
(repro.serve_db.scheduler) shares the same law for its turn quantum.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

from ..core.batching import alg1_next_k


@dataclass
class AdaptiveRequestBatcher:
    k0: float = 1.0
    c: float = 1.5
    t_min: float = 0.05  # seconds: serving rounds, not analytics scans
    t_max: float = 0.5
    max_batch: int = 64
    history: List = field(default_factory=list)

    def __post_init__(self):
        self._k = float(self.k0)

    def admit(self, waiting: int, free_slots: int) -> int:
        """How many queued requests to admit this round."""
        return max(min(int(round(self._k)), waiting, free_slots), 1 if waiting and free_slots else 0)

    def update(self, runtime: float, served: int) -> None:
        """Alg 1 UPDATE with (T_i, r_i) = (round wall time, requests
        served this round)."""
        self.history.append((runtime, served))
        k_next = alg1_next_k(self._k, runtime, served, self.c, self.t_max, self.t_min)
        self._k = float(min(max(k_next, 1.0), self.max_batch))

    @property
    def k(self) -> float:
        return self._k
