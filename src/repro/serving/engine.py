"""Continuous-batching serve engine with adaptive admission.

Slot-pool design (vLLM-lite): a fixed pool of `max_batch` sequence slots
shares one padded KV cache; every decode iteration steps ALL active slots.
Admission of waiting requests is governed by the paper's Alg 1 transplant
(serving/batcher.py): rounds that run hot shrink admission toward the
latency floor, fast rounds grow it geometrically.

This engine is the real thing (used by examples/serve_lm.py on a small
model); the dry-run's decode cells lower exactly the decode_step it calls.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, init_caches, prefill
from .batcher import AdaptiveRequestBatcher


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    submitted_at: float = field(default_factory=time.perf_counter)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    output: List[int] = field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        return None if self.first_token_at is None else self.first_token_at - self.submitted_at


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 8,
        cache_len: int = 256,
        batcher: Optional[AdaptiveRequestBatcher] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.batcher = batcher or AdaptiveRequestBatcher(max_batch=max_batch)
        self.waiting: List[Request] = []
        self.active: Dict[int, Request] = {}  # slot -> request
        self._next_rid = 0
        self.caches = init_caches(params, cfg, max_batch, cache_len)
        self.cur_pos = jnp.zeros((max_batch,), jnp.int32)
        self.live = jnp.zeros((max_batch,), jnp.bool_)
        self.last_tok = jnp.zeros((max_batch, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, b, c, cp: decode_step(p, cfg, b, c, cp)
        )
        self._prefill_1 = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=cache_len)
        )
        self.completed: List[Request] = []

    # ------------------------------------------------------------- public
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16, eos_id=None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(Request(rid, np.asarray(prompt, np.int32), max_new_tokens, eos_id))
        return rid

    def run(self, max_rounds: int = 10_000) -> List[Request]:
        """Serve until all submitted requests finish."""
        rounds = 0
        while (self.waiting or self.active) and rounds < max_rounds:
            self.step_round()
            rounds += 1
        return self.completed

    # ----------------------------------------------------------- internals
    def _free_slots(self) -> List[int]:
        return [s for s in range(self.max_batch) if s not in self.active]

    def _admit(self, n: int) -> None:
        """Prefill n waiting requests into free slots (one at a time: the
        prompt lengths differ; production would bucket them)."""
        for _ in range(n):
            if not self.waiting:
                return
            slots = self._free_slots()
            if not slots:
                return
            slot = slots[0]
            req = self.waiting.pop(0)
            _, caches_1, last_pos = self._prefill_1(
                self.params, {"inputs": jnp.asarray(req.prompt[None, :])}
            )
            # Copy the single-row caches into this slot of the pool.
            self.caches = jax.tree_util.tree_map(
                lambda pool, one: pool.at[:, slot : slot + 1].set(one), self.caches, caches_1
            )
            self.cur_pos = self.cur_pos.at[slot].set(len(req.prompt))
            self.last_tok = self.last_tok.at[slot, 0].set(int(req.prompt[-1]))
            self.live = self.live.at[slot].set(True)
            self.active[slot] = req

    def step_round(self) -> None:
        t0 = time.perf_counter()
        self._admit(self.batcher.admit(len(self.waiting), len(self._free_slots())))
        served = len(self.active)
        if served:
            logits, self.caches = self._decode(
                self.params, {"inputs": self.last_tok}, self.caches, self.cur_pos
            )
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy
            nxt_np = np.asarray(nxt)
            now = time.perf_counter()
            done_slots = []
            for slot, req in self.active.items():
                tok = int(nxt_np[slot])
                if req.first_token_at is None:
                    req.first_token_at = now
                req.output.append(tok)
                if (req.eos_id is not None and tok == req.eos_id) or len(
                    req.output
                ) >= req.max_new_tokens or int(self.cur_pos[slot]) + 1 >= self.cache_len - 1:
                    req.finished_at = now
                    done_slots.append(slot)
            self.last_tok = nxt[:, None]
            self.cur_pos = self.cur_pos + self.live.astype(jnp.int32)
            for slot in done_slots:
                self.completed.append(self.active.pop(slot))
                self.live = self.live.at[slot].set(False)
        self.batcher.update(time.perf_counter() - t0, served)
