from .batcher import AdaptiveRequestBatcher  # noqa: F401
from .engine import ServeEngine, Request  # noqa: F401
