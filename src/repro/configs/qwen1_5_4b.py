"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912
vocab=151936; QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    layer_pattern=("global",),
    qkv_bias=True,
    act="silu",
    rope_theta=5000000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512
    )
