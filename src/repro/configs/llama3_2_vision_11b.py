"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer (8 total), gated
residuals. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Backbone only: the vision tower is a stub — input_specs() provides
precomputed vision states (B, n_image_tokens, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=("global", "global", "global", "global", "cross"),
    n_image_tokens=1601,  # 1 tile x (40x40 patches + 1 CLS)
    act="silu",
    rope_theta=500000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, n_image_tokens=17,
    )
