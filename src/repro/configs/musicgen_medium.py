"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only per the assignment: the EnCodec frontend is a stub —
input_specs() provides precomputed frame embeddings (B, S, d_model); the
head predicts the 2048-entry codebook. Plain (non-GLU) GELU MLP at 4x,
matching the MusicGen transformer."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=("global",),
    act="gelu",
    mlp_type="plain",
    embed_input=False,  # frame embeddings come from the stub frontend
    rope_theta=10000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=256
    )
