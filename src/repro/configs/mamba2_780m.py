"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128; SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab_size=512, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=32,
    )
