"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400,
16 experts top-2, vocab=32064. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    layer_pattern=("global",),
    n_experts=16,
    top_k=2,
    capacity_factor=1.25,
    act="silu",
    rope_theta=10000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab_size=512, n_experts=4, top_k=2,
    )
