"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local:global interleave (window 1024), QK-norm, dual RoPE
bases (10k local / 1M global), 128k context. [hf:google/gemma-3 family;
unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    qk_norm=True,
    sandwich_norm=True,
    scale_embedding=True,
    tie_embeddings=True,
    act="gelu",
    rope_theta=1000000.0,
    rope_theta_local=10000.0,
    attn_scale=1.0 / 16.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16, window=16, attn_scale=0.25,
    )
