"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local+global alternating sliding window, attn+final logit
softcap, sandwich norms, head_dim 256. [arXiv:2408.00118; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    scale_embedding=True,
    tie_embeddings=True,
    act="gelu",
    rope_theta=10000.0,
    attn_scale=1.0 / 16.0,  # query_pre_attn_scalar = 256 = head_dim
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16, window=32, attn_scale=0.25,
    )
