"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544; llama-style GQA + SwiGLU. [arXiv:2403.17297; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    layer_pattern=("global",),
    act="silu",
    rope_theta=1000000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab_size=512
    )
