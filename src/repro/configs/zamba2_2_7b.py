"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
ssm_state=64 vocab=32000; Mamba2 backbone + shared full-attention block
applied every 6th layer (9 applications, shared weights, per-application
KV caches). [arXiv:2411.15242; hf]

The real Zamba2 concatenates the original embedding into the shared block
and adds per-application LoRAs; both omitted (assignment dims only, noted
in DESIGN.md)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "ssm_shared_attn"),
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_heads=32,
    shared_attn_kv_heads=32,
    shared_attn_d_ff=10240,
    act="gelu",
    rope_theta=10000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, ssm_state=16, ssm_head_dim=16,
        shared_attn_heads=4, shared_attn_kv_heads=4, shared_attn_d_ff=128,
        ssm_chunk=32,
    )
