"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16, MHA) d_ff=1408
(per expert, DeepSeek-style fine-grained), vocab=163840, MoE 64 experts
top-6. [hf:moonshotai/Moonlight-16B-A3B; hf]

Assignment dims kept exactly; Moonlight's shared experts / first dense
layer are not in the assignment spec and are omitted (noted in DESIGN.md
§Arch-applicability)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    layer_pattern=("global",),
    n_experts=64,
    top_k=6,
    capacity_factor=1.25,
    act="silu",
    rope_theta=50000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512, n_experts=8, top_k=2,
    )
