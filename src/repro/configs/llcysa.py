"""The paper's own system configuration: the LLCySA pipeline (store +
ingest + query) and the ~100M-param analytics LM trained on tokenized
events (examples/train_lm.py).

Paper reference points (§IV): 8-node Accumulo instance for queries; 24-core
/ 64 GB nodes; adaptive batching defaults k0=10, c=1.5, Tmin=1s, Tmax=30s;
planner threshold w empirically derived (we default 10)."""
from dataclasses import dataclass

from .base import ModelConfig


@dataclass(frozen=True)
class PipelineConfig:
    n_shards: int = 8  # "tablet servers" (paper: 8-node instance)
    n_ingest_workers: int = 4
    flush_rows: int = 32768
    max_runs: int = 8
    agg_bucket_seconds: int = 3600
    batch_rows: int = 4096
    planner_w: float = 10.0
    k0: float = 10.0
    c: float = 1.5
    t_min: float = 1.0
    t_max: float = 30.0


PIPELINE = PipelineConfig()

# ~100M-param event LM (d=768, 12L) for the end-to-end training example.
CONFIG = ModelConfig(
    name="llcysa-analytics-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab_size=32768,
    layer_pattern=("global",),
    act="silu",
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=2048)
