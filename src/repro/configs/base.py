"""Model + run configuration dataclasses.

One `ModelConfig` instance per assigned architecture lives in
`repro/configs/<id>.py` with the exact published dimensions, plus a
`smoke()` reduction of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int  # query heads; 0 for attention-free stacks
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- attention flavor ---
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None  # gemma2 attn logit soft-capping
    final_softcap: Optional[float] = None  # gemma2 final logit soft-capping
    qk_norm: bool = False  # gemma3 RMS-norms q and k instead of softcap
    layer_pattern: Tuple[str, ...] = ("global",)
    #   cycled over layers; entries: 'global' | 'local' | 'cross' | 'ssm'
    #   | 'ssm_shared_attn' (zamba2: ssm block + shared attn applied after)
    window: int = 4096  # sliding window for 'local'
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None  # gemma3 uses 10k local / 1M global
    sandwich_norm: bool = False  # gemma2/3 pre+post block norms
    scale_embedding: bool = False  # gemma family: embed * sqrt(d_model)
    attn_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # --- MLP ---
    act: str = "silu"  # silu | gelu
    mlp_type: str = "glu"  # glu | plain
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): shared attention block params ---
    shared_attn_heads: int = 0
    shared_attn_kv_heads: int = 0
    shared_attn_d_ff: int = 0

    # --- vlm ---
    n_image_tokens: int = 0  # stub vision frontend sequence length

    # --- audio ---
    embed_input: bool = True  # False: inputs are precomputed embeddings

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.pattern_period == 0, (
            self.name,
            self.n_layers,
            self.layer_pattern,
        )
        return self.n_layers // self.pattern_period

    @property
    def attn_free(self) -> bool:
        return all(t == "ssm" for t in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k per the assignment: SSM and hybrid stacks
        qualify; any per-layer 'global' full-attention disqualifies. (The
        zamba2 hybrid's shared-attention applications are few and global —
        the assignment explicitly includes hybrids, so 'ssm_shared_attn'
        qualifies; see DESIGN.md §Arch-applicability.)"""
        return all(t in ("ssm", "local", "ssm_shared_attn") for t in self.layer_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline numbers)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        n = 0
        if self.embed_input:
            n += v * d
        if not self.tie_embeddings:
            n += v * d
        per_layer = {}
        for kind in self.layer_pattern:
            if kind in ("global", "local", "cross"):
                qkv = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
                if self.qkv_bias:
                    qkv += hd * (self.n_heads + 2 * self.n_kv_heads)
                o = hd * self.n_heads * d
                if self.n_experts:
                    mlp = self.n_experts * 3 * d * ff + d * self.n_experts
                else:
                    mlp = (3 if self.mlp_type == "glu" else 2) * d * ff
                per_layer[kind] = qkv + o + mlp + 2 * d
            elif kind in ("ssm", "ssm_shared_attn"):
                d_in = self.ssm_expand * d
                nh = d_in // self.ssm_head_dim
                g = 1
                proj_in = d * (2 * d_in + 2 * g * self.ssm_state + nh)
                conv = self.ssm_conv * (d_in + 2 * g * self.ssm_state)
                proj_out = d_in * d
                per_layer[kind] = proj_in + conv + proj_out + 2 * nh + 2 * d + d_in
        n += sum(per_layer[kind] for kind in self.layer_pattern) * self.n_groups
        if self.shared_attn_heads:
            hd2 = self.d_model // self.shared_attn_heads
            n += (
                self.d_model * hd2 * (self.shared_attn_heads + 2 * self.shared_attn_kv_heads)
                + hd2 * self.shared_attn_heads * self.d_model
                + 3 * self.d_model * self.shared_attn_d_ff
                + 2 * self.d_model
            )
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive_per_moe_layer = (self.n_experts - self.top_k) * 3 * d * ff
        n_moe_layers = (
            sum(1 for k in self.layer_pattern if k in ("global", "local")) * self.n_groups
        )
        return full - inactive_per_moe_layer * n_moe_layers


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
