from .base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401
