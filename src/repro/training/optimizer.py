"""AdamW with f32 state over (possibly bf16) params, global-norm clipping,
linear-warmup cosine schedule, and optional error-feedback gradient
compression.

Gradient compression (beyond-paper distributed-optimization feature): grads
quantize to bf16 with an f32 error-feedback accumulator before entering
Adam — the dp all-reduce / ZeRO reshard then moves half the bytes. The
error buffer makes the compression unbiased over time (Karimireddy et al.,
EF-SGD); tests/test_training.py checks convergence parity on a small
problem.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    compress_grads: bool = False  # bf16 + error feedback


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.float32(cfg.lr) * warm * (0.1 + 0.9 * cos)


def adamw_init(params: PyTree, cfg: OptConfig) -> PyTree:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree_util.tree_map(zeros32, params)
    return state


def _global_norm(tree: PyTree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: PyTree, grads: PyTree, state: PyTree, cfg: OptConfig
) -> Tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    lr = schedule(cfg, step)

    if cfg.compress_grads:
        # Error-feedback bf16 compression: g_c = bf16(g + err); err += g - g_c.
        def compress(g, e):
            g32 = g.astype(jnp.float32) + e
            gc = g32.astype(jnp.bfloat16).astype(jnp.float32)
            return gc, g32 - gc

        pairs = jax.tree_util.tree_map(compress, grads, state["err"])
        grads = jax.tree_util.tree_map(lambda x: x[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree_util.tree_map(lambda x: x[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = None

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = jnp.float32(cfg.b1), jnp.float32(cfg.b2)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
    }
    if new_err is not None:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
