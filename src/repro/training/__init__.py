from .optimizer import adamw_init, adamw_update, OptConfig  # noqa: F401
