"""Composable decoder-stack model zoo covering the 10 assigned
architectures: dense GQA transformers (gemma2/3, internlm2, qwen1.5),
MoE (moonshot/moonlight, phi3.5-moe), Mamba2 SSD, the Zamba2 hybrid,
Llama-3.2-Vision cross-attn injection, and the MusicGen audio backbone.

All stacks scan over layer GROUPS (one group = the repeating layer pattern,
e.g. gemma2's [local, global] pair) with stacked params, so 40-54 layer
models lower to compact HLO. dtypes are pinned bf16/f32/int32 throughout —
x64 is enabled globally for the store's packed keys and must not leak here
(tests/test_models.py asserts this).
"""
from .model import Model, init_params  # noqa: F401
from .registry import get_config, list_archs  # noqa: F401
