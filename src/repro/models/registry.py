"""Architecture registry: --arch <id> -> ModelConfig (full or smoke)."""
from __future__ import annotations

import importlib
from typing import List

from ..configs.base import ModelConfig

_ARCHS = {
    "gemma2-9b": "gemma2_9b",
    "internlm2-20b": "internlm2_20b",
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma3-12b": "gemma3_12b",
    "musicgen-medium": "musicgen_medium",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "zamba2-2.7b": "zamba2_2_7b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "mamba2-780m": "mamba2_780m",
    "llcysa-analytics-100m": "llcysa",
}


def list_archs(assigned_only: bool = True) -> List[str]:
    names = list(_ARCHS)
    return names[:-1] if assigned_only else names


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.smoke() if smoke else mod.CONFIG
