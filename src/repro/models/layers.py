"""Shared layer primitives: RMSNorm, RoPE, MLPs, embeddings, soft-capping.

All functions are dtype-disciplined: compute-sensitive reductions run in
f32, weights/activations stay in cfg.dtype (bf16 by default). Every array
literal pins a dtype — x64 is globally enabled for the store and must not
leak into model HLO.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with f32 accumulation, (1 + scale) parameterization (gemma /
    llama convention compatible: init scale at 0 or 1 respectively)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + jnp.float32(eps))
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(logits, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return logits
    c = jnp.float32(cap)
    return (jnp.tanh(logits.astype(jnp.float32) / c) * c).astype(logits.dtype)


def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return (1.0 / (jnp.float32(theta) ** exponent)).astype(dtype)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, d_head), positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,) f32
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def mlp_glu(x, wi_gate, wi_up, wo, act: str):
    """SwiGLU / GeGLU: (x @ gate) * act ⊙ (x @ up) @ wo."""
    g = activation(jnp.einsum("...d,df->...f", x, wi_gate), act)
    u = jnp.einsum("...d,df->...f", x, wi_up)
    return jnp.einsum("...f,fd->...d", g * u, wo)


def mlp_plain(x, wi, wo, act: str):
    return jnp.einsum("...f,fd->...d", activation(jnp.einsum("...d,df->...f", x, wi), act), wo)


def embed(tokens, table, scale: bool):
    """Token embedding lookup; gemma scales by sqrt(d_model)."""
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(np.sqrt(table.shape[1]), x.dtype)
    return x


def unembed(x, table_or_head, tied: bool):
    if tied:
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)
