"""Unified decoder-stack assembly for all 10 assigned architectures.

Layer stacking: `cfg.layer_pattern` is the repeating period (e.g. gemma2's
('local','global'), gemma3's 5x('local',)+('global',), zamba2's
5x('ssm',)+('ssm_shared_attn',)); params for each pattern position are
stacked over `n_groups` and the stack runs under one lax.scan — 54-layer
models lower to period-sized HLO.

Three entry points, matching the dry-run cells:
  loss_and_logits   train_4k     full causal forward + CE loss
  prefill           prefill_32k  forward returning per-layer KV caches
  decode_step       decode_32k / long_500k  one token against caches

Caches mirror the params' group structure so scan can thread them as
xs/ys. SSM layers carry (state, conv_tail) instead of KV; cross-attention
layers cache the projected vision K/V; zamba2's shared attention block has
shared *weights* but per-application caches.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..distributed import ctx as dist_ctx
from . import ssm as ssm_mod
from .attention import decode_attention, flash_attention, ring_slot_positions
from .layers import apply_rope, embed, mlp_glu, mlp_plain, rms_norm, softcap, unembed
from .moe import init_moe_params, moe_ffn

PyTree = Any


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# =====================================================================
# Parameter init
# =====================================================================
def _init_attn(key, cfg: ModelConfig, kind: str) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dt(cfg)
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)
    p = {
        "norm": jnp.zeros((d,), dt),
        "wq": (jax.random.normal(ks[0], (d, nh * hd), dt) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd), dt) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd), dt) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (nh * hd, d), dt) * std).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    if cfg.sandwich_norm:
        p["post_norm"] = jnp.zeros((d,), dt)
    if kind == "cross":
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    return p


def _init_mlp(key, cfg: ModelConfig) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"mlp_norm": jnp.zeros((d,), dt)}
    if cfg.n_experts:
        p["moe"] = init_moe_params(ks[0], d, ff, cfg.n_experts, dt)
    elif cfg.mlp_type == "glu":
        p["wi_gate"] = (jax.random.normal(ks[0], (d, ff), dt) / math.sqrt(d)).astype(dt)
        p["wi_up"] = (jax.random.normal(ks[1], (d, ff), dt) / math.sqrt(d)).astype(dt)
        p["wo_mlp"] = (jax.random.normal(ks[2], (ff, d), dt) / math.sqrt(ff)).astype(dt)
    else:
        p["wi"] = (jax.random.normal(ks[0], (d, ff), dt) / math.sqrt(d)).astype(dt)
        p["wo_mlp"] = (jax.random.normal(ks[1], (ff, d), dt) / math.sqrt(ff)).astype(dt)
    if cfg.sandwich_norm:
        p["post_mlp_norm"] = jnp.zeros((d,), dt)
    return p


def _init_layer(key, cfg: ModelConfig, kind: str) -> Dict:
    if kind in ("ssm", "ssm_shared_attn"):
        return {
            "norm": jnp.zeros((cfg.d_model,), _dt(cfg)),
            "ssm": ssm_mod.init_ssm_params(key, ssm_mod.spec_from_cfg(cfg), _dt(cfg)),
        }
    k1, k2 = jax.random.split(key)
    return {**_init_attn(k1, cfg, kind), **_init_mlp(k2, cfg)}


def _init_shared_attn(key, cfg: ModelConfig) -> Dict:
    """Zamba2 shared transformer block (weights shared across
    applications)."""
    d = cfg.d_model
    nh, nkv = cfg.shared_attn_heads, cfg.shared_attn_kv_heads
    hd = d // nh
    ff = cfg.shared_attn_d_ff
    dt = _dt(cfg)
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)
    return {
        "norm": jnp.zeros((d,), dt),
        "wq": (jax.random.normal(ks[0], (d, nh * hd), dt) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd), dt) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd), dt) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (nh * hd, d), dt) * std).astype(dt),
        "mlp_norm": jnp.zeros((d,), dt),
        "wi_gate": (jax.random.normal(ks[4], (d, ff), dt) * std).astype(dt),
        "wi_up": (jax.random.normal(ks[5], (d, ff), dt) * std).astype(dt),
        "wo_mlp": (jax.random.normal(ks[6], (ff, d), dt) / math.sqrt(ff)).astype(dt),
    }


def init_params(key, cfg: ModelConfig) -> PyTree:
    dt = _dt(cfg)
    keys = jax.random.split(key, 4 + cfg.pattern_period)
    params: Dict[str, Any] = {}
    if cfg.embed_input:
        params["embed"] = (
            jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dt) * 0.02
        ).astype(dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size), dt)
            / math.sqrt(cfg.d_model)
        ).astype(dt)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    groups = []
    for p_idx, kind in enumerate(cfg.layer_pattern):
        gkeys = jax.random.split(keys[3 + p_idx], cfg.n_groups)
        groups.append(jax.vmap(lambda k: _init_layer(k, cfg, kind))(gkeys))
    params["groups"] = tuple(groups)
    if cfg.shared_attn_heads:
        params["shared_attn"] = _init_shared_attn(keys[2], cfg)
    return params


# =====================================================================
# Layer application
# =====================================================================
def _attn_block(
    p: Dict,
    h,
    cfg: ModelConfig,
    kind: str,
    *,
    mode: str,
    positions,
    cache: Optional[Dict],
    cur_pos,
    vision_states,
    cache_len: int,
):
    """One attention layer (+ its MLP handled by caller). Returns
    (attn_out, new_cache)."""
    b, s, d = h.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    x = rms_norm(h, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, nh, hd)

    local = kind == "local"
    window = cfg.window if local else None
    theta = (
        cfg.rope_theta_local
        if (local and cfg.rope_theta_local is not None)
        else cfg.rope_theta
    )

    if kind == "cross":
        # K/V from the (stub) vision states; cached after prefill.
        if cache is not None and mode == "decode":
            k, v = cache["k"], cache["v"]
            new_cache = cache
        else:
            kv_src = vision_states
            k = jnp.einsum("bnd,de->bne", kv_src, p["wk"]).reshape(b, -1, nkv, hd)
            v = jnp.einsum("bnd,de->bne", kv_src, p["wv"]).reshape(b, -1, nkv, hd)
            new_cache = {"k": k, "v": v} if mode != "train" else None
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if mode == "decode":
            out = decode_attention(
                q, k, v, jnp.full((b,), k.shape[1] - 1, jnp.int32),
                softcap_val=cfg.attn_softcap, scale=cfg.attn_scale,
            )
        else:
            out = flash_attention(
                q, k, v, causal=False, softcap_val=cfg.attn_softcap, scale=cfg.attn_scale
            )
    else:
        kx = jnp.einsum("bsd,de->bse", x, p["wk"])
        vx = jnp.einsum("bsd,de->bse", x, p["wv"])
        if cfg.qkv_bias:
            kx = kx + p["bk"]
            vx = vx + p["bv"]
        k_new = kx.reshape(b, s, nkv, hd)
        v_new = vx.reshape(b, s, nkv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, theta)
        k_new = apply_rope(k_new, positions, theta)

        if mode == "train":
            out = flash_attention(
                q, k_new, v_new, causal=True, window=window,
                softcap_val=cfg.attn_softcap, scale=cfg.attn_scale,
            )
            new_cache = None
        elif mode == "prefill":
            out = flash_attention(
                q, k_new, v_new, causal=True, window=window,
                softcap_val=cfg.attn_softcap, scale=cfg.attn_scale,
            )
            # Local layers keep only a window-sized ring cache (slot =
            # pos % W): a 32k-context gemma-2 local layer stores 4k slots.
            eff_len = min(window, cache_len) if local else cache_len
            kc = jnp.zeros((b, eff_len, nkv, hd), k_new.dtype)
            vc = jnp.zeros((b, eff_len, nkv, hd), v_new.dtype)
            if local and s > eff_len:
                idx = jnp.arange(s - eff_len, s, dtype=jnp.int32) % eff_len
                kc = kc.at[:, idx].set(k_new[:, s - eff_len :])
                vc = vc.at[:, idx].set(v_new[:, s - eff_len :])
            else:
                idx = jnp.arange(s, dtype=jnp.int32) % eff_len
                kc = kc.at[:, idx].set(k_new)
                vc = vc.at[:, idx].set(v_new)
            new_cache = {"k": kc, "v": vc}
        else:  # decode
            bidx = jnp.arange(b)
            eff_len = cache["k"].shape[1]
            slot = cur_pos % eff_len if local else cur_pos
            kc = cache["k"].at[bidx, slot].set(k_new[:, 0])
            vc = cache["v"].at[bidx, slot].set(v_new[:, 0])
            slot_pos = ring_slot_positions(cur_pos, eff_len) if local else None
            out = decode_attention(
                q, kc, vc, cur_pos, window=window,
                softcap_val=cfg.attn_softcap, scale=cfg.attn_scale,
                slot_positions=slot_pos,
            )
            new_cache = {"k": kc, "v": vc}

    out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, nh * hd), p["wo"])
    if cfg.sandwich_norm:
        out = rms_norm(out, p["post_norm"], cfg.norm_eps)
    if kind == "cross":
        out = out * jnp.tanh(p["gate_attn"]).astype(out.dtype)
    return out, new_cache


def _mlp_block(p: Dict, h, cfg: ModelConfig, kind: str):
    """Returns (mlp_out, aux_loss)."""
    x = rms_norm(h, p["mlp_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        out, aux = moe_ffn(
            p["moe"], x, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, act=cfg.act
        )
    elif cfg.mlp_type == "glu":
        out = mlp_glu(x, p["wi_gate"], p["wi_up"], p["wo_mlp"], cfg.act)
    else:
        out = mlp_plain(x, p["wi"], p["wo_mlp"], cfg.act)
    if cfg.sandwich_norm:
        out = rms_norm(out, p["post_mlp_norm"], cfg.norm_eps)
    if kind == "cross":
        out = out * jnp.tanh(p["gate_mlp"]).astype(out.dtype)
    return out, aux


def _shared_attn_block(sp: Dict, h, cfg: ModelConfig, *, mode, positions, cache, cur_pos, cache_len):
    """Zamba2's shared full-attention transformer block."""
    b, s, d = h.shape
    nh, nkv = cfg.shared_attn_heads, cfg.shared_attn_kv_heads
    hd = d // nh
    x = rms_norm(h, sp["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", x, sp["wq"]).reshape(b, s, nh, hd)
    k_new = jnp.einsum("bsd,de->bse", x, sp["wk"]).reshape(b, s, nkv, hd)
    v_new = jnp.einsum("bsd,de->bse", x, sp["wv"]).reshape(b, s, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    if mode == "train":
        out = flash_attention(q, k_new, v_new, causal=True)
        new_cache = None
    elif mode == "prefill":
        out = flash_attention(q, k_new, v_new, causal=True)
        kc = jnp.zeros((b, cache_len, nkv, hd), k_new.dtype)
        vc = jnp.zeros((b, cache_len, nkv, hd), v_new.dtype)
        kc = lax.dynamic_update_slice_in_dim(kc, k_new, 0, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v_new, 0, axis=1)
        new_cache = {"k": kc, "v": vc}
    else:
        bidx = jnp.arange(b)
        kc = cache["k"].at[bidx, cur_pos].set(k_new[:, 0])
        vc = cache["v"].at[bidx, cur_pos].set(v_new[:, 0])
        out = decode_attention(q, kc, vc, cur_pos)
        new_cache = {"k": kc, "v": vc}
    h = h + jnp.einsum("bse,ed->bsd", out.reshape(b, s, nh * hd), sp["wo"])
    x2 = rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
    h = h + mlp_glu(x2, sp["wi_gate"], sp["wi_up"], sp["wo_mlp"], cfg.act)
    return h, new_cache


def _apply_layer(
    p: Dict,
    h,
    cfg: ModelConfig,
    kind: str,
    *,
    mode: str,
    positions,
    cache,
    cur_pos,
    vision_states,
    shared_params,
    cache_len: int,
):
    """Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("ssm", "ssm_shared_attn"):
        spec = ssm_mod.spec_from_cfg(cfg)
        x = rms_norm(h, p["norm"], cfg.norm_eps)
        if mode == "train":
            h = h + ssm_mod.ssm_forward(p["ssm"], x, spec)
            new_cache: Any = None
        elif mode == "prefill":
            out, state = ssm_mod.ssm_forward(p["ssm"], x, spec, return_state=True)
            h = h + out
            new_cache = {"state": state[0], "conv": state[1]}
        else:
            out, state = ssm_mod.ssm_decode_step(p["ssm"], x, (cache["state"], cache["conv"]), spec)
            h = h + out
            new_cache = {"state": state[0], "conv": state[1]}
        if kind == "ssm_shared_attn":
            sa_cache = cache.get("sa") if isinstance(cache, dict) else None
            h, sa_new = _shared_attn_block(
                shared_params, h, cfg, mode=mode, positions=positions,
                cache=sa_cache, cur_pos=cur_pos, cache_len=cache_len,
            )
            if new_cache is not None and sa_new is not None:
                new_cache["sa"] = sa_new
        return h, new_cache, aux

    attn_out, new_cache = _attn_block(
        p, h, cfg, kind, mode=mode, positions=positions, cache=cache,
        cur_pos=cur_pos, vision_states=vision_states, cache_len=cache_len,
    )
    h = h + attn_out
    mlp_out, aux = _mlp_block(p, h, cfg, kind)
    h = h + mlp_out
    return h, new_cache, aux


# =====================================================================
# Full-stack forwards
# =====================================================================
def _pick_outer(n_groups: int) -> int:
    """Largest divisor of n_groups not exceeding sqrt(n_groups)."""
    best = 1
    d = 1
    while d * d <= n_groups:
        if n_groups % d == 0:
            best = d
        d += 1
    return best


def _stack(
    params: PyTree,
    cfg: ModelConfig,
    h,
    *,
    mode: str,
    positions,
    caches,
    cur_pos,
    vision_states,
    cache_len: int,
    remat: bool = False,
    two_level_scan: bool = True,
):
    """Scan over layer groups. Returns (h, new_caches, aux_total).

    Training memory: scan-of-checkpointed-body saves h per group — and XLA
    (measured on this backend) hoists the backward loop's bf16->f32 convert
    of that stack out of the loop, materializing BOTH dtypes. Two-level
    (sqrt-L) scan cuts the live stack from O(G) to O(sqrt(G)): the outer
    scan checkpoints blocks of groups, the inner scan checkpoints single
    groups and is replayed per-block in the backward pass.
    """
    shared = params.get("shared_attn")

    def group_body(carry, xs):
        h, aux_acc = carry
        h = dist_ctx.constrain("activations", h)
        gp, gc = xs
        new_gc = []
        for pos_idx, kind in enumerate(cfg.layer_pattern):
            cache_i = gc[pos_idx] if gc is not None else None
            h, nc, aux = _apply_layer(
                gp[pos_idx], h, cfg, kind, mode=mode, positions=positions,
                cache=cache_i, cur_pos=cur_pos, vision_states=vision_states,
                shared_params=shared, cache_len=cache_len,
            )
            new_gc.append(nc)
        if all(c is None for c in new_gc):
            out_gc = None
        else:
            out_gc = tuple(new_gc)
        return (h, aux_acc + aux), out_gc

    carry0 = (h, jnp.zeros((), jnp.float32))
    n_outer = _pick_outer(cfg.n_groups) if (remat and two_level_scan and caches is None) else 1
    if n_outer > 1 and mode == "train":
        n_inner = cfg.n_groups // n_outer
        groups2 = jax.tree_util.tree_map(
            lambda x: x.reshape(n_outer, n_inner, *x.shape[1:]), params["groups"]
        )

        def outer_body(carry, gp_block):
            carry, _ = lax.scan(jax.checkpoint(group_body), carry, (gp_block, None))
            return carry, None

        (h, aux), _ = lax.scan(jax.checkpoint(outer_body), carry0, groups2)
        return h, None, aux

    body = jax.checkpoint(group_body) if remat else group_body
    (h, aux), new_caches = lax.scan(body, carry0, (params["groups"], caches))
    return h, new_caches, aux


def _inputs_to_h(params, cfg: ModelConfig, tokens, embeds):
    if cfg.embed_input:
        return embed(tokens, params["embed"], cfg.scale_embedding)
    return embeds.astype(_dt(cfg))


def _logits(params, cfg: ModelConfig, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(h, table, cfg.tie_embeddings)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def chunked_xent(params, cfg: ModelConfig, h, targets, chunk: int = 512):
    """Cross-entropy without ever materializing (B, S, V) f32 logits: scan
    over sequence chunks, rematerializing each chunk's logits in the
    backward pass (jax.checkpoint on the chunk body). With the vocab dim of
    each chunk's logits sharded over 'model', peak loss memory is
    B * chunk * V/n_model * 4 bytes instead of B * S * V * 4."""
    b, s, d = h.shape
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
        s = h.shape[1]
    nc = s // chunk
    hc = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xs):
        hh, tt = xs  # (B, c, D), (B, c)
        logits = unembed(hh, table, cfg.tie_embeddings).astype(jnp.float32)
        logits = dist_ctx.constrain("logits_chunk", logits)
        logits = softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.clip(tt, 0, cfg.vocab_size - 1).astype(jnp.int32)
        picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        mask = (tt >= 0).astype(jnp.float32)
        nll = (lse - picked) * mask
        return (acc[0] + nll.sum(), acc[1] + mask.sum()), None

    (tot, cnt), _ = lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, tc))
    return tot / jnp.maximum(cnt, 1.0), cnt


def forward_train(
    params, cfg: ModelConfig, batch: Dict, remat: bool = True, loss_chunk: int = 512
):
    """batch: {'inputs' (B,S) i32 | 'embeds' (B,S,D), 'targets' (B,S) i32,
    optional 'vision_states' (B,N,D)}. Returns (loss, metrics)."""
    tokens = batch.get("inputs")
    h = _inputs_to_h(params, cfg, tokens, batch.get("embeds"))
    b, s = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _, aux = _stack(
        params, cfg, h, mode="train", positions=positions, caches=None,
        cur_pos=None, vision_states=batch.get("vision_states"),
        cache_len=s, remat=remat,
    )
    loss, n_tok = chunked_xent(params, cfg, h, batch["targets"], chunk=loss_chunk)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": n_tok}


def prefill(params, cfg: ModelConfig, batch: Dict, cache_len: Optional[int] = None):
    """Returns (last-position logits (B,V), caches, last_pos (B,))."""
    tokens = batch.get("inputs")
    h = _inputs_to_h(params, cfg, tokens, batch.get("embeds"))
    b, s = h.shape[:2]
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, caches, _ = _stack(
        params, cfg, h, mode="prefill", positions=positions, caches=None,
        cur_pos=None, vision_states=batch.get("vision_states"), cache_len=cache_len,
    )
    logits = _logits(params, cfg, h[:, -1:, :])[:, 0]
    return logits, caches, jnp.full((b,), s - 1, jnp.int32)


def decode_step(params, cfg: ModelConfig, batch: Dict, caches, cur_pos):
    """One decode step. batch: {'inputs' (B,1) | 'embeds' (B,1,D), optional
    vision_states}; cur_pos (B,) position of the NEW token. Returns
    (logits (B,V), new_caches)."""
    tokens = batch.get("inputs")
    h = _inputs_to_h(params, cfg, tokens, batch.get("embeds"))
    b = h.shape[0]
    positions = cur_pos[:, None]
    h, caches, _ = _stack(
        params, cfg, h, mode="decode", positions=positions, caches=caches,
        cur_pos=cur_pos, vision_states=batch.get("vision_states"),
        cache_len=int(caches_len(caches)),
    )
    logits = _logits(params, cfg, h)[:, 0]
    return logits, caches


def caches_len(caches) -> int:
    """Cache sequence length (static) from any attn cache leaf."""
    lens = []

    def visit(x):
        if hasattr(x, "shape") and x.ndim >= 3:
            lens.append(x.shape)

    jax.tree_util.tree_map(visit, caches)
    for shp in lens:
        if len(shp) == 5:  # (G, B, L, K, hd)
            return shp[2]
    return 0


def init_caches(params, cfg: ModelConfig, batch: int, cache_len: int, n_img: int = 0):
    """Zero caches for decode-from-scratch (and for the decode dry-run
    cells, where the cache is an input ShapeDtypeStruct)."""
    dt = _dt(cfg)
    hd = cfg.head_dim_
    spec = ssm_mod.spec_from_cfg(cfg) if any(
        k in ("ssm", "ssm_shared_attn") for k in cfg.layer_pattern
    ) else None
    per_pos = []
    g = cfg.n_groups
    for kind in cfg.layer_pattern:
        if kind in ("ssm", "ssm_shared_attn"):
            c = {
                "state": jnp.zeros((g, batch, spec.n_heads, spec.d_state, spec.head_dim), jnp.float32),
                "conv": jnp.zeros((g, batch, spec.d_conv - 1, spec.conv_dim), jnp.float32),
            }
            if kind == "ssm_shared_attn":
                nh, nkv = cfg.shared_attn_heads, cfg.shared_attn_kv_heads
                shd = cfg.d_model // nh
                c["sa"] = {
                    "k": jnp.zeros((g, batch, cache_len, nkv, shd), dt),
                    "v": jnp.zeros((g, batch, cache_len, nkv, shd), dt),
                }
            per_pos.append(c)
        elif kind == "cross":
            per_pos.append(
                {
                    "k": jnp.zeros((g, batch, n_img, cfg.n_kv_heads, hd), dt),
                    "v": jnp.zeros((g, batch, n_img, cfg.n_kv_heads, hd), dt),
                }
            )
        else:
            # Local layers: window-sized ring cache (slot = pos % W).
            eff = min(cfg.window, cache_len) if kind == "local" else cache_len
            per_pos.append(
                {
                    "k": jnp.zeros((g, batch, eff, cfg.n_kv_heads, hd), dt),
                    "v": jnp.zeros((g, batch, eff, cfg.n_kv_heads, hd), dt),
                }
            )
    return tuple(per_pos)


class Model:
    """Thin OO veneer used by examples/serving; the functional entry points
    above are what the launcher jits."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key) -> PyTree:
        return init_params(key, self.cfg)

    def loss(self, params, batch, remat: bool = True):
        return forward_train(params, self.cfg, batch, remat=remat)

    def prefill(self, params, batch, cache_len=None):
        return prefill(params, self.cfg, batch, cache_len)

    def decode_step(self, params, batch, caches, cur_pos):
        return decode_step(params, self.cfg, batch, caches, cur_pos)
