"""Blocked (flash-style) attention in pure lax, with a custom-VJP
memory-efficient backward — the framework's attention primitive for
training and prefill, plus the masked full-cache read used at decode.

Why blocked: a 32k-token prefill with materialized (B, H, S, S) scores
cannot compile within HBM. We stream KV in blocks with an online-softmax
accumulator; temporaries stay at (B, H, q_chunk, kv_block).

Why q-chunked with static prefix lengths: for causal attention, q-chunk i
only needs KV blocks 0..i, so compiled FLOPs are block-triangular (~half
the full rectangle), keeping HLO_FLOPs honest vs the 6ND model. Sliding-
window layers additionally skip blocks outside [q0 - window, q1).

Why custom_vjp: jax's autodiff of the online-softmax scan saves per-block
probabilities (or acc carries) as residuals — measured 10-30 GiB/device on
train_4k cells, defeating the point of flash attention. The custom
backward saves only (q, k, v, out, lse) and recomputes each block's
probabilities from lse, exactly like FlashAttention's dq/dk/dv pass
[arXiv:2205.14135].

GQA: queries reshape to (B, S, n_kv, group, d); every einsum carries the
kv-head axis so KV is never materialized repeated.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -2.0e30


class _Opts(NamedTuple):
    causal: bool
    window: Optional[int]
    softcap_val: Optional[float]
    scale: float
    q_chunk: int
    kv_block: int
    q_offset: int


def _mask(abs_q0, p0, sq, skv_block, skv_total, opts: _Opts):
    qi = abs_q0 + jnp.arange(sq, dtype=jnp.int32)[:, None]
    kj = p0 + jnp.arange(skv_block, dtype=jnp.int32)[None, :]
    m = kj < skv_total  # block padding
    if opts.causal:
        m &= kj <= qi
    if opts.window is not None:
        m &= kj > qi - opts.window
    return m


def _logits(qc, kb, opts: _Opts):
    """(B,Sq,K,G,D) x (B,Skv,K,D) -> (B,K,G,Sq,Skv) f32, capped but NOT
    masked."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kb, preferred_element_type=jnp.float32)
    s = s * jnp.float32(opts.scale)
    if opts.softcap_val is not None:
        c = jnp.float32(opts.softcap_val)
        s = jnp.tanh(s / c) * c
    return s


def _chunk_plan(sq, skv, opts: _Opts):
    """Static per-q-chunk KV extents."""
    q_chunk = min(opts.q_chunk, sq)
    kv_block = min(opts.kv_block, skv)
    plans = []
    n_q = (sq + q_chunk - 1) // q_chunk
    for qi in range(n_q):
        q0 = qi * q_chunk
        q1 = min(q0 + q_chunk, sq)
        abs_q0, abs_q1 = opts.q_offset + q0, opts.q_offset + q1
        kv_end = skv if not opts.causal else max(min(skv, abs_q1), 1)
        kv_start = 0
        if opts.window is not None:
            kv_start = max(0, ((abs_q0 - opts.window + 1) // kv_block) * kv_block)
            kv_start = min(kv_start, max(kv_end - kv_block, 0))
        n_kv = (kv_end - kv_start + kv_block - 1) // kv_block
        plans.append((q0, q1, abs_q0, kv_start, n_kv))
    return q_chunk, kv_block, plans


def _kv_blocks(k, kv_start, n_kv, kv_block):
    b, skv, kh, d = k.shape
    ext = n_kv * kv_block
    k_ext = k[:, kv_start : min(kv_start + ext, skv)]
    if k_ext.shape[1] < ext:
        k_ext = jnp.pad(k_ext, ((0, 0), (0, ext - k_ext.shape[1]), (0, 0), (0, 0)))
    return k_ext.reshape(b, n_kv, kv_block, kh, d).transpose(1, 0, 2, 3, 4)


def _flash_fwd_impl(q, k, v, opts: _Opts):
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d)
    q_chunk, kv_block, plans = _chunk_plan(sq, skv, opts)
    outs, lses = [], []
    for (q0, q1, abs_q0, kv_start, n_kv) in plans:
        qc = qf[:, q0:q1]
        sqc = q1 - q0
        kb = _kv_blocks(k, kv_start, n_kv, kv_block)
        vb = _kv_blocks(v, kv_start, n_kv, kv_block)
        kv_pos = kv_start + jnp.arange(n_kv, dtype=jnp.int32) * kv_block

        def body(carry, xs):
            m_run, l_run, acc = carry
            kblk, vblk, p0 = xs
            s = _logits(qc, kblk, opts)
            msk = _mask(jnp.int32(abs_q0), p0, sqc, kv_block, skv, opts)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vblk, preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv), None

        m0 = jnp.full((b, kh, g, sqc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, sqc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, sqc, d), jnp.float32)
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kb, vb, kv_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(b, sqc, h, d))
        lses.append(lse)  # (b, kh, g, sqc)
    out = jnp.concatenate(outs, axis=1).astype(q.dtype)
    lse = jnp.concatenate(lses, axis=3)  # (b, kh, g, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, opts: _Opts):
    out, _ = _flash_fwd_impl(q, k, v, opts)
    return out


def _flash_fwd(q, k, v, opts: _Opts):
    out, lse = _flash_fwd_impl(q, k, v, opts)
    return out, (q, k, v, out, lse)


def _flash_bwd(opts: _Opts, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d)
    doutf = dout.astype(jnp.float32).reshape(b, sq, kh, g, d)
    outf = out.astype(jnp.float32).reshape(b, sq, kh, g, d)
    # delta = rowwise dot(dout, out): (b, kh, g, sq)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", doutf, outf)
    q_chunk, kv_block, plans = _chunk_plan(sq, skv, opts)

    dq = jnp.zeros((b, sq, kh, g, d), jnp.float32)
    dk = jnp.zeros((b, skv, kh, d), jnp.float32)
    dv = jnp.zeros((b, skv, kh, d), jnp.float32)

    for (q0, q1, abs_q0, kv_start, n_kv) in plans:
        sqc = q1 - q0
        qc = qf[:, q0:q1]
        dc = doutf[:, q0:q1]
        lsec = lse[..., q0:q1]
        delc = delta[..., q0:q1]
        kb = _kv_blocks(k, kv_start, n_kv, kv_block)
        vb = _kv_blocks(v, kv_start, n_kv, kv_block)
        kv_pos = kv_start + jnp.arange(n_kv, dtype=jnp.int32) * kv_block

        def body(dq_c, xs):
            kblk, vblk, p0 = xs
            sraw = jnp.einsum("bqkgd,bskd->bkgqs", qc, kblk, preferred_element_type=jnp.float32)
            s = sraw * jnp.float32(opts.scale)
            if opts.softcap_val is not None:
                c = jnp.float32(opts.softcap_val)
                t = jnp.tanh(s / c)
                s_capped = t * c
            else:
                t = None
                s_capped = s
            msk = _mask(jnp.int32(abs_q0), p0, sqc, kv_block, skv, opts)
            s_masked = jnp.where(msk[None, None, None], s_capped, NEG_INF)
            p = jnp.exp(s_masked - lsec[..., None])  # (b,kh,g,q,s)
            dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p, dc)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dc, vblk, preferred_element_type=jnp.float32)
            ds = p * (dp - delc[..., None])  # d/d s_capped
            if t is not None:
                ds = ds * (1.0 - t * t)  # through tanh cap
            ds = ds * jnp.float32(opts.scale)
            ds = jnp.where(msk[None, None, None], ds, 0.0)
            dq_blk = jnp.einsum("bkgqs,bskd->bqkgd", ds, kblk)
            dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qc)
            return dq_c + dq_blk, (dk_blk, dv_blk)

        dq0 = jnp.zeros((b, sqc, kh, g, d), jnp.float32)
        dq_c, (dk_blocks, dv_blocks) = lax.scan(body, dq0, (kb, vb, kv_pos))
        dq = dq.at[:, q0:q1].add(dq_c)
        ext = n_kv * kv_block
        hi = min(kv_start + ext, skv)
        dk_flat = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(b, ext, kh, d)
        dv_flat = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(b, ext, kh, d)
        dk = dk.at[:, kv_start:hi].add(dk_flat[:, : hi - kv_start])
        dv = dv.at[:, kv_start:hi].add(dv_flat[:, : hi - kv_start])

    return (
        dq.reshape(b, sq, h, d).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap_val: Optional[float] = None,
    scale: Optional[float] = None,
    q_chunk: int = 1024,
    kv_block: int = 1024,
    q_offset: int = 0,
):
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D) with H % K == 0.
    Returns (B, Sq, H, D) in q.dtype."""
    d = q.shape[-1]
    opts = _Opts(
        causal=causal,
        window=window,
        softcap_val=softcap_val,
        scale=scale if scale is not None else 1.0 / math.sqrt(d),
        q_chunk=q_chunk,
        kv_block=kv_block,
        q_offset=q_offset,
    )
    return _flash(q, k, v, opts)


def decode_attention(
    q,
    k_cache,
    v_cache,
    cur_pos,
    *,
    window: Optional[int] = None,
    softcap_val: Optional[float] = None,
    scale: Optional[float] = None,
    slot_positions=None,
):
    """Single-step decode: q (B, 1, H, D) against a cache (B, L, K, D);
    positions > cur_pos, < 0, or outside the window are masked.
    slot_positions (B, L): absolute position held by each cache slot —
    defaults to arange(L) (linear cache); ring-buffer local-layer caches
    pass their slot->position map. Memory-bound by design — the whole cache
    is read once."""
    b, _, h, d = q.shape
    _, L, kh, _ = k_cache.shape
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, kh, g, d)
    logits = jnp.einsum(
        "bkgd,blkd->bkgl", qf, k_cache, preferred_element_type=jnp.float32
    ) * jnp.float32(scale)
    if softcap_val is not None:
        c = jnp.float32(softcap_val)
        logits = jnp.tanh(logits / c) * c
    if slot_positions is None:
        pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None, :], (b, L))
    else:
        pos = slot_positions
    mask = (pos <= cur_pos[:, None]) & (pos >= 0)
    if window is not None:
        mask &= pos > cur_pos[:, None] - window
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgl,blkd->bkgd", p, v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def ring_slot_positions(cur_pos, n_slots: int):
    """Absolute position held by each slot of a ring cache written at
    (pos % n_slots): slot j holds the largest p <= cur with p % W == j;
    negative means not yet written."""
    j = jnp.arange(n_slots, dtype=jnp.int32)[None, :]
    cur = cur_pos[:, None]
    return cur - ((cur - j) % n_slots)
