"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Chunked dual form: within a chunk of length Q the computation is an
attention-like quadratic over the chunk (MXU-friendly); across chunks a
small (H, N, P) state carries via lax.scan. Decode is the O(1) recurrence
  state <- state * exp(dt*A) + dt * B ⊗ x ;  y = C · state + D * x
which is why SSM/hybrid archs own the long_500k cell.

TP note: the reference Mamba2 fuses z|x|B|C|dt into one in_proj; that fused
layout cannot shard on the 'model' axis (the split boundaries don't align
with any even partition). We keep mathematically identical SEPARATE
projections — z/x shard by heads over 'model', B/C/dt replicate (they are
tiny), and the whole SSD recurrence is then shard-local per head. Recorded
in DESIGN.md §Hardware-adaptation.

Shapes: d_inner = expand * d_model; heads H = d_inner / head_dim P;
B/C live in a single group (G=1) of state size N = cfg.ssm_state.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..distributed import ctx as dist_ctx
from .layers import rms_norm


class SSMSpec(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    d_conv: int
    chunk: int

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def spec_from_cfg(cfg) -> SSMSpec:
    d_inner = cfg.ssm_expand * cfg.d_model
    return SSMSpec(
        d_model=cfg.d_model,
        d_inner=d_inner,
        n_heads=d_inner // cfg.ssm_head_dim,
        head_dim=cfg.ssm_head_dim,
        d_state=cfg.ssm_state,
        d_conv=cfg.ssm_conv,
        chunk=cfg.ssm_chunk,
    )


def init_ssm_params(key, spec: SSMSpec, dtype) -> dict:
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(spec.d_model)
    n = spec.d_state

    def w(k, shape, s=std):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)

    return {
        "in_z": w(ks[0], (spec.d_model, spec.d_inner)),
        "in_x": w(ks[1], (spec.d_model, spec.d_inner)),
        "in_B": w(ks[2], (spec.d_model, n)),
        "in_C": w(ks[3], (spec.d_model, n)),
        "in_dt": w(ks[4], (spec.d_model, spec.n_heads)),
        "conv_x_w": jnp.full((spec.d_conv, spec.d_inner), 0.25, dtype),
        "conv_x_b": jnp.zeros((spec.d_inner,), dtype),
        "conv_B_w": jnp.full((spec.d_conv, n), 0.25, dtype),
        "conv_B_b": jnp.zeros((n,), dtype),
        "conv_C_w": jnp.full((spec.d_conv, n), 0.25, dtype),
        "conv_C_b": jnp.zeros((n,), dtype),
        "dt_bias": jnp.zeros((spec.n_heads,), jnp.float32),
        "A_log": jnp.zeros((spec.n_heads,), jnp.float32),
        "D": jnp.ones((spec.n_heads,), jnp.float32),
        "norm": jnp.zeros((spec.d_inner,), dtype),
        "out_proj": w(ks[5], (spec.d_inner, spec.d_model), 1.0 / math.sqrt(spec.d_inner)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B, S, C), w: (K, C). K small: unrolled
    taps (shift-and-add), no conv primitive needed."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _segsum(a):
    """(..., Q) -> (..., Q, Q) lower-triangular pairwise sums:
    out[i, j] = sum_{m in (j, i]} a[m], -inf above the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_(j..i]
    i = jnp.arange(q, dtype=jnp.int32)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD over a full sequence, chunked.

    x:  (b, s, h, p) f32    per-head inputs
    dt: (b, s, h)    f32    discretization steps (post-softplus)
    A:  (h,)         f32    negative decay rates
    B:  (b, s, n)    f32    input maps   (G=1 group)
    C:  (b, s, n)    f32    output maps
    initial_state: (b, h, n, p) f32 carried from a previous segment
    (chunked prefill continuation).
    Returns y: (b, s, h, p) f32 and final state (b, h, n, p).
    """
    b, s_real, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s_real)
    if s_real % q:
        # Pad to a chunk multiple with dt=0 positions: a = dt*A = 0 means
        # no decay and no input, so the final state is exact.
        pad = q - s_real % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s = x.shape[1]
    nc = s // q

    a = dt * A[None, None, :]  # (b, s, h) negative
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    ac = a.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)

    # --- intra-chunk (quadratic, attention-like) ---
    # Contraction order matters: pairwise products keep the largest
    # intermediate at (b,nc,h,q,q) [head-sharded]; a naive multi-operand
    # einsum materializes (b,nc,q,h*p,q) — 16x larger (measured 12 GiB/dev
    # on mamba2 train_4k before this fix).
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (b,nc,h,q,q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,nc,q,q)
    m = scores[:, :, None, :, :] * L  # (b,nc,h,i,j)
    m = m * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # * dt_j
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", m, xc)  # batched (i,j)x(j,p)

    # --- chunk summary states ---
    a_cum = jnp.cumsum(ac, axis=2)  # (b,nc,q,h)
    a_tail = a_cum[:, :, -1:, :] - a_cum  # decay from pos j to chunk end
    wx = (jnp.exp(a_tail) * dtc)[..., None] * xc  # (b,nc,q,h,p)
    states = jnp.einsum("bcjn,bcjhp->bchnp", Bc, wx)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b,nc,h)

    def scan_body(s_prev, inp):
        st, dec = inp  # (b,h,n,p), (b,h)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, n, p), jnp.float32)
    )
    s_final, s_prevs = lax.scan(
        scan_body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # (b,nc,h,n,p): state entering chunk

    # --- state -> output within each chunk ---
    cs = jnp.einsum("bcin,bchnp->bcihp", Cc, s_prevs)  # (b,nc,q,h,p)
    y_off = cs * jnp.exp(a_cum)[..., None]  # a_cum: (b,nc,q,h)
    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_real]
    return y, s_final


def ssm_forward(
    params: dict,
    x,
    spec: SSMSpec,
    *,
    initial_state: Optional[Tuple] = None,
    return_state: bool = False,
):
    """Full-sequence Mamba2 block. x: (B, S, D) -> (B, S, D).
    State = (ssd_state (B,H,N,P) f32, conv_tail (B, d_conv-1, conv_dim) f32)
    where conv_tail stacks [x | B | C] pre-conv channels."""
    b, s, d = x.shape
    h, p, n = spec.n_heads, spec.head_dim, spec.d_state
    dt_x = x.dtype

    z = jnp.einsum("bsd,de->bse", x, params["in_z"])
    xs_raw = jnp.einsum("bsd,de->bse", x, params["in_x"])
    B_raw = jnp.einsum("bsd,dn->bsn", x, params["in_B"])
    C_raw = jnp.einsum("bsd,dn->bsn", x, params["in_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["in_dt"])

    if initial_state is not None:
        tail = initial_state[1].astype(xs_raw.dtype)  # (B, K-1, conv_dim)
        tx, tb, tc = jnp.split(tail, [spec.d_inner, spec.d_inner + n], axis=-1)
        xs_c = _causal_conv(jnp.concatenate([tx, xs_raw], 1), params["conv_x_w"], params["conv_x_b"])[:, tx.shape[1]:]
        B_c = _causal_conv(jnp.concatenate([tb, B_raw], 1), params["conv_B_w"], params["conv_B_b"])[:, tb.shape[1]:]
        C_c = _causal_conv(jnp.concatenate([tc, C_raw], 1), params["conv_C_w"], params["conv_C_b"])[:, tc.shape[1]:]
    else:
        xs_c = _causal_conv(xs_raw, params["conv_x_w"], params["conv_x_b"])
        B_c = _causal_conv(B_raw, params["conv_B_w"], params["conv_B_b"])
        C_c = _causal_conv(C_raw, params["conv_C_w"], params["conv_C_b"])
    xs = jax.nn.silu(xs_c)
    Bv = jax.nn.silu(B_c)
    Cv = jax.nn.silu(C_c)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    # Head-shard the SSD internals over 'model': the intra-chunk L and
    # decay tensors are (B, nc, H, Q, Q)-sized — without this hint GSPMD
    # replicates them and the dual form blows past HBM.
    x4 = dist_ctx.constrain("ssm_x4", xs.astype(jnp.float32).reshape(b, s, h, p))
    dt = dist_ctx.constrain("ssm_heads3", dt)
    y, s_final = ssd_chunked(
        x4,
        dt,
        A,
        Bv.astype(jnp.float32),
        Cv.astype(jnp.float32),
        spec.chunk,
        initial_state=initial_state[0] if initial_state is not None else None,
    )
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32).reshape(b, s, h, p)
    y = y.reshape(b, s, spec.d_inner).astype(dt_x)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if return_state:
        k1 = spec.d_conv - 1
        pre = jnp.concatenate([xs_raw, B_raw, C_raw], axis=-1)
        if s < k1:
            prev = (
                initial_state[1].astype(pre.dtype)
                if initial_state is not None
                else jnp.zeros((b, k1, pre.shape[-1]), pre.dtype)
            )
            pre = jnp.concatenate([prev, pre], axis=1)
        tail = pre[:, -k1:, :]
        return out, (s_final, tail.astype(jnp.float32))
    return out


def ssm_decode_step(params: dict, x, state, spec: SSMSpec):
    """One-token decode. x: (B, 1, D). Returns (y (B,1,D), new state)."""
    b = x.shape[0]
    h, p, n = spec.n_heads, spec.head_dim, spec.d_state
    ssm_state, conv_tail = state  # (B,H,N,P), (B, K-1, conv_dim)

    z = jnp.einsum("bsd,de->bse", x, params["in_z"])
    xs_raw = jnp.einsum("bsd,de->bse", x, params["in_x"])[:, 0]
    B_raw = jnp.einsum("bsd,dn->bsn", x, params["in_B"])[:, 0]
    C_raw = jnp.einsum("bsd,dn->bsn", x, params["in_C"])[:, 0]
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["in_dt"])[:, 0]

    pre = jnp.concatenate([xs_raw, B_raw, C_raw], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([conv_tail.astype(pre.dtype), pre[:, None, :]], axis=1)  # (B,K,C)
    w_all = jnp.concatenate([params["conv_x_w"], params["conv_B_w"], params["conv_C_w"]], axis=-1)
    b_all = jnp.concatenate([params["conv_x_b"], params["conv_B_b"], params["conv_C_b"]], axis=-1)
    conv_out = jnp.einsum("bkc,kc->bc", window, w_all) + b_all
    new_tail = window[:, 1:, :].astype(jnp.float32)
    xs = jax.nn.silu(conv_out[:, : spec.d_inner])
    Bv = jax.nn.silu(conv_out[:, spec.d_inner : spec.d_inner + n]).astype(jnp.float32)
    Cv = jax.nn.silu(conv_out[:, spec.d_inner + n :]).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])  # (B,H)
    A = -jnp.exp(params["A_log"])
    d_a = jnp.exp(dt * A[None, :])  # (B,H)
    xh = xs.astype(jnp.float32).reshape(b, h, p)
    new_state = ssm_state * d_a[..., None, None] + jnp.einsum("bn,bh,bhp->bhnp", Bv, dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cv, new_state) + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, spec.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, (new_state, new_tail)


def init_ssm_state(batch: int, spec: SSMSpec):
    return (
        jnp.zeros((batch, spec.n_heads, spec.d_state, spec.head_dim), jnp.float32),
        jnp.zeros((batch, spec.d_conv - 1, spec.conv_dim), jnp.float32),
    )
