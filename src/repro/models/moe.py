"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design for TPU + GSPMD (see DESIGN.md): activations between blocks are
replicated over the 'model' mesh axis (standard TP), experts are sharded
over 'model' (EP on the same axis). Dispatch is *local selection*, not
all_to_all: a scatter builds the (E, C, D) expert buffer, sharded on E, so
each shard materializes only its experts' tokens; the combine scatter-adds
back to the replicated activation, which GSPMD completes with the same
all-reduce a dense TP FFN needs anyway.

FLOPs honesty: dispatch/combine are gathers/scatters (O(bytes), ~0 FLOPs);
expert compute is E_local × C × (GLU matmuls) ≈ tokens × top_k ×
capacity_factor × per-expert-FFN — matching 6·N_active·D within the
capacity slack, unlike dense one-hot dispatch (which would inflate
HLO_FLOPs ~E/top_k x).

Capacity-overflow tokens are dropped (GShard semantics); the router's
aux load-balancing loss (Switch-style) keeps drop rates low in training.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed import ctx as dist_ctx
from .layers import activation


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": (jax.random.normal(k1, (d_model, n_experts), jnp.float32) * std_in),
        "wi_gate": (jax.random.normal(k2, (n_experts, d_model, d_ff), dtype) * std_in).astype(dtype),
        "wi_up": (jax.random.normal(k3, (n_experts, d_model, d_ff), dtype) * std_in).astype(dtype),
        "wo": (jax.random.normal(k4, (n_experts, d_ff, d_model), dtype) * std_out).astype(dtype),
    }


def capacity_for(n_tokens: int, n_experts: int, top_k: int, capacity_factor: float) -> int:
    """Per-expert capacity. Rows align to the MXU (128) in the training
    regime, but the floor scales down for small token counts (decode:
    T_local of a few tokens would otherwise pad every expert to 128 rows —
    measured 100x useful/HLO waste on the MoE decode cells)."""
    c = int(math.ceil(n_tokens * top_k * capacity_factor / n_experts))
    if n_tokens >= 1024:
        return max(((c + 127) // 128) * 128, 128)  # MXU-aligned rows
    return max(((c + 7) // 8) * 8, 8)  # decode-sized: sublane-aligned


def _dispatch_compute_combine(xf, router, wi_gate, wi_up, wo, *, top_k, cap, act,
                              e_first: int = 0, e_local: Optional[int] = None):
    """Shared core: route + capacity-dispatch xf (T, D) to experts
    [e_first, e_first + e_local), run the GLU FFN, weighted-combine back.
    Returns (y (T, D) partial over the expert range, aux f32)."""
    t, d = xf.shape
    e = router.shape[1]
    e_local = e if e_local is None else e_local

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E) f32
    gates, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux load-balancing loss (local tokens).
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_in_e = jnp.arange(t * top_k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    le = se.astype(jnp.int32) - e_first
    keep = (pos_in_e < cap) & (le >= 0) & (le < e_local)
    token_of = (order // top_k).astype(jnp.int32)
    gate_of = gates.reshape(-1)[order]

    slot = jnp.clip(le, 0, e_local - 1) * cap + jnp.clip(pos_in_e, 0, cap - 1)
    slot = jnp.where(keep, slot, e_local * cap)  # overflow slot (discarded)
    buf = jnp.zeros((e_local * cap + 1, d), xf.dtype).at[slot].set(xf[token_of])
    buf = buf[: e_local * cap].reshape(e_local, cap, d)
    buf = dist_ctx.constrain("moe_buf", buf) if e_local == e else buf

    g = activation(jnp.einsum("ecd,edf->ecf", buf, wi_gate), act)
    u = jnp.einsum("ecd,edf->ecf", buf, wi_up)
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, wo)
    out_buf = dist_ctx.constrain("moe_buf", out_buf) if e_local == e else out_buf

    flat_out = out_buf.reshape(e_local * cap, d)
    picked = flat_out[jnp.clip(slot, 0, e_local * cap - 1)]
    contrib = picked * jnp.where(keep, gate_of, 0.0).astype(picked.dtype)[:, None]
    y = jnp.zeros((t, d), xf.dtype).at[token_of].add(contrib)
    return y, aux


def moe_ffn(
    params: dict,
    x,
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    constrain_buf: Optional[Callable] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar f32).

    Under a mesh context with a >1 'model' axis and divisible experts, the
    expert-parallel shard_map path runs: routing + dispatch are LOCAL per
    (dp, model) shard (each model shard selects tokens for ITS experts from
    its dp-local, model-replicated activations) and the only collective is
    the per-layer psum over 'model' that dense TP FFNs pay anyway. Without
    it, GSPMD lowers the global argsort-dispatch into cross-device sorts —
    measured 9.4 s/step of collectives on moonshot train_4k."""
    b, s, d = x.shape
    e = params["router"].shape[1]

    mesh = dist_ctx.current_mesh()
    if mesh is not None:
        nm = mesh.shape.get("model", 1)
        dp_names = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp_sz = int(np.prod([mesh.shape[a] for a in dp_names])) if dp_names else 1
        if nm > 1 and e % nm == 0 and b % max(dp_sz, 1) == 0:
            return _moe_ffn_shard_map(
                params, x, top_k=top_k, capacity_factor=capacity_factor,
                act=act, mesh=mesh, dp_names=dp_names,
            )

    t = b * s
    cap = capacity_for(t, e, top_k, capacity_factor)
    y, aux = _dispatch_compute_combine(
        x.reshape(t, d), params["router"], params["wi_gate"], params["wi_up"],
        params["wo"], top_k=top_k, cap=cap, act=act,
    )
    return y.reshape(b, s, d), aux


def _moe_ffn_shard_map(params, x, *, top_k, capacity_factor, act, mesh, dp_names):
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    e = params["router"].shape[1]
    nm = mesh.shape["model"]
    dp_sz = int(np.prod([mesh.shape[a] for a in dp_names])) if dp_names else 1
    t_loc = (b // dp_sz) * s
    cap = capacity_for(t_loc, e, top_k, capacity_factor)
    bspec = dp_names if dp_names else None

    def inner(x_loc, router, wg, wu, wo):
        e_loc = wg.shape[0]
        m_idx = jax.lax.axis_index("model")
        bl, sl, dl = x_loc.shape
        y, aux = _dispatch_compute_combine(
            x_loc.reshape(bl * sl, dl), router, wg, wu, wo,
            top_k=top_k, cap=cap, act=act,
            e_first=m_idx * e_loc, e_local=e_loc,
        )
        y = jax.lax.psum(y, "model")  # the TP combine a dense FFN pays too
        if dp_names:
            aux = jax.lax.pmean(aux, dp_names)
        return y.reshape(bl, sl, dl), aux

    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )(x, params["router"], params["wi_gate"], params["wi_up"], params["wo"])
