"""Filter condition syntax trees + compilation to a device predicate program.

Paper §III: "conditions take the form of a syntax tree, where each node is a
boolean operation ('and', 'or', 'not') or a conditional statement applied to
a particular field-value pair. Conditions can enforce equality, inequality,
or regular expression matching."

Host side: a small AST (Eq / Cmp / Match / In / And / Or / Not). Device
side: the tree compiles to a postfix (RPN) program over a boolean stack,
evaluated for every row of a columnar tile — this is the TPU-native
replacement for Accumulo's server-side WholeRowIterator subclass, and the
exact program format executed by the Pallas `filter_scan` kernel.

String-typed conditions resolve to dictionary code sets on the host
(Match -> prefix code set; Cmp on numeric-string fields -> code set), so the
device program only ever sees int32 comparisons — TPUs have no string unit.

Opcodes (postfix):
    NOP         padding
    PUSH_EQ     push (col[field] == code)
    PUSH_IN     push (col[field] in codeset[set_id])
    PUSH_TRUE   push all-true (empty residual)
    AND/OR/NOT  stack ops
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

# Canonical opcode values live with the shared device interpreter
# (kernels/program_eval.py) so the kernels package never has to import
# core at module scope; re-exported here for the host-side compiler API.
from ..kernels.program_eval import (  # noqa: F401
    MAX_STACK,
    OP_AND,
    OP_NOP,
    OP_NOT,
    OP_OR,
    OP_PUSH_EQ,
    OP_PUSH_IN,
    OP_PUSH_TRUE,
)


class Node:
    """Base class for filter syntax tree nodes."""


@dataclass(frozen=True)
class Eq(Node):
    field: str
    value: str


@dataclass(frozen=True)
class Cmp(Node):
    """Inequality on a numeric-string field (paper: 'field1 < value1').
    op in {'<', '<=', '>', '>='} — resolved host-side to a code set."""

    field: str
    op: str
    value: float


@dataclass(frozen=True)
class Match(Node):
    """Prefix match — the host-resolvable core of the paper's regex
    conditions (full regex falls back to host post-filtering)."""

    field: str
    prefix: str


@dataclass(frozen=True)
class In(Node):
    field: str
    values: Tuple[str, ...]


@dataclass(frozen=True)
class And(Node):
    children: Tuple[Node, ...]

    def __init__(self, *children: Node):
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Or(Node):
    children: Tuple[Node, ...]

    def __init__(self, *children: Node):
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Not(Node):
    child: Node


@dataclass(frozen=True)
class TrueNode(Node):
    """Matches everything (empty residual after index planning)."""


@dataclass
class FilterProgram:
    """Device-executable predicate program (see kernels/filter_scan)."""

    opcodes: np.ndarray  # int32 [P]
    arg0: np.ndarray  # int32 [P]   field id
    arg1: np.ndarray  # int32 [P]   code (PUSH_EQ) or codeset row (PUSH_IN)
    codesets: np.ndarray  # int32 [n_sets, max_set] padded with -1
    max_depth: int

    @property
    def length(self) -> int:
        return int(self.opcodes.shape[0])


class _Compiler:
    def __init__(self, store):
        self.store = store
        self.ops: List[Tuple[int, int, int]] = []
        self.codesets: List[np.ndarray] = []

    def _codeset(self, codes: np.ndarray) -> int:
        self.codesets.append(np.asarray(codes, dtype=np.int32))
        return len(self.codesets) - 1

    def emit(self, node: Node) -> int:
        """Returns stack depth consumed by subtree evaluation."""
        if isinstance(node, TrueNode):
            self.ops.append((OP_PUSH_TRUE, 0, 0))
            return 1
        if isinstance(node, Eq):
            fid = self.store.schema.field_id(node.field)
            code = self.store.dictionaries[node.field].lookup(node.value)
            if code is None:
                # Never-ingested value: matches nothing == IN(empty set).
                self.ops.append((OP_PUSH_IN, fid, self._codeset(np.empty(0, np.int32))))
            else:
                self.ops.append((OP_PUSH_EQ, fid, int(code)))
            return 1
        if isinstance(node, (Match, In, Cmp)):
            fid = self.store.schema.field_id(node.field)
            codes = resolve_codes(self.store, node)
            self.ops.append((OP_PUSH_IN, fid, self._codeset(codes)))
            return 1
        if isinstance(node, Not):
            d = self.emit(node.child)
            self.ops.append((OP_NOT, 0, 0))
            return d
        if isinstance(node, (And, Or)):
            opc = OP_AND if isinstance(node, And) else OP_OR
            if not node.children:
                raise ValueError("empty boolean node")
            depth = self.emit(node.children[0])
            for child in node.children[1:]:
                depth = max(depth, 1 + self.emit(child))
                self.ops.append((opc, 0, 0))
            return depth
        raise TypeError(f"unknown node {node!r}")


def resolve_codes(store, node: Node) -> np.ndarray:
    """Host-side resolution of non-equality conditions to dictionary code
    sets."""
    d = store.dictionaries[node.field]
    if isinstance(node, Match):
        return d.prefix_codes(node.prefix)
    if isinstance(node, In):
        codes = [d.lookup(v) for v in node.values]
        return np.asarray([c for c in codes if c is not None], dtype=np.int32)
    if isinstance(node, Cmp):
        out = []
        for s, c in d._fwd.items():
            try:
                x = float(s)
            except ValueError:
                continue
            if (
                (node.op == "<" and x < node.value)
                or (node.op == "<=" and x <= node.value)
                or (node.op == ">" and x > node.value)
                or (node.op == ">=" and x >= node.value)
            ):
                out.append(c)
        return np.asarray(out, dtype=np.int32)
    raise TypeError(node)


def compile_tree(store, tree: Optional[Node]) -> FilterProgram:
    """Compile a filter tree against a store's schema+dictionaries."""
    comp = _Compiler(store)
    depth = comp.emit(tree if tree is not None else TrueNode())
    if depth > MAX_STACK:
        raise ValueError(f"filter tree too deep for device stack ({depth} > {MAX_STACK})")
    ops = np.asarray(comp.ops, dtype=np.int32).reshape(-1, 3)
    max_set = max((len(c) for c in comp.codesets), default=0)
    n_sets = max(len(comp.codesets), 1)
    codesets = np.full((n_sets, max(max_set, 1)), -1, dtype=np.int32)
    for i, cs in enumerate(comp.codesets):
        codesets[i, : len(cs)] = cs
    return FilterProgram(
        opcodes=ops[:, 0].copy(),
        arg0=ops[:, 1].copy(),
        arg1=ops[:, 2].copy(),
        codesets=codesets,
        max_depth=depth,
    )


def eval_tree_rows(store, tree: Optional[Node], cols: np.ndarray) -> np.ndarray:
    """Pure-host oracle: evaluate a filter tree over rows of a columnar
    block (n, n_fields) of int32 codes. Used by tests as ground truth for
    both the compiled program and the Pallas kernel."""
    if tree is None or isinstance(tree, TrueNode):
        return np.ones(cols.shape[0], dtype=bool)
    if isinstance(tree, Eq):
        code = store.dictionaries[tree.field].lookup(tree.value)
        fid = store.schema.field_id(tree.field)
        if code is None:
            return np.zeros(cols.shape[0], dtype=bool)
        return cols[:, fid] == code
    if isinstance(tree, (Match, In, Cmp)):
        fid = store.schema.field_id(tree.field)
        codes = resolve_codes(store, tree)
        return np.isin(cols[:, fid], codes)
    if isinstance(tree, Not):
        return ~eval_tree_rows(store, tree.child, cols)
    if isinstance(tree, And):
        out = eval_tree_rows(store, tree.children[0], cols)
        for c in tree.children[1:]:
            out &= eval_tree_rows(store, c, cols)
        return out
    if isinstance(tree, Or):
        out = eval_tree_rows(store, tree.children[0], cols)
        for c in tree.children[1:]:
            out |= eval_tree_rows(store, c, cols)
        return out
    raise TypeError(tree)
