"""Query planner — paper §III-B, the four heuristics, verbatim.

"Query planning in this context is more accurately described as access path
selection" — the planner decides which equality conditions run as index
scans (key sets intersected/unioned at the client) and which run as tablet
server filters, using densities d_i from the aggregate table and a global
threshold w that "determines a threshold to avoid intersections between
sets of significantly different sizes".

Heuristics (quoted from the paper):
  1. root is Eq                         -> index scan.
  2. root is OR, all children Eq        -> index scan every child, union.
  3. root is AND                        -> index scan every Eq child with
       d_i < w * min_i d_i; intersect key sets; pass to event scanner with
       the remaining syntax tree as a filter.
  4. otherwise                          -> full tablet-server filtering.

One refinement on 1/3: an indexed equality condition whose density over
the query range is zero PROVES the (intersected) result empty — the
aggregate buckets cover a superset of [t_start, t_stop] — so the plan
short-circuits to mode='empty' and the executors skip every scan.

The density source is duck-typed: anything with .schema, .dictionaries
and .agg_count works — the host EventStore reads its aggregate table,
DistQueryProcessor psums the distributed aggregate tablets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .filter import And, Eq, Node, Or, TrueNode
from .store import EventStore

DEFAULT_W = 10.0  # paper: "a global, empirically derived parameter"


@dataclass
class IndexCond:
    field: str
    value: str
    density: float  # d_i: estimated matching rows in the time range


@dataclass
class QueryPlan:
    mode: str  # 'index' | 'filter' | 'empty'
    combine: str  # 'intersect' | 'union' (index mode)
    index_conds: List[IndexCond] = field(default_factory=list)
    residual: Optional[Node] = None  # tablet-server filter after index step

    def describe(self) -> str:
        if self.mode == "filter":
            return "full tablet-server filter"
        if self.mode == "empty":
            conds = ", ".join(f"{c.field}={c.value}" for c in self.index_conds)
            return f"provably empty (zero-density condition: {conds})"
        conds = ", ".join(f"{c.field}={c.value}(d={c.density:.0f})" for c in self.index_conds)
        res = "none" if isinstance(self.residual, TrueNode) or self.residual is None else "tree"
        return f"index[{self.combine}]({conds}) residual={res}"


def _density(store: EventStore, cond: Eq, t_start: int, t_stop: int) -> float:
    """d_i — 'a density estimate related to the inverse of selectivity',
    read from the aggregate table over the query's time range."""
    return float(store.agg_count(cond.field, cond.value, t_start, t_stop))


def plan_query(
    store: EventStore,
    tree: Optional[Node],
    t_start: int,
    t_stop: int,
    w: float = DEFAULT_W,
    use_index: bool = True,
) -> QueryPlan:
    if tree is None or isinstance(tree, TrueNode):
        return QueryPlan(mode="filter", combine="intersect", residual=TrueNode())
    if not use_index:
        return QueryPlan(mode="filter", combine="intersect", residual=tree)

    # Heuristic 1: root equality condition. A zero density over the
    # (bucket-superset) time range PROVES the result empty — the aggregate
    # buckets cover [t_start, t_stop], so no matching row can exist.
    # Short-circuit instead of emitting an index scan.
    if isinstance(tree, Eq) and store.schema.is_indexed(tree.field):
        d = _density(store, tree, t_start, t_stop)
        if d <= 0:
            return QueryPlan(
                mode="empty",
                combine="intersect",
                index_conds=[IndexCond(tree.field, tree.value, 0.0)],
            )
        return QueryPlan(
            mode="index",
            combine="intersect",
            index_conds=[IndexCond(tree.field, tree.value, d)],
            residual=TrueNode(),
        )

    # Heuristic 2: root OR with all-equality children.
    if isinstance(tree, Or) and all(
        isinstance(c, Eq) and store.schema.is_indexed(c.field) for c in tree.children
    ):
        conds = [
            IndexCond(c.field, c.value, _density(store, c, t_start, t_stop))
            for c in tree.children
        ]
        return QueryPlan(mode="index", combine="union", index_conds=conds, residual=TrueNode())

    # Heuristic 3: root AND — index the rare equality children. Any
    # indexed equality child with zero density proves the whole AND empty
    # (an empty set intersected with anything stays empty): short-circuit
    # rather than paying index scans of the other conditions plus a
    # residual tablet filter, per batch, for a provably-empty result.
    if isinstance(tree, And):
        eq_children = [
            c
            for c in tree.children
            if isinstance(c, Eq) and store.schema.is_indexed(c.field)
        ]
        if eq_children:
            dens = {c: _density(store, c, t_start, t_stop) for c in eq_children}
            d_min = min(dens.values())
            if d_min <= 0:
                zero = [c for c in eq_children if dens[c] <= 0]
                return QueryPlan(
                    mode="empty",
                    combine="intersect",
                    index_conds=[IndexCond(c.field, c.value, 0.0) for c in zero],
                )
            selected = [c for c in eq_children if dens[c] < w * max(d_min, 1.0)]
            if selected:
                rest = tuple(c for c in tree.children if c not in selected)
                residual: Node = And(*rest) if rest else TrueNode()
                return QueryPlan(
                    mode="index",
                    combine="intersect",
                    index_conds=[IndexCond(c.field, c.value, dens[c]) for c in selected],
                    residual=residual,
                )

    # Heuristic 4: everything else — tablet-server filtering.
    return QueryPlan(mode="filter", combine="intersect", residual=tree)
