"""Adaptive query batching — paper §III-A, Algorithms 1 and 2, verbatim.

Instead of executing a query over its whole time range [t_start, t_stop],
the range is partitioned into batches [p_i, p_i + b_i] sized to return
approximately k_i results. After each batch the observed (runtime T_i,
result count r_i) adapt the next batch:

    k_{i+1} <- c * k_i                       (grow desired count)
    That_{i+1} <- k_{i+1} * (T_i / r_i)      (estimate runtime)
    if That > T_max:  k_{i+1} <- T_max * (r_i / T_i)   (too large)
    elif That < T_min: k_{i+1} <- T_min * (r_i / T_i)  (too small)
    b_{i+1} <- min(k_{i+1} * (b_i / r_i), t_stop - p_i)
    p_{i+1} <- p_i + b_i + eps

Defaults (paper): k_0 = 10, c = 1.5, T_max = 30 s, T_min = 1 s. b_0 is
pre-computed per table from historical hit rates r/b. eps is the minimum
time resolution (1 s here: integer-second timestamps).

Deviation (documented): Alg 1 divides by r_i, undefined when a batch
returns zero rows. On r_i == 0 we keep k and grow b geometrically by c —
the least-surprising completion consistent with the algorithm's intent.

This same batcher drives BOTH the store's query processor (its original
role) and the serving engine's request scheduler (repro.serving.batcher) —
the paper's technique applied beyond the paper.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Tuple

DEFAULT_K0 = 10.0
DEFAULT_C = 1.5
DEFAULT_T_MAX = 30.0
DEFAULT_T_MIN = 1.0
DEFAULT_EPS = 1


def alg1_next_k(
    k: float, runtime: float, rows: int, c: float, t_max: float, t_min: float
) -> float:
    """The Alg-1 UPDATE law for the desired result count, shared verbatim
    by every admission policy in the repo: the range batcher below, the
    LM serving admission (repro.serving.batcher) and the query-serving
    scheduler's turn quantum (repro.serve_db.scheduler). Grow k
    geometrically; if the projected next runtime k' * (T/r) leaves
    [t_min, t_max], re-derive k' from the observed rate r/T so the next
    unit of work lands back inside the latency window. Returns the raw
    k' — callers apply their own floors/caps (the batcher floors at 1,
    serving caps at the slot pool, the scheduler caps at its turn
    budget). rows == 0 keeps k (the rate is unobservable)."""
    t_i = max(float(runtime), 1e-9)
    if rows <= 0:
        return float(k)
    k_next = c * k
    t_hat = k_next * (t_i / rows)
    if t_hat > t_max:
        k_next = t_max * (rows / t_i)
    elif t_hat < t_min:
        k_next = t_min * (rows / t_i)
    return float(k_next)


@dataclass
class BatchRecord:
    index: int
    p: float  # batch start position
    b: float  # batch size (time units)
    k: float  # desired result count when issued
    runtime: float = 0.0
    rows: int = 0


@dataclass
class AdaptiveBatcher:
    """Algorithm 1 state machine. One instance per executing query."""

    t_start: float
    t_stop: float
    b0: float  # initial batch size (per-table historical hit rate)
    k0: float = DEFAULT_K0
    c: float = DEFAULT_C
    t_max: float = DEFAULT_T_MAX
    t_min: float = DEFAULT_T_MIN
    eps: float = DEFAULT_EPS
    history: List[BatchRecord] = field(default_factory=list)

    def __post_init__(self):
        if self.t_stop < self.t_start:
            raise ValueError("t_stop < t_start")
        self._p = float(self.t_start)
        self._k = float(self.k0)
        self._b = max(min(float(self.b0), self.t_stop - self._p), self.eps)
        self._i = 0

    @property
    def done(self) -> bool:
        # Alg 2 line 1: while p_i < t_stop  (<= so a zero-width final range
        # [t, t] still executes once when t_start == t_stop).
        return self._p > self.t_stop if self._i > 0 else False

    def next_range(self) -> Tuple[float, float]:
        """Time range [p_i, p_i + b_i] for the next batch (inclusive)."""
        return self._p, min(self._p + self._b, self.t_stop)

    def update(self, runtime: float, rows: int) -> None:
        """Alg 1 UPDATE(T_i, r_i)."""
        rec = BatchRecord(self._i, self._p, self._b, self._k, runtime, rows)
        self.history.append(rec)
        if rows > 0:
            # Lines 2-7 are the shared law (alg1_next_k).
            k_next = alg1_next_k(self._k, runtime, rows, self.c, self.t_max, self.t_min)
            b_next = k_next * (self._b / rows)  # line 9
        else:
            # r_i == 0 guard (see module docstring).
            k_next = self._k
            b_next = self._b * self.c
        b_next = min(b_next, self.t_stop - self._p)  # line 9 clamp
        self._p = self._p + self._b + self.eps  # line 10
        self._b = max(b_next, self.eps)
        self._k = max(k_next, 1.0)
        self._i += 1


def run_batched_query(
    t_start: float,
    t_stop: float,
    b0: float,
    query: Callable[[float, float], Tuple[float, int]],
    **kw,
) -> AdaptiveBatcher:
    """Algorithm 2: execute `query(p, p + b)` over adapting batches until the
    position passes t_stop. `query` returns (runtime_seconds, n_rows)."""
    batcher = AdaptiveBatcher(t_start=t_start, t_stop=t_stop, b0=b0, **kw)
    while not batcher.done:
        lo, hi = batcher.next_range()
        runtime, rows = query(lo, hi)
        batcher.update(runtime, rows)
    return batcher


def iter_batches(
    t_start: float, t_stop: float, b0: float, **kw
) -> Iterator[Tuple[Tuple[float, float], Callable[[float, int], None]]]:
    """Generator form used by the query processor: yields
    ((lo, hi), report) pairs; caller must invoke report(runtime, rows) before
    advancing."""
    batcher = AdaptiveBatcher(t_start=t_start, t_stop=t_stop, b0=b0, **kw)
    while not batcher.done:
        rng = batcher.next_range()
        reported = {}

        def report(runtime: float, rows: int, _r=reported):
            _r["x"] = (runtime, rows)

        yield rng, report
        if "x" not in reported:
            raise RuntimeError("iter_batches: caller did not report batch stats")
        batcher.update(*reported["x"])


class HitRateTracker:
    """Per-table historical hit rate r/b used to seed b_0 (paper: 'b_0
    pre-computed for the particular Accumulo table being queried based on
    the typical hit-rates of previous queries on that table').

    Thread-safe: one tracker is shared by every session querying the same
    table through the serve plane, so concurrent observe() calls must not
    tear the EWMA update."""

    def __init__(self, default_rate: float = 1.0, alpha: float = 0.2):
        self._rate = default_rate  # rows per time unit
        self._alpha = alpha
        self._lock = threading.Lock()

    def observe(self, rows: int, b: float) -> None:
        if b > 0:
            with self._lock:
                self._rate = (1 - self._alpha) * self._rate + self._alpha * (rows / b)

    def initial_b(self, k0: float = DEFAULT_K0) -> float:
        return max(k0 / max(self._rate, 1e-9), 1.0)
