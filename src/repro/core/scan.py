"""Scanners — range reads against the sharded store.

Paper semantics reproduced:
  * Scanner: "given a starting and ending row ID range ... will only return
    those entries whose row IDs fall within that range" — here a packed-key
    range per shard resolved by vectorized searchsorted.
  * BatchScanner: "due to sharding, all queries utilize the BatchScanner,
    which makes no guarantee on the ordering of results ... results are
    returned from each tablet server as they become available" — we iterate
    shards and yield per-shard row blocks; cross-shard order is unspecified.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import keypack
from .store import EventStore, join_key64


@dataclass
class RowBlock:
    """A block of event rows from one shard (columnar)."""

    shard: int
    keys: np.ndarray  # int64 [n] packed event keys
    cols: np.ndarray  # int32 [n, n_cols] dictionary codes
    field_ids: Optional[np.ndarray] = None  # set when projected: cols -> schema ids

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    @property
    def nbytes(self) -> int:
        """Bytes this block costs to ship to the client (the quantity the
        iterator stack exists to shrink)."""
        return self.keys.nbytes + self.cols.nbytes

    def ts(self) -> np.ndarray:
        _, rts, _ = keypack.unpack_event_key(self.keys)
        return keypack.unrev_ts(rts)


def scan_events(
    store: EventStore,
    t_start: int,
    t_stop: int,
    shards: Optional[Sequence[int]] = None,
    iterators=None,
) -> Iterator[RowBlock]:
    """BatchScanner over the event table restricted to a time range
    (timestamps in [t_start, t_stop], inclusive — the paper's queries are
    always time-restricted).

    `iterators`: optional IteratorStack (core/iterators.py) applied to each
    block before it leaves the scanner — the server side of the scan. With
    a terminal CombinerIterator the scan yields AggregateBlocks."""
    for s in shards if shards is not None else range(store.n_shards):
        lo, hi = keypack.event_key_range(s, t_start, t_stop)
        keys, cols = store.event_tablets[s].scan_range(int(lo), int(hi))
        if keys.size:
            blk = RowBlock(s, keys, cols)
            if iterators is not None:
                blk = iterators.apply_block(blk)
                if blk is None:
                    continue
            yield blk


def index_scan(
    store: EventStore,
    field: str,
    value_codes: np.ndarray,
    t_start: int,
    t_stop: int,
    shards: Optional[Sequence[int]] = None,
) -> List[np.ndarray]:
    """Index-table lookup: event keys (per shard, sorted) for rows where
    `field` has any of `value_codes`, within the time range. This is the
    paper's 'index table encodes field names and values in the row ID to
    allow fast look-ups by column value'."""
    fid = store.schema.field_id(field)
    out: List[np.ndarray] = []
    for s in shards if shards is not None else range(store.n_shards):
        tab = store.index_tablets[s]
        parts = []
        for code in np.atleast_1d(value_codes):
            lo = keypack.pack_index_key(fid, int(code), keypack.rev_ts(t_stop))
            hi = keypack.pack_index_key(fid, int(code), keypack.rev_ts(t_start)) + 1
            _, payload = tab.scan_range(int(lo), int(hi))
            if payload.size:
                parts.append(join_key64(payload[:, 0], payload[:, 1]))
        if parts:
            ek = np.concatenate(parts)
            ek.sort()
            out.append(ek)
        else:
            out.append(np.empty(0, np.int64))
    return out


def fetch_rows_by_keys(
    store: EventStore, shard: int, event_keys: np.ndarray
) -> RowBlock:
    """Point-lookups of event rows given packed keys (sorted), within one
    shard — the 'resulting row IDs passed to an event table scanner' step of
    the paper's query plan (Fig 2)."""
    tab = store.event_tablets[shard]
    runs = tab.snapshot_runs()
    found_k: List[np.ndarray] = []
    found_c: List[np.ndarray] = []
    for r in runs:
        pos = np.searchsorted(r.keys, event_keys)
        pos_c = np.clip(pos, 0, max(r.n - 1, 0))
        hit = (pos < r.n) & (r.keys[pos_c] == event_keys) if r.n else np.zeros(len(event_keys), bool)
        if hit.any():
            found_k.append(event_keys[hit])
            found_c.append(r.cols[pos_c[hit]])
    if not found_k:
        return RowBlock(shard, np.empty(0, np.int64), np.empty((0, tab.width), np.int32))
    keys = np.concatenate(found_k)
    cols = np.concatenate(found_c)
    order = np.argsort(keys, kind="stable")
    return RowBlock(shard, keys[order], cols[order])
