"""Server-side iterator stack — Accumulo's composable scan-time iterators.

Accumulo's real power is not the single filter the paper's §III-B
WholeRowIterator demonstrates, but the *stack*: every scan runs a
configurable chain of iterators inside the tablet server — versioning
(newest entry wins), filtering, combining (aggregation at scan time),
projection — so data is reduced before it ever crosses the network. The
D4M 2.0 schema work (arXiv:1407.3859) and the 100M-inserts/sec study
(arXiv:1406.4923) both lean on exactly this machinery.

This module is the TPU-native equivalent. An iterator transforms one
columnar RowBlock at a time, server-side (inside scan_events / the
shard_map program), and a stack composes them in order:

    VersioningIterator   newest-entry-wins on duplicate packed keys
    FilterIterator       compiled predicate program (filter_scan kernel)
    ProjectingIterator   column subset (fewer bytes to the client)
    CombinerIterator     sum/min/max/count grouped by key prefix — the
                         terminal iterator: rows become aggregates

The combiner is fused with the filter into ONE kernel dispatch
(kernels/combine_scan): the row tile is filtered and segment-aggregated in
a single VMEM pass, so an aggregation query ships per-group partials to
the client instead of raw rows.

Stack ordering rules (validated):
  * at most one CombinerIterator, and it must be last;
  * ProjectingIterator must come after any FilterIterator (the filter
    program addresses fields by schema id) and cannot precede a combiner.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import keypack
from .filter import FilterProgram, Node, compile_tree
from .scan import RowBlock
from ..kernels.combine_scan import combine_scan
from ..kernels.filter_scan import filter_scan

MAX_GROUP_SPACE = 1 << 24  # dense-gid cap for the distributed psum path


# --------------------------------------------------------------- aggregates
@dataclass(frozen=True)
class AggregateSpec:
    """Scan-time aggregation spec (`Query.aggregate=`): e.g. "count events
    per src_ip per hour" is AggregateSpec(group_by=("src_ip",),
    time_bucket_s=3600)."""

    group_by: Tuple[str, ...]
    op: str = "count"  # 'count' | 'sum' | 'min' | 'max'
    value_field: Optional[str] = None  # aggregand for sum/min/max
    time_bucket_s: Optional[int] = None  # also group by ts // bucket

    def __post_init__(self):
        if self.op not in ("count", "sum", "min", "max"):
            raise ValueError(f"unknown combiner op {self.op!r}")
        if self.op != "count" and self.value_field is None:
            raise ValueError(f"op {self.op!r} needs value_field")
        if not self.group_by and self.time_bucket_s is None:
            raise ValueError("aggregate needs group_by fields or a time bucket")


@dataclass
class ResolvedGrouping:
    """AggregateSpec bound to a store + time range: mixed-radix packing of
    (group field codes ..., time bucket) into one int64 group id. Codes are
    dense per-field (dictionary), buckets dense over the query range, so
    the id space is dense too — which is what lets the distributed path
    combine partials with a fixed-size psum."""

    spec: AggregateSpec
    fids: Tuple[int, ...]
    radices: Tuple[int, ...]  # dictionary sizes at bind time
    n_buckets: int
    bucket_lo: int  # t_start // bucket_s
    value_fid: Optional[int]
    value_table: Optional[np.ndarray]  # int32 [n_codes]: code -> numeric value

    @property
    def strides(self) -> Tuple[int, ...]:
        out: List[int] = []
        s = self.n_buckets
        for r in reversed(self.radices):
            out.append(s)
            s *= r
        return tuple(reversed(out))

    @property
    def size(self) -> int:
        s = self.n_buckets
        for r in self.radices:
            s *= r
        return s

    def group_ids(self, ts: np.ndarray, cols: np.ndarray) -> np.ndarray:
        gid = np.zeros(len(ts), np.int64)
        for fid, stride in zip(self.fids, self.strides):
            gid += cols[:, fid].astype(np.int64) * stride
        if self.spec.time_bucket_s is not None:
            gid += ts // self.spec.time_bucket_s - self.bucket_lo
        return gid

    def values(self, cols: np.ndarray) -> Optional[np.ndarray]:
        if self.value_fid is None:
            return None
        codes = np.clip(cols[:, self.value_fid], 0, len(self.value_table) - 1)
        return self.value_table[codes]

    def unpack(self, gids: np.ndarray) -> Tuple[Dict[str, np.ndarray], Optional[np.ndarray]]:
        """gids -> per-field code arrays + bucket-start timestamps."""
        rest = np.asarray(gids, np.int64)
        bucket_ts = None
        if self.spec.time_bucket_s is not None:
            b = rest % self.n_buckets
            bucket_ts = (b + self.bucket_lo) * self.spec.time_bucket_s
        rest = rest // self.n_buckets
        codes: Dict[str, np.ndarray] = {}
        for name, r in zip(reversed(self.spec.group_by), reversed(self.radices)):
            codes[name] = (rest % r).astype(np.int32)
            rest = rest // r
        return {k: codes[k] for k in self.spec.group_by}, bucket_ts


def numeric_value_table(store, field: str) -> np.ndarray:
    """code -> int32 numeric value for a numeric-string field (e.g.
    bytes_out). Non-numeric strings map to 0 — the server-side 'decode'
    that lets the combiner sum real quantities, not dictionary codes."""
    d = store.dictionaries[field]
    table = np.zeros(max(len(d), 1), np.int32)
    for s, c in d._fwd.items():
        try:
            table[c] = int(float(s))
        except ValueError:
            pass
    return table


def resolve_grouping(store, spec: AggregateSpec, t_start: int, t_stop: int) -> ResolvedGrouping:
    fids = tuple(store.schema.field_id(f) for f in spec.group_by)
    radices = tuple(max(len(store.dictionaries[f]), 1) for f in spec.group_by)
    if spec.time_bucket_s is not None:
        bucket_lo = int(t_start) // spec.time_bucket_s
        n_buckets = int(t_stop) // spec.time_bucket_s - bucket_lo + 1
    else:
        bucket_lo, n_buckets = 0, 1
    value_fid = value_table = None
    if spec.value_field is not None:
        value_fid = store.schema.field_id(spec.value_field)
        value_table = numeric_value_table(store, spec.value_field)
    g = ResolvedGrouping(spec, fids, radices, n_buckets, bucket_lo, value_fid, value_table)
    if g.size > MAX_GROUP_SPACE:
        raise ValueError(
            f"group space too large ({g.size} > {MAX_GROUP_SPACE}); "
            "coarsen time_bucket_s or drop a group field"
        )
    return g


@dataclass
class AggregateBlock:
    """Per-(batch, tablet-set) partial aggregates — what the server ships
    instead of raw rows. gids are ResolvedGrouping-packed group ids."""

    shard: int  # -1: combined across shards in one dispatch
    gids: np.ndarray  # int64 [n]
    values: np.ndarray  # int64 [n] aggregate per group (overflow-safe sums)
    counts: np.ndarray  # int32 [n] matching rows per group

    @property
    def n(self) -> int:
        return int(self.gids.shape[0])

    @property
    def matched(self) -> int:
        """Rows that survived the filter (drives the adaptive batcher)."""
        return int(self.counts.sum())

    @property
    def nbytes(self) -> int:
        return self.gids.nbytes + self.values.nbytes + self.counts.nbytes


@dataclass
class AggregateResult:
    """Client-side merge of AggregateBlocks (tiny: one row per group)."""

    grouping: ResolvedGrouping
    gids: np.ndarray
    values: np.ndarray
    counts: np.ndarray

    @property
    def n_groups(self) -> int:
        return int(self.gids.shape[0])

    def total_matched(self) -> int:
        return int(self.counts.sum())

    def rows(self, store) -> List[dict]:
        """Decoded result rows: {field: str, ..., 'bucket_ts': int|None,
        'value': int, 'count': int}."""
        codes, bucket_ts = self.grouping.unpack(self.gids)
        out = []
        for i in range(self.n_groups):
            row = {
                name: store.dictionaries[name].decode(arr[i])
                for name, arr in codes.items()
            }
            row["bucket_ts"] = None if bucket_ts is None else int(bucket_ts[i])
            row["value"] = int(self.values[i])
            row["count"] = int(self.counts[i])
            out.append(row)
        return out


def merge_aggregate_blocks(
    grouping: ResolvedGrouping, blocks: Iterable[AggregateBlock]
) -> AggregateResult:
    """Combine partial aggregates across batches/shards — the client-side
    epilogue of a scan-time aggregation (cheap: group cardinality, not row
    cardinality)."""
    blocks = [b for b in blocks if b.n]
    if not blocks:
        e = np.empty(0, np.int64)
        return AggregateResult(grouping, e, e.copy(), np.empty(0, np.int32))
    gids = np.concatenate([b.gids for b in blocks])
    vals = np.concatenate([b.values for b in blocks])
    cnts = np.concatenate([b.counts for b in blocks])
    order = np.argsort(gids, kind="stable")
    gids, vals, cnts = gids[order], vals[order], cnts[order]
    heads = np.concatenate([[True], gids[1:] != gids[:-1]])
    starts = np.flatnonzero(heads)
    op = grouping.spec.op
    if op in ("count", "sum"):
        # int64 stays int64: the merged totals are the accumulators the
        # combiner exists to keep overflow-safe.
        mvals = np.add.reduceat(vals.astype(np.int64), starts)
    elif op == "min":
        mvals = np.minimum.reduceat(vals, starts)
    else:
        mvals = np.maximum.reduceat(vals, starts)
    mcnts = np.add.reduceat(cnts.astype(np.int64), starts).astype(np.int32)
    return AggregateResult(grouping, gids[starts], mvals, mcnts)


# ---------------------------------------------------------------- iterators
class ScanIterator:
    """One stage of the server-side stack: RowBlock -> RowBlock (or, for
    the terminal combiner, RowBlock -> AggregateBlock). Returning None
    drops the block."""

    def apply(self, block: RowBlock):
        raise NotImplementedError


class VersioningIterator(ScanIterator):
    """Accumulo's default iterator: keep the newest max_versions entries
    per key. Runs are sorted by packed key; duplicate keys are adjacent and
    ordered newest-first (rev_ts key layout), so 'newest wins' = 'first
    occurrences win'."""

    def __init__(self, max_versions: int = 1):
        if max_versions < 1:
            raise ValueError("max_versions >= 1")
        self.max_versions = max_versions

    def apply(self, block: RowBlock) -> RowBlock:
        keys = block.keys
        n = len(keys)
        if n == 0:
            return block
        head = np.concatenate([[True], keys[1:] != keys[:-1]])
        run_start = np.maximum.accumulate(np.where(head, np.arange(n), 0))
        occurrence = np.arange(n) - run_start
        keep = occurrence < self.max_versions
        if keep.all():
            return block
        return RowBlock(block.shard, keys[keep], block.cols[keep], block.field_ids)


class FilterIterator(ScanIterator):
    """The paper's §III-B filter, refactored as one stack stage: a
    compiled predicate program evaluated by the filter_scan kernel."""

    def __init__(self, store, tree: Optional[Node] = None, prog: Optional[FilterProgram] = None,
                 backend: str = "auto"):
        self.prog = prog if prog is not None else compile_tree(store, tree)
        self.backend = backend

    def apply(self, block: RowBlock) -> Optional[RowBlock]:
        if block.n == 0:
            return block
        mask = filter_scan(block.cols, self.prog, backend=self.backend)
        if mask.all():
            return block
        if not mask.any():
            return None
        return RowBlock(block.shard, block.keys[mask], block.cols[mask], block.field_ids)


class ProjectingIterator(ScanIterator):
    """Column-subset projection at scan time — the paper's 'optional column
    projection', server-side: unrequested columns never leave the tablet."""

    def __init__(self, store, fields: Sequence[str]):
        self.field_ids = np.asarray([store.schema.field_id(f) for f in fields], np.int32)
        self.fields = tuple(fields)

    def apply(self, block: RowBlock) -> RowBlock:
        if block.field_ids is not None:
            raise ValueError("block already projected")
        return RowBlock(
            block.shard, block.keys, block.cols[:, self.field_ids], self.field_ids
        )


class CombinerIterator(ScanIterator):
    """Scan-time aggregation (Accumulo combiner at scan scope): group rows
    by (group field codes, time bucket) and aggregate server-side. Fuses an
    optional residual filter program into the same kernel dispatch
    (kernels/combine_scan), so filter + combine is one VMEM pass."""

    def __init__(self, grouping: ResolvedGrouping, prog: Optional[FilterProgram] = None,
                 backend: str = "auto"):
        self.grouping = grouping
        self.prog = prog  # fused residual filter; None = match all
        self.backend = backend

    def combine_rows(self, keys: np.ndarray, cols: np.ndarray, shard: int = -1) -> AggregateBlock:
        if len(keys) == 0:
            e = np.empty(0, np.int64)
            return AggregateBlock(shard, e, e.copy(), np.empty(0, np.int32))
        _, rts, _ = keypack.unpack_event_key(keys)
        ts = keypack.unrev_ts(rts)
        gids = self.grouping.group_ids(ts, cols)
        order = np.argsort(gids, kind="stable")
        values = self.grouping.values(cols)
        ukeys, aggs, cnts = combine_scan(
            gids[order],
            None if values is None else values[order],
            cols[order],
            self.prog,
            op=self.grouping.spec.op,
            backend=self.backend,
        )
        return AggregateBlock(shard, ukeys, aggs, cnts)

    def apply(self, block: RowBlock) -> AggregateBlock:
        if block.field_ids is not None:
            raise ValueError("combiner needs unprojected schema-wide columns")
        return self.combine_rows(block.keys, block.cols, shard=block.shard)


class IteratorStack:
    """An ordered server-side iterator chain applied to every scanned
    block. Validates Accumulo-style composition rules at construction."""

    def __init__(self, iterators: Sequence[ScanIterator]):
        its = list(iterators)
        for i, it in enumerate(its):
            if isinstance(it, CombinerIterator) and i != len(its) - 1:
                raise ValueError("CombinerIterator must be the last iterator")
            if isinstance(it, ProjectingIterator):
                if any(isinstance(j, (FilterIterator, CombinerIterator)) for j in its[i + 1 :]):
                    raise ValueError(
                        "ProjectingIterator must come after filters and "
                        "cannot precede a combiner"
                    )
        self.iterators = its

    @property
    def terminal_combiner(self) -> Optional[CombinerIterator]:
        if self.iterators and isinstance(self.iterators[-1], CombinerIterator):
            return self.iterators[-1]
        return None

    def apply_block(self, block: RowBlock):
        out = block
        for it in self.iterators:
            out = it.apply(out)
            if out is None or out.n == 0:
                return None
        return out
