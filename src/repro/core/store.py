"""EventStore — the 'Accumulo instance': three tables per data source
(paper §II, Fig 1), range-partitioned into tablets.

  event table   key = shard|rev_ts|hash            cols = field codes
  index table   key = field|value|rev_ts           cols = event key (2 lanes)
  aggregate     key = field|value|time_bucket      cols = count

Sharding (paper): every entry gets a uniform-random shard prefix so ingest
has no hotspots; the guidance "N should be at least as large as half the
number of parallel client processes" is enforced as a config check in the
ingest layer.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import keypack
from .schema import EventSchema, FieldDictionary
from .tables import AggregateTablet, Tablet

DEFAULT_AGG_BUCKET_SECONDS = 3600  # paper: counts "by time interval"


def split_key64(key: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """int64 -> (hi, lo) int32 lanes. TPU-native carry format: Pallas
    kernels and the index table payload never touch 64-bit lanes."""
    key = np.asarray(key, dtype=np.int64)
    hi = (key >> 32).astype(np.int32)
    lo = (key & 0xFFFFFFFF).astype(np.uint32).astype(np.int64)
    lo = np.where(lo >= (1 << 31), lo - (1 << 32), lo).astype(np.int32)
    return hi, lo


def join_key64(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    hi = np.asarray(hi).astype(np.int64)
    lo = np.asarray(lo).astype(np.int64) & 0xFFFFFFFF
    return (hi << 32) | lo


class EventStore:
    """One data source's three tables, sharded n_shards ways."""

    def __init__(
        self,
        schema: EventSchema,
        n_shards: int = 8,
        flush_rows: int = 32768,
        max_runs: int = 8,
        agg_bucket_seconds: int = DEFAULT_AGG_BUCKET_SECONDS,
        seed: int = 0,
    ):
        if n_shards > keypack.MAX_SHARDS:
            raise ValueError(f"n_shards > {keypack.MAX_SHARDS}")
        self.schema = schema
        self.n_shards = n_shards
        self.agg_bucket_seconds = agg_bucket_seconds
        self.dictionaries: Dict[str, FieldDictionary] = {
            f.name: FieldDictionary(f.name) for f in schema.fields
        }
        self.event_tablets: List[Tablet] = [
            Tablet(s, width=schema.n_fields, flush_rows=flush_rows, max_runs=max_runs)
            for s in range(n_shards)
        ]
        self.index_tablets: List[Tablet] = [
            Tablet(s, width=2, flush_rows=flush_rows, max_runs=max_runs)
            for s in range(n_shards)
        ]
        # Aggregate table: single tablet; ingest workers pre-sum locally
        # (paper §II) so its write volume is tiny relative to event/index.
        self.agg_tablet = AggregateTablet(0, flush_rows=flush_rows, max_runs=max_runs)
        self._indexed_field_ids = np.asarray(
            [schema.field_id(f.name) for f in schema.fields if f.indexed],
            dtype=np.int64,
        )
        self._rng_lock = threading.Lock()
        self._rng = np.random.default_rng(seed)
        self.total_rows = 0
        self._rows_lock = threading.Lock()
        self._nonce = 0  # per-row nonce mixed into the short hash
        self.ts_min: Optional[int] = None
        self.ts_max: Optional[int] = None

    # ------------------------------------------------------------- encode
    def encode_events(
        self, ts: np.ndarray, values: Dict[str, Sequence[str]]
    ) -> np.ndarray:
        """values[field] -> list[str] per event; returns (n, n_fields) int32
        codes. Missing fields encode as the empty string."""
        n = len(ts)
        cols = np.zeros((n, self.schema.n_fields), dtype=np.int32)
        for name in self.schema.field_names():
            fid = self.schema.field_id(name)
            vals = values.get(name)
            if vals is None:
                cols[:, fid] = self.dictionaries[name].encode("")
            else:
                cols[:, fid] = self.dictionaries[name].encode_many(vals)
        return cols

    # ------------------------------------------------------------- ingest
    def ingest_encoded(self, ts: np.ndarray, cols: np.ndarray) -> float:
        """Insert pre-encoded events. Returns seconds blocked on compaction
        (backpressure). This is the server-side half of a BatchWriter
        flush."""
        n = len(ts)
        if n == 0:
            return 0.0
        ts = np.asarray(ts, dtype=np.int64)
        if np.any(ts < 0) or np.any(ts > keypack.TS_MAX):
            raise ValueError("timestamp out of 30-bit store range")
        with self._rng_lock:
            shards = keypack.assign_shards(n, self.n_shards, self._rng)
            nonce = np.arange(self._nonce, self._nonce + n, dtype=np.int64)
            self._nonce += n
        rts = keypack.rev_ts(ts)
        # The paper's "short hash to prevent collisions": mixed over content
        # AND a per-row nonce so identical events in the same second still
        # get distinct row keys. Residual 16-bit birthday collisions follow
        # Accumulo's last-write-wins (VersioningIterator) semantics.
        h = keypack.short_hash(*(cols[:, j] for j in range(cols.shape[1])), ts, nonce)
        ekeys = keypack.pack_event_key(shards, rts, h)

        blocked = 0.0
        for s in np.unique(shards):
            m = shards == s
            blocked += self.event_tablets[int(s)].insert(ekeys[m], cols[m])
            # Index entries: one per (indexed field, event).
            n_m = int(m.sum())
            if n_m and len(self._indexed_field_ids):
                fids = np.repeat(self._indexed_field_ids, n_m)
                vcodes = cols[m][:, self._indexed_field_ids].T.reshape(-1).astype(np.int64)
                ikeys = keypack.pack_index_key(fids, vcodes, np.tile(rts[m], len(self._indexed_field_ids)))
                hi, lo = split_key64(np.tile(ekeys[m], len(self._indexed_field_ids)))
                blocked += self.index_tablets[int(s)].insert(
                    ikeys, np.stack([hi, lo], axis=1)
                )
        # Aggregate: pre-sum locally (client-side combine), then insert.
        buckets = ts // self.agg_bucket_seconds
        akeys_all = []
        for fid in self._indexed_field_ids:
            akeys_all.append(
                keypack.pack_agg_key(fid, cols[:, fid].astype(np.int64), buckets)
            )
        if akeys_all:
            akeys = np.concatenate(akeys_all)
            ukeys, counts = np.unique(akeys, return_counts=True)
            blocked += self.agg_tablet.insert(
                ukeys, counts.astype(np.int64)[:, None]
            )
        with self._rows_lock:
            self.total_rows += n
            lo, hi = int(ts.min()), int(ts.max())
            self.ts_min = lo if self.ts_min is None else min(self.ts_min, lo)
            self.ts_max = hi if self.ts_max is None else max(self.ts_max, hi)
        return blocked

    def rows_per_second(self) -> float:
        """Mean event density — seeds the adaptive batcher's b0 (paper:
        'b0 pre-computed for the particular table based on typical
        hit-rates of previous queries')."""
        if not self.total_rows or self.ts_min is None:
            return 1.0
        return self.total_rows / max(self.ts_max - self.ts_min, 1)

    def ingest(self, ts: np.ndarray, values: Dict[str, Sequence[str]]) -> float:
        return self.ingest_encoded(np.asarray(ts), self.encode_events(ts, values))

    # -------------------------------------------------------------- reads
    def agg_count(self, field: str, value: str, t_start: int, t_stop: int) -> int:
        """Selectivity estimation input (paper §III-B): occurrences of
        field=value in the bucketed time range, from the aggregate table."""
        code = self.dictionaries[field].lookup(value)
        if code is None:
            return 0
        fid = self.schema.field_id(field)
        b0 = int(t_start) // self.agg_bucket_seconds
        b1 = int(t_stop) // self.agg_bucket_seconds
        lo = keypack.pack_agg_key(fid, code, b0)
        hi = keypack.pack_agg_key(fid, code, b1) + 1
        return self.agg_tablet.count_range(int(lo), int(hi))

    def flush_all(self) -> None:
        for t in self.event_tablets + self.index_tablets + [self.agg_tablet]:
            t.flush()

    def compact_all(self) -> None:
        for t in self.event_tablets + self.index_tablets + [self.agg_tablet]:
            t.compact()

    # ---------------------------------------------------------- telemetry
    def backpressure_stats(self) -> Dict[str, float]:
        evs = self.event_tablets
        return {
            "rows": self.total_rows,
            "minor_compactions": sum(t.minor_compactions for t in evs),
            "major_compactions": sum(t.major_compactions for t in evs),
            "blocked_seconds": sum(t.blocked_seconds for t in evs),
        }
