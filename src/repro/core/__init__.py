"""The paper's primary contribution: the LLCySA Accumulo pipeline —
sharded key-value event store (3 tables/source), parallel ingest with
backpressure, adaptive query batching (Algs 1-2), and the density-heuristic
query planner. See DESIGN.md for the TPU adaptation table."""
from . import batching, filter, iterators, keypack, planner, query, scan, schema, store, tables  # noqa: F401
from .batching import AdaptiveBatcher, run_batched_query  # noqa: F401
from .filter import And, Cmp, Eq, In, Match, Node, Not, Or, TrueNode  # noqa: F401
from .iterators import (  # noqa: F401
    AggregateBlock,
    AggregateResult,
    AggregateSpec,
    CombinerIterator,
    FilterIterator,
    IteratorStack,
    ProjectingIterator,
    ScanIterator,
    VersioningIterator,
    merge_aggregate_blocks,
    resolve_grouping,
)
from .planner import QueryPlan, plan_query  # noqa: F401
from .query import QueryProcessor, QueryStats  # noqa: F401
from .schema import EventSchema, FieldSpec, web_proxy_schema  # noqa: F401
from .store import EventStore  # noqa: F401
