"""Packed fixed-width binary row keys — the TPU adaptation of Accumulo's
lexicographic string keys (paper §II, Fig 1).

Accumulo sorts variable-length byte-string keys on JVM tablet servers. A TPU
data plane wants fixed-width integer keys so that "range scan = contiguous
slice of a sorted vector" survives as a vectorized searchsorted. We pack the
paper's three key schemes into int64:

  event key   :  shard(7b) | rev_ts(30b) | hash(16b)            = 53 bits
  index key   :  field(10b) | value(22b) | rev_ts(30b)          = 62 bits
                 (shard implicit: index entries co-live with their tablet,
                  the event key is carried in a sibling column — the paper's
                  "row ID stored in the index table's column qualifier")
  agg key     :  field(10b) | value(22b) | bucket(30b)          = 62 bits

rev_ts = TS_MAX - ts gives the paper's "reversed timestamp to provide
first-class support for filtering entries by time range" — most recent
entries sort first within a shard. The 16-bit hash is the paper's "short
hash to prevent collisions".

On TPU, int64 lowers to 2x32-bit lanes; the Pallas kernels therefore operate
on the unpacked int32 lanes / dictionary codes, never on the packed key.
"""
from __future__ import annotations

import numpy as np

SHARD_BITS = 7
TS_BITS = 30
HASH_BITS = 16
FIELD_BITS = 10
VALUE_BITS = 22
BUCKET_BITS = 30

MAX_SHARDS = 1 << SHARD_BITS
TS_MAX = (1 << TS_BITS) - 1
HASH_MAX = (1 << HASH_BITS) - 1
MAX_FIELDS = 1 << FIELD_BITS
MAX_VALUES = 1 << VALUE_BITS
BUCKET_MAX = (1 << BUCKET_BITS) - 1

# Epoch offset so that 30-bit second timestamps cover 2000-01-01 .. ~2034.
EPOCH_OFFSET = 946684800  # 2000-01-01T00:00:00Z

_EV_SHARD_SHIFT = TS_BITS + HASH_BITS
_EV_TS_SHIFT = HASH_BITS
_IX_FIELD_SHIFT = VALUE_BITS + TS_BITS
_IX_VALUE_SHIFT = TS_BITS
_AG_FIELD_SHIFT = VALUE_BITS + BUCKET_BITS
_AG_VALUE_SHIFT = BUCKET_BITS


def rev_ts(ts):
    """Reversed timestamp: newest-first sort order within a shard."""
    return TS_MAX - ts


def unrev_ts(rts):
    return TS_MAX - rts


def pack_event_key(shard, rts, h):
    """shard | rev_ts | hash -> int64. Accepts scalars or numpy arrays."""
    shard = np.asarray(shard, dtype=np.int64)
    rts = np.asarray(rts, dtype=np.int64)
    h = np.asarray(h, dtype=np.int64)
    return (shard << _EV_SHARD_SHIFT) | (rts << _EV_TS_SHIFT) | h


def unpack_event_key(key):
    key = np.asarray(key, dtype=np.int64)
    shard = key >> _EV_SHARD_SHIFT
    rts = (key >> _EV_TS_SHIFT) & TS_MAX
    h = key & HASH_MAX
    return shard, rts, h


def event_key_range(shard, t_start, t_stop):
    """[lo, hi) of packed event keys for events with ts in [t_start, t_stop],
    within one shard. Because timestamps are reversed, t_stop maps to the low
    end of the key range."""
    rts_lo = rev_ts(t_stop)
    rts_hi = rev_ts(t_start)
    lo = pack_event_key(shard, rts_lo, 0)
    hi = pack_event_key(shard, rts_hi, HASH_MAX) + 1
    return lo, hi


def pack_index_key(field, value, rts):
    field = np.asarray(field, dtype=np.int64)
    value = np.asarray(value, dtype=np.int64)
    rts = np.asarray(rts, dtype=np.int64)
    return (field << _IX_FIELD_SHIFT) | (value << _IX_VALUE_SHIFT) | rts


def unpack_index_key(key):
    key = np.asarray(key, dtype=np.int64)
    field = key >> _IX_FIELD_SHIFT
    value = (key >> _IX_VALUE_SHIFT) & (MAX_VALUES - 1)
    rts = key & TS_MAX
    return field, value, rts


def index_key_range(field, value, t_start, t_stop):
    """[lo, hi) of packed index keys for one (field, value) over a time
    range."""
    lo = pack_index_key(field, value, rev_ts(t_stop))
    hi = pack_index_key(field, value, rev_ts(t_start)) + 1
    return lo, hi


def pack_agg_key(field, value, bucket):
    field = np.asarray(field, dtype=np.int64)
    value = np.asarray(value, dtype=np.int64)
    bucket = np.asarray(bucket, dtype=np.int64)
    return (field << _AG_FIELD_SHIFT) | (value << _AG_VALUE_SHIFT) | bucket


def unpack_agg_key(key):
    key = np.asarray(key, dtype=np.int64)
    field = key >> _AG_FIELD_SHIFT
    value = (key >> _AG_VALUE_SHIFT) & (MAX_VALUES - 1)
    bucket = key & BUCKET_MAX
    return field, value, bucket


def short_hash(*cols):
    """Deterministic 16-bit mixing hash over int arrays (fnv-ish). The paper
    appends a short hash purely to avoid key collisions between events with
    identical (shard, timestamp)."""
    acc = np.uint64(0xCBF29CE484222325)
    for c in cols:
        c = np.asarray(c).astype(np.uint64)
        acc = (acc ^ c) * np.uint64(0x100000001B3)
        acc ^= acc >> np.uint64(29)
    return (acc & np.uint64(HASH_MAX)).astype(np.int64)


def assign_shards(n, n_shards, rng):
    """The paper's sharding: 'prepending the row ID with a random zero-padded
    shard number between 0 and N-1' — uniform random shard per entry."""
    return rng.integers(0, n_shards, size=n, dtype=np.int64)
