"""Event schema + per-field string dictionaries.

Accumulo is schemaless: entries are (row, colq) -> value byte strings. The
paper's events are parsed log lines — "a set of fields and values" (§II) —
with dozens of string-typed attributes. A TPU data plane cannot compare
variable-length strings, so each field gets a host-side dictionary mapping
string -> int32 code (codes are dense, per-field). The device-side event
table is columnar: one int32 code vector per field. This is the standard
dictionary-encoding move (Parquet/Arrow) applied to the D4M schema.

The dictionary is also how the paper's index table works here: an index
entry's packed key embeds (field_id, value_code), and equality conditions
resolve strings -> codes before touching the device.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field as dc_field
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import keypack


class FieldDictionary:
    """Bidirectional str <-> int32 code map for one field. Thread-safe:
    parallel ingest workers (paper §II: 'multiple ingest worker processes')
    encode concurrently."""

    def __init__(self, name: str):
        self.name = name
        self._fwd: Dict[str, int] = {}
        self._rev: List[str] = []
        self._lock = threading.Lock()

    def encode(self, value: str) -> int:
        code = self._fwd.get(value)
        if code is not None:
            return code
        with self._lock:
            code = self._fwd.get(value)
            if code is None:
                code = len(self._rev)
                if code >= keypack.MAX_VALUES:
                    raise ValueError(
                        f"field {self.name!r}: dictionary overflow "
                        f"(> {keypack.MAX_VALUES} distinct values)"
                    )
                self._fwd[value] = code
                self._rev.append(value)
            return code

    def encode_many(self, values: Sequence[str]) -> np.ndarray:
        return np.fromiter(
            (self.encode(v) for v in values), dtype=np.int32, count=len(values)
        )

    def lookup(self, value: str) -> Optional[int]:
        """Code for a value if it has ever been ingested, else None (a query
        for a never-seen value matches nothing)."""
        return self._fwd.get(value)

    def decode(self, code: int) -> str:
        return self._rev[int(code)]

    def decode_many(self, codes) -> List[str]:
        return [self._rev[int(c)] for c in codes]

    def prefix_codes(self, prefix: str) -> np.ndarray:
        """All codes whose string value starts with `prefix` — host-side
        resolution of the paper's regex/match conditions (see DESIGN.md:
        TPUs have no string unit; pattern conditions resolve to code sets)."""
        return np.asarray(
            [c for s, c in self._fwd.items() if s.startswith(prefix)],
            dtype=np.int32,
        )

    def __len__(self):
        return len(self._rev)


@dataclass(frozen=True)
class FieldSpec:
    name: str
    indexed: bool = True  # paper: equality conditions on indexed fields use the index table


@dataclass
class EventSchema:
    """One data source ('event type' in LLCySA — web proxy, DHCP, ...)."""

    source: str
    fields: List[FieldSpec]
    _field_ids: Dict[str, int] = dc_field(default_factory=dict)

    def __post_init__(self):
        if len(self.fields) >= keypack.MAX_FIELDS:
            raise ValueError("too many fields")
        self._field_ids = {f.name: i for i, f in enumerate(self.fields)}

    def field_id(self, name: str) -> int:
        return self._field_ids[name]

    def field_names(self) -> List[str]:
        return [f.name for f in self.fields]

    def is_indexed(self, name: str) -> bool:
        return self.fields[self._field_ids[name]].indexed

    @property
    def n_fields(self) -> int:
        return len(self.fields)


def web_proxy_schema() -> EventSchema:
    """The paper's experimental data source (§IV): web proxy logs — 'each
    event occurrence represents a single HTTP request and has dozens of
    attributes'. We model the prominent ones."""
    names = [
        "src_ip",
        "dst_ip",
        "domain",
        "url_path",
        "method",
        "status",
        "user_agent",
        "content_type",
        "bytes_out",
        "bytes_in",
        "referer",
        "scheme",
    ]
    return EventSchema("web_proxy", [FieldSpec(n) for n in names])
