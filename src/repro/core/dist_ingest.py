"""Distributed ingest plane — writable device-resident LSM tablets.

The paper's headline experiment (§IV-A, Figs 3-4) is ingest scalability vs
client processes x tablet servers; until this module the mesh data plane
was read-only (dist_query scattered a finished host store post hoc). Here
every mesh device hosts `tablets_per_device` *writable* tablet servers,
and the full LSM lifecycle of core/tables.py runs as jitted shard_map
programs over device-resident state:

    append   DistBatchWriter shards encoded events by row hash; each
             tablet picks its rows out of the replicated batch and
             scatter-appends them into its memtable slab
    minor    per-tablet memtable sort into the next sorted-run slot
    major    k-way merge of runs + base via the merge_runs rank kernel
             (kernels/merge_runs) into a single sorted base run —
             BLOCKING the writer that tripped it, which is the paper's
             backpressure, reproduced on the mesh

Per-tablet device counters (rows, minor/major compactions, overflow)
record the blocked-writer dynamics; host wall-clock blocked-seconds
accrue to each writer's IngestMetrics exactly as in the host path.

publish() folds everything into the base run and returns a DistStore
view of it — the incremental-update path: freshly ingested rows become
visible to DistQueryProcessor without a host round trip or re-scatter
(the compactions are device programs; no row ever returns to the host).

Host-side flush triggers are exact with zero device syncs: tablet
assignments are computed host-side, so a bincount per chunk mirrors the
device memtable fills and run-slot counts precisely — compactions fire
only when some tablet is actually full.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import keypack
from .dist_query import DistStore
from .ingest import BatchWriter, IngestMetrics, check_shard_guidance

REV_PAD = np.iinfo(np.int32).max  # +inf rev_ts sentinel (matches DistStore)


def _n_devices(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def _linear_device_index(mesh: Mesh):
    """Row-major device index over the mesh axes — the shard_map slab of a
    P(axes, ...)-sharded array on this device covers tablets
    [idx * tablets_per_device, (idx + 1) * tablets_per_device)."""
    idx = jnp.int32(0)
    for a in mesh.axis_names:
        idx = idx * jnp.int32(mesh.shape[a]) + lax.axis_index(a)
    return idx


class DistIngestPlane:
    """Device-resident LSM tablet grid + its jitted ingest/compaction
    programs. T = n_devices * tablets_per_device tablets, each with a
    memtable slab (mem_rows), max_runs sorted-run slots (mem_rows each)
    and a base run (capacity rows)."""

    def __init__(
        self,
        mesh: Mesh,
        n_fields: int,
        capacity: int,
        tablets_per_device: int = 1,
        mem_rows: int = 4096,
        max_runs: int = 4,
        append_rows: int = 1024,
    ):
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.n_fields = int(n_fields)
        self.tablets_per_device = int(tablets_per_device)
        self.n_tablets = _n_devices(mesh) * self.tablets_per_device
        self.capacity = int(capacity)
        self.mem_rows = int(mem_rows)
        self.max_runs = int(max_runs)
        self.append_rows = int(min(append_rows, mem_rows))
        self._steps: Dict[str, object] = {}
        # Exact host-side mirrors of the device memtable fills and run-slot
        # counts (see module docstring) — updated in lockstep with the
        # device programs' own guards, never read back from the device.
        self._fill = np.zeros(self.n_tablets, np.int64)
        self._runs_host = np.zeros(self.n_tablets, np.int32)
        self._dirty = True
        self._published: Optional[DistStore] = None
        self.blocked_seconds = 0.0  # aggregate; per-writer in IngestMetrics
        # Concurrent DistBatchWriters (paper: many parallel ingest clients)
        # share one plane: the lock serializes state/counter updates, like
        # the host Tablet's lock. Writers blocked here while another's
        # flush compacts is exactly the paper's backpressure coupling.
        self._lock = threading.Lock()
        self.state = self._init_state()

    # ----------------------------------------------------------- state
    def _specs(self) -> Dict[str, P]:
        ax = self.axes
        return {
            "mem_rts": P(ax, None),
            "mem_cols": P(ax, None, None),
            "mem_n": P(ax),
            "run_rts": P(ax, None, None),
            "run_cols": P(ax, None, None, None),
            "run_n": P(ax, None),
            "n_runs": P(ax),
            "base_rts": P(ax, None),
            "base_cols": P(ax, None, None),
            "base_n": P(ax),
            "rows": P(ax),
            "minor": P(ax),
            "major": P(ax),
            "overflow": P(ax),
        }

    def _init_state(self) -> Dict[str, jax.Array]:
        t, m, k, c, f = (
            self.n_tablets, self.mem_rows, self.max_runs, self.capacity, self.n_fields,
        )
        host = {
            "mem_rts": np.zeros((t, m), np.int32),
            "mem_cols": np.zeros((t, m, f), np.int32),
            "mem_n": np.zeros((t,), np.int32),
            "run_rts": np.full((t, k, m), REV_PAD, np.int32),
            "run_cols": np.zeros((t, k, m, f), np.int32),
            "run_n": np.zeros((t, k), np.int32),
            "n_runs": np.zeros((t,), np.int32),
            "base_rts": np.full((t, c), REV_PAD, np.int32),
            "base_cols": np.zeros((t, c, f), np.int32),
            "base_n": np.zeros((t,), np.int32),
            "rows": np.zeros((t,), np.int64),
            "minor": np.zeros((t,), np.int32),
            "major": np.zeros((t,), np.int32),
            "overflow": np.zeros((t,), np.int32),
        }
        specs = self._specs()
        return {
            name: jax.device_put(arr, NamedSharding(self.mesh, specs[name]))
            for name, arr in host.items()
        }

    # ------------------------------------------------------ step builders
    def _append_step(self):
        if "append" in self._steps:
            return self._steps["append"]
        mesh, tl = self.mesh, self.tablets_per_device
        specs = self._specs()

        def device_fn(mem_rts, mem_cols, mem_n, rows, overflow, b_rts, b_cols, b_tab):
            dev = _linear_device_index(mesh)

            def one(i, rts_l, cols_l, n):
                gid = dev * jnp.int32(tl) + i
                mine = b_tab == gid
                m = rts_l.shape[0]
                # Scatter-append: row dest = running fill; non-mine and
                # overflow rows map out of bounds and drop.
                dest = jnp.where(
                    mine, n + jnp.cumsum(mine.astype(jnp.int32)) - 1, jnp.int32(m)
                )
                rts_l = rts_l.at[dest].set(b_rts, mode="drop")
                cols_l = cols_l.at[dest].set(b_cols, mode="drop")
                want = n + mine.sum(dtype=jnp.int32)
                new_n = jnp.minimum(want, jnp.int32(m))
                return rts_l, cols_l, new_n, new_n - n, want - new_n

            idx = jnp.arange(tl, dtype=jnp.int32)
            new_rts, new_cols, new_n, appended, lost = jax.vmap(
                one, in_axes=(0, 0, 0, 0)
            )(idx, mem_rts, mem_cols, mem_n)
            return (
                new_rts, new_cols, new_n,
                rows + appended.astype(rows.dtype),
                overflow + lost,
            )

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(
                specs["mem_rts"], specs["mem_cols"], specs["mem_n"],
                specs["rows"], specs["overflow"],
                P(None), P(None, None), P(None),  # batch: replicated
            ),
            out_specs=(
                specs["mem_rts"], specs["mem_cols"], specs["mem_n"],
                specs["rows"], specs["overflow"],
            ),
            check_rep=False,
        )
        self._steps["append"] = jax.jit(smapped, donate_argnums=(0, 1, 2, 3, 4))
        return self._steps["append"]

    def _minor_step(self):
        if "minor" in self._steps:
            return self._steps["minor"]
        mesh, k = self.mesh, self.max_runs
        specs = self._specs()

        def device_fn(mem_rts, mem_cols, mem_n, run_rts, run_cols, run_n, n_runs, minor):
            def one(rts_l, cols_l, n, rrts_l, rcols_l, rn_l, nr):
                m = rts_l.shape[0]
                valid = jnp.arange(m, dtype=jnp.int32) < n
                keys = jnp.where(valid, rts_l, jnp.int32(REV_PAD))
                order = jnp.argsort(keys)
                skeys = keys[order]
                scols = cols_l[order]
                do = (n > 0) & (nr < jnp.int32(k))
                slot = jnp.clip(nr, 0, k - 1)
                rrts_l = rrts_l.at[slot].set(jnp.where(do, skeys, rrts_l[slot]))
                rcols_l = rcols_l.at[slot].set(jnp.where(do, scols, rcols_l[slot]))
                rn_l = rn_l.at[slot].set(jnp.where(do, n, rn_l[slot]))
                return (
                    jnp.where(do, 0, n), rrts_l, rcols_l, rn_l,
                    nr + do.astype(nr.dtype), do.astype(jnp.int32),
                )

            new_n, nrr, nrc, nrn, nnr, did = jax.vmap(one)(
                mem_rts, mem_cols, mem_n, run_rts, run_cols, run_n, n_runs
            )
            return new_n, nrr, nrc, nrn, nnr, minor + did

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(
                specs["mem_rts"], specs["mem_cols"], specs["mem_n"],
                specs["run_rts"], specs["run_cols"], specs["run_n"],
                specs["n_runs"], specs["minor"],
            ),
            out_specs=(
                specs["mem_n"], specs["run_rts"], specs["run_cols"],
                specs["run_n"], specs["n_runs"], specs["minor"],
            ),
            check_rep=False,
        )
        self._steps["minor"] = jax.jit(smapped, donate_argnums=(3, 4, 5))
        return self._steps["minor"]

    def _major_step(self):
        if "major" in self._steps:
            return self._steps["major"]
        from ..kernels.merge_runs import merge_sorted_device

        mesh = self.mesh
        k, m, c, f = self.max_runs, self.mem_rows, self.capacity, self.n_fields
        specs = self._specs()
        # Two-stage merge: the K runs (m rows each) first, then the result
        # against the base — pad both sides of the 2-way merge to one
        # power-of-two length.
        l2 = 1
        while l2 < max(c, k * m):
            l2 *= 2

        def device_fn(run_rts, run_cols, run_n, n_runs, base_rts, base_cols, base_n, major, overflow):
            def one(rrts_l, rcols_l, rn_l, nr, brts_l, bcols_l, bn):
                # Mask stale slots/rows (run_n is authoritative; slots past
                # n_runs were zeroed at the previous major).
                within = jnp.arange(m, dtype=jnp.int32)[None, :] < rn_l[:, None]
                ck = jnp.where(within, rrts_l, jnp.int32(REV_PAD))
                cc = jnp.where(within[..., None], rcols_l, 0)
                mk, mc = merge_sorted_device(ck, cc)  # (k*m,), sentinel tail
                pad_a = jnp.full((l2,), REV_PAD, jnp.int32).at[:c].set(brts_l)
                pad_b = jnp.full((l2,), REV_PAD, jnp.int32).at[: k * m].set(mk)
                ca = jnp.zeros((l2, f), jnp.int32).at[:c].set(bcols_l)
                cb = jnp.zeros((l2, f), jnp.int32).at[: k * m].set(mc)
                fk, fc = merge_sorted_device(
                    jnp.stack([pad_a, pad_b]), jnp.stack([ca, cb])
                )
                do = nr > 0
                new_brts = jnp.where(do, fk[:c], brts_l)
                new_bcols = jnp.where(do, fc[:c], bcols_l)
                total = bn + rn_l.sum()
                new_bn = jnp.where(do, jnp.minimum(total, jnp.int32(c)), bn)
                lost = jnp.where(do, total - new_bn, 0)
                return (
                    jnp.where(do, jnp.zeros_like(rn_l), rn_l),
                    jnp.where(do, 0, nr),
                    new_brts, new_bcols, new_bn,
                    do.astype(jnp.int32), lost,
                )

            nrn, nnr, nbr, nbc, nbn, did, lost = jax.vmap(one)(
                run_rts, run_cols, run_n, n_runs, base_rts, base_cols, base_n
            )
            return nrn, nnr, nbr, nbc, nbn, major + did, overflow + lost

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(
                specs["run_rts"], specs["run_cols"], specs["run_n"], specs["n_runs"],
                specs["base_rts"], specs["base_cols"], specs["base_n"],
                specs["major"], specs["overflow"],
            ),
            out_specs=(
                specs["run_n"], specs["n_runs"],
                specs["base_rts"], specs["base_cols"], specs["base_n"],
                specs["major"], specs["overflow"],
            ),
            check_rep=False,
        )
        # The base buffers are deliberately NOT donated: publish() hands
        # out DistStore views of them, and on backends that implement
        # donation (TPU/GPU) a donated major would delete the arrays a
        # caller may still hold. Majors are rare; one base copy each is
        # the price of stable published views.
        self._steps["major"] = jax.jit(smapped, donate_argnums=(2, 3))
        return self._steps["major"]

    # ------------------------------------------------------------- ingest
    def _run_minor(self) -> None:
        s = self.state
        step = self._minor_step()
        s["mem_n"], s["run_rts"], s["run_cols"], s["run_n"], s["n_runs"], s["minor"] = step(
            s["mem_rts"], s["mem_cols"], s["mem_n"],
            s["run_rts"], s["run_cols"], s["run_n"], s["n_runs"], s["minor"],
        )
        # Mirror the device guard exactly: a tablet flushes iff it holds
        # rows AND has a free run slot.
        flushed = (self._fill > 0) & (self._runs_host < self.max_runs)
        self._runs_host += flushed
        self._fill = np.where(flushed, 0, self._fill)

    def _run_major(self) -> None:
        s = self.state
        step = self._major_step()
        (
            s["run_n"], s["n_runs"], s["base_rts"], s["base_cols"], s["base_n"],
            s["major"], s["overflow"],
        ) = step(
            s["run_rts"], s["run_cols"], s["run_n"], s["n_runs"],
            s["base_rts"], s["base_cols"], s["base_n"], s["major"], s["overflow"],
        )
        self._runs_host[:] = 0

    def ingest(self, rts: np.ndarray, cols: np.ndarray, tab: np.ndarray) -> float:
        """Append a pre-encoded, pre-sharded batch. rts int32 reversed
        timestamps; cols (n, F) int32 codes; tab (n,) int32 tablet ids.
        Returns seconds spent blocked on major compaction (backpressure) —
        the server-side half of a DistBatchWriter flush."""
        n = len(rts)
        if n == 0:
            return 0.0
        rts = np.asarray(rts, np.int32)
        cols = np.asarray(cols, np.int32)
        tab = np.asarray(tab, np.int32)
        append = self._append_step()
        with self._lock:
            return self._ingest_locked(append, rts, cols, tab, n)

    def _ingest_locked(self, append, rts, cols, tab, n: int) -> float:
        s = self.state
        blocked = 0.0
        b = self.append_rows
        for off in range(0, n, b):
            chunk = min(b, n - off)
            tab_chunk = tab[off : off + chunk]
            cb = np.bincount(tab_chunk, minlength=self.n_tablets)
            # Exact room check from the host-side fill mirror: flush only
            # the moment some tablet's memtable would actually overflow.
            if np.any(self._fill + cb > self.mem_rows):
                if np.any((self._fill > 0) & (self._runs_host >= self.max_runs)):
                    # No free run slot for a tablet that must flush: major
                    # compaction first — it BLOCKS the writer that tripped
                    # it, Accumulo's backpressure reproduced on the mesh.
                    t0 = time.perf_counter()
                    self._run_major()
                    jax.block_until_ready(self.state["base_n"])
                    dt = time.perf_counter() - t0
                    blocked += dt
                    self.blocked_seconds += dt
                self._run_minor()
            pad_rts = np.zeros((b,), np.int32)
            pad_cols = np.zeros((b, self.n_fields), np.int32)
            pad_tab = np.full((b,), -1, np.int32)  # -1: no tablet claims it
            pad_rts[:chunk] = rts[off : off + chunk]
            pad_cols[:chunk] = cols[off : off + chunk]
            pad_tab[:chunk] = tab_chunk
            s["mem_rts"], s["mem_cols"], s["mem_n"], s["rows"], s["overflow"] = append(
                s["mem_rts"], s["mem_cols"], s["mem_n"], s["rows"], s["overflow"],
                jnp.asarray(pad_rts), jnp.asarray(pad_cols), jnp.asarray(pad_tab),
            )
            self._fill += cb
        self._dirty = True
        return blocked

    # -------------------------------------------------------------- reads
    def publish(self) -> DistStore:
        """Fold memtables and runs into the base run (device-side merges
        only) and return the query-visible DistStore view. Cheap when
        nothing was ingested since the last publish."""
        with self._lock:
            if not self._dirty and self._published is not None:
                return self._published
            for _ in range(3):
                self._run_minor()
                self._run_major()
                if int(self._fill.max()) == 0:  # exact mirror: no device sync
                    break
            else:  # pragma: no cover — the invariant bounds this to 2 passes
                raise RuntimeError("publish did not drain the memtables")
            self._dirty = False
            self._published = DistStore(
                rev_ts=self.state["base_rts"],
                cols=self.state["base_cols"],
                counts=self.state["base_n"],
                mesh=self.mesh,
            )
            return self._published

    def telemetry(self) -> Dict[str, np.ndarray]:
        """Per-tablet device counters (the paper's backpressure signals)."""
        with self._lock:
            out = {
                name: np.asarray(jax.device_get(self.state[name]))
                for name in ("rows", "minor", "major", "overflow", "mem_n", "n_runs", "base_n")
            }
            out["blocked_seconds"] = np.float64(self.blocked_seconds)
            return out


class DistBatchWriter(BatchWriter):
    """Client-side ingest writer for the device plane (paper §II: one
    BatchWriter per parallel ingest client). Buffers parsed events exactly
    like the host BatchWriter; a flush encodes via the store's dictionaries,
    shards by row hash, and appends through the plane — blocking while a
    tripped major compaction drains, which is the measured backpressure."""

    def __init__(
        self,
        store,
        plane: DistIngestPlane,
        batch_rows: int = 4096,
        metrics: Optional[IngestMetrics] = None,
        writer_id: int = 0,
    ):
        super().__init__(store, batch_rows=batch_rows, metrics=metrics)
        self.plane = plane
        self._writer_id = np.int64(writer_id)
        self._count = 0

    def _write(self, ts: np.ndarray, values) -> float:
        ts = np.asarray(ts, dtype=np.int64)
        if np.any(ts < 0) or np.any(ts > keypack.TS_MAX):
            # Same contract as EventStore.ingest_encoded — out-of-range
            # timestamps must not silently wrap into negative rev_ts.
            raise ValueError("timestamp out of 30-bit store range")
        cols = self.store.encode_events(ts, values)
        n = len(ts)
        # Row hash decides the tablet: content + per-writer nonce, so
        # identical events still spread uniformly (the paper's random
        # sharding; shard id is implicit in tablet choice here).
        nonce = np.arange(self._count, self._count + n, dtype=np.int64)
        self._count += n
        h = keypack.short_hash(
            *(cols[:, j] for j in range(cols.shape[1])), ts, nonce, self._writer_id
        )
        tab = (h % self.plane.n_tablets).astype(np.int32)
        rts = keypack.rev_ts(np.asarray(ts, np.int64)).astype(np.int32)
        return self.plane.ingest(rts, cols, tab)


def check_tablet_guidance(n_tablets: int, n_writers: int) -> bool:
    """Paper sizing guidance, lifted to the mesh: tablet count at least
    half the parallel writer count (the shard-vs-client rule, one home)."""
    return check_shard_guidance(n_tablets, n_writers)
