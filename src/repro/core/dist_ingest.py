"""Distributed ingest plane — writable device-resident LSM tablets for
ALL THREE of the paper's tables.

The paper's headline experiment (§IV-A, Figs 3-4) is ingest scalability vs
client processes x tablet servers; until this module the mesh data plane
was read-only (dist_query scattered a finished host store post hoc). Here
every mesh device hosts `tablets_per_device` *writable* tablet servers,
and the full LSM lifecycle of core/tables.py runs as jitted shard_map
programs over device-resident state:

    append   DistBatchWriter shards encoded events by row hash; each
             tablet picks its rows out of the replicated batch and
             scatter-appends them into its memtable slab
    minor    per-tablet memtable sort into the next sorted-run slot
    major    k-way merge of runs + base via the merge_runs rank kernel
             (kernels/merge_runs, Pallas on TPU / jnp reference on CPU) —
             BLOCKING the writer that tripped it, which is the paper's
             backpressure, reproduced on the mesh

Each tablet owns three table FAMILIES, the paper's per-source schema
(§II, Fig 1) maintained in lockstep through the same programs:

    ev   event table      key = rev_ts (int32), payload = field codes
    ix   index table      key = field|value|rev_ts packed int64 — the
                          D4M-style transpose table; postings for one
                          (field, value) are a contiguous sorted rev_ts
                          range, which is what the distributed index
                          query path binary-searches
    ag   aggregate table  key = field|value|time_bucket packed int64,
                          payload = count (int64) — duplicate keys are
                          summed at major compaction (Accumulo's
                          combiner-on-compaction); the query planner
                          reads densities from it with a psum

Index and aggregate entries are SYNTHESIZED ON DEVICE inside the append
program from the event rows themselves (writers ship only events):
index maintenance rides the ingest path, never a post-hoc build — the
index is live at publish() with no rebuild, per the 100M-inserts/sec
study's design (arXiv:1406.4923).

PLANE SHARDING (per-tablet-group ownership): the plane is decomposed
into ``n_groups`` independent :class:`TabletGroup` shards. Each group
owns a CONTIGUOUS range of ``n_tablets / n_groups`` global tablets with
its OWN OwnedLock, device state, host fill/run mirrors, generation
tags, and fold-debt accounting — so W concurrent DistBatchWriters whose
row-hash shards land on disjoint groups append fully concurrently
instead of serializing behind one plane lock (the D4M 100M-inserts/sec
curve only climbs when client parallelism is not funneled through a
single coordination point). The jitted step programs are SHARED across
groups through one :class:`_PlanePrograms` cache (every group has
identical slab shapes, so one trace/compile serves all G shards).
:meth:`DistIngestPlane.publish` composes per-group zero-copy snapshots
into one DistStore (per-group gens under ``DistStore.gens``) without a
global stop-the-world: each group seals under only its own lock, and a
group untouched since its last seal ALIASES its previous snapshot.
``compact_step`` folds one increment of the MOST-INDEBTED group under
only that group's lock. With ``n_groups == 1`` (the default) the facade
degenerates to the former single-lock plane — same lock name, same
state dict, same publish identity/aliasing guarantees.

Per-tablet device counters (rows, minor/major compactions, per-family
overflow) record the blocked-writer dynamics; host wall-clock blocked
seconds accrue PER WRITER (each writer's own tripped-major drains), with
the plane scalar kept as their sum — the paper's §IV-A per-client
backpressure curve is directly plottable from telemetry(). Exact host
mirrors of the per-tablet rows/minor/major counters are also snapshot
into ``plane{n}`` registry gauges at publish()/telemetry() boundaries —
zero device syncs, the mirrors are maintained in lockstep with the
device programs.

publish() is a SNAPSHOT, not a fold: it seals the memtables (one
fill-bounded sort, O(live fill) — the host fill mirror picks the slab
head to sort, pow2-bucketed) and hands out a DistStore view of ALL
levels — base, run slabs, sealed memtable — for every family. The
distributed read path (core/dist_query.py) searches every level, so
freshly ingested rows AND their index/aggregate entries become visible
to DistQueryProcessor without a host round trip, a re-scatter, or the
former O(capacity) run->base re-merge per freshness flip. Major
compaction (threshold-driven during ingest, or batched in the
background via compact()) is the ONLY fold point.

Host-side flush triggers are exact with zero device syncs: tablet
assignments are computed host-side, so a bincount per chunk mirrors the
device memtable fills and run-slot counts precisely — compactions fire
only when some tablet is actually full. Index/aggregate slabs are sized
n_indexed x the event slabs, so one mirror covers all three families
(each event contributes exactly n_indexed entries to each).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import keypack
from .dist_query import DistStore
from .ingest import BatchWriter, IngestMetrics, check_shard_guidance
from .store import DEFAULT_AGG_BUCKET_SECONDS
from ..kernels.merge_runs.ops import _pow2
from ..obs import MetricsRegistry, OwnedLock, span

REV_PAD = np.iinfo(np.int32).max  # +inf rev_ts sentinel (matches DistStore)
KEY_PAD64 = np.iinfo(np.int64).max  # +inf packed-key sentinel (ix/ag)

_plane_seq = itertools.count()  # names each plane's private metrics registry


def _n_devices(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def _linear_device_index(mesh: Mesh):
    """Row-major device index over the mesh axes — the shard_map slab of a
    P(axes, ...)-sharded array on this device covers tablets
    [idx * tablets_per_device, (idx + 1) * tablets_per_device)."""
    idx = jnp.int32(0)
    for a in mesh.axis_names:
        idx = idx * jnp.int32(mesh.shape[a]) + lax.axis_index(a)
    return idx


@dataclass(frozen=True)
class _Family:
    """One table family's static shape parameters. Every family shares the
    tablet grid, run-slot count and compaction lifecycle; they differ in
    key dtype, payload width, slab sizes, and whether duplicate keys are
    combined (summed) at major compaction."""

    name: str
    key_dtype: np.dtype
    sentinel: int
    width: int
    col_dtype: np.dtype
    mem_rows: int
    capacity: int
    combine: str = "none"  # major-scope fold: "none" | "sum" | "dedup"


def _combine_dup_keys(keys, vals, sentinel):
    """Sum payloads of equal adjacent keys in a sorted (sentinel-tailed)
    sequence and compact the unique keys to the front — the traceable form
    of tables.py::_combine_sorted, used for the aggregate family's
    combiner-on-compaction. Returns (ukeys, usums, n_unique)."""
    n = keys.shape[0]
    is_head = jnp.concatenate([jnp.ones((1,), bool), keys[1:] != keys[:-1]])
    seg = jnp.cumsum(is_head.astype(jnp.int32)) - 1
    sums = jax.ops.segment_sum(vals.astype(jnp.int64), seg, num_segments=n)
    n_unique = (is_head & (keys != sentinel)).sum(dtype=jnp.int32)
    # All members of a segment carry the same key, so the duplicate-index
    # scatter is idempotent; the sentinel segment (if any) is the last.
    ukeys = jnp.full((n,), sentinel, keys.dtype).at[seg].set(keys)
    return ukeys, sums, n_unique


def _sort_masked(keys, cols, n, sentinel):
    """Mask entries past the fill to the sentinel and sort (payload travels
    with its key) — memtable slots beyond n hold stale rows left over from
    before the last flush. Shared by minor compaction and the publish seal
    so both produce the same sorted, sentinel-tailed level layout."""
    valid = jnp.arange(keys.shape[0], dtype=jnp.int32) < n
    masked = jnp.where(valid, keys, sentinel)
    order = jnp.argsort(masked)
    return masked[order], cols[order]


class _PlanePrograms:
    """The plane's static configuration + ONE shared cache of jitted step
    programs (append / minor / major / fold_one / seal variants).

    Every :class:`TabletGroup` of a plane has identical slab shapes (same
    tablets-per-device-per-group, mem_rows, max_runs, families), so the
    shard_map programs are shape-identical across groups — caching them
    here means G shards pay ONE trace + compile per step, not G. The
    cache has its own small lock (never held while device programs run);
    lock order is always group.lock -> programs._lock, never reversed."""

    def __init__(
        self,
        mesh: Mesh,
        n_fields: int,
        capacity: int,
        tablets_per_device: int,
        mem_rows: int,
        max_runs: int,
        append_rows: int,
        indexed_fids: Tuple[int, ...],
        agg_bucket_s: int,
        kernel_backend: str,
    ):
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.n_fields = int(n_fields)
        # Per-GROUP tablets per device: a group's state arrays shard this
        # many tablets onto each mesh device.
        self.tablets_per_device = int(tablets_per_device)
        self.n_tablets = _n_devices(mesh) * self.tablets_per_device
        self.capacity = int(capacity)
        self.mem_rows = int(mem_rows)
        self.max_runs = int(max_runs)
        self.append_rows = int(min(append_rows, mem_rows))
        self.indexed_fids = tuple(int(f) for f in indexed_fids)
        self.agg_bucket_s = int(agg_bucket_s)
        self.kernel_backend = kernel_backend
        self.families: Tuple[_Family, ...] = self._make_families()
        self._steps: Dict[object, object] = {}  # guarded-by: _lock
        self._lock = OwnedLock("plane_step_lock")

    # ----------------------------------------------------------- families
    def _make_families(self) -> Tuple[_Family, ...]:
        fams = [
            _Family(
                "ev", np.dtype(np.int32), REV_PAD, self.n_fields,
                np.dtype(np.int32), self.mem_rows, self.capacity,
            )
        ]
        n_idx = len(self.indexed_fids)
        if n_idx:
            fams.append(
                _Family(
                    "ix", np.dtype(np.int64), KEY_PAD64, 0,
                    np.dtype(np.int32), n_idx * self.mem_rows, n_idx * self.capacity,
                    combine="dedup",
                )
            )
            fams.append(
                _Family(
                    "ag", np.dtype(np.int64), KEY_PAD64, 1,
                    np.dtype(np.int64), n_idx * self.mem_rows, n_idx * self.capacity,
                    combine="sum",
                )
            )
        return tuple(fams)

    # --------------------------------------------------------------- specs
    def _spec_of(self, name: str) -> P:
        ax = self.axes
        if name.endswith(("_mem_k", "_base_k")):
            return P(ax, None)
        if name.endswith(("_mem_c", "_base_c")):
            return P(ax, None, None)
        if name.endswith("_run_k"):
            return P(ax, None, None)
        if name.endswith("_run_c"):
            return P(ax, None, None, None)
        if name.endswith("_run_n"):
            return P(ax, None)
        return P(ax)  # *_mem_n, *_base_n, *_overflow, n_runs, rows, minor, major

    def _specs(self, names) -> Dict[str, P]:
        return {n: self._spec_of(n) for n in names}

    # --------------------------------------------------------- name lists
    def _append_names(self):
        names = ["rows"]
        for f in self.families:
            p = f.name
            names += [f"{p}_mem_k", f"{p}_mem_c", f"{p}_mem_n", f"{p}_overflow"]
        return names

    def _minor_names(self):
        names = ["n_runs", "minor"]
        for f in self.families:
            p = f.name
            names += [
                f"{p}_mem_k", f"{p}_mem_c", f"{p}_mem_n",
                f"{p}_run_k", f"{p}_run_c", f"{p}_run_n",
            ]
        return names

    def _major_names(self):
        run = ["n_runs", "major"]
        base = []
        for f in self.families:
            p = f.name
            run += [f"{p}_run_k", f"{p}_run_c", f"{p}_run_n", f"{p}_overflow"]
            base += [f"{p}_base_k", f"{p}_base_c", f"{p}_base_n"]
        return run, base

    def _seal_names(self):
        names = []
        for f in self.families:
            p = f.name
            names += [f"{p}_mem_k", f"{p}_mem_c", f"{p}_mem_n"]
        return names

    def _seal_bucket(self, fill_max: int) -> int:
        """Event-family slot count the seal program must sort to cover a
        memtable fill of fill_max — the live fill rounded up to a power of
        two (floored at 8) so the number of distinct seal compilations is
        log2-bounded, clamped to the slab capacity."""
        return int(min(max(_pow2(max(fill_max, 1)), 8), self.mem_rows))

    # ----------------------------------------------------------- step cache
    def _get_step(self, key, build):
        """Shared compile cache: two groups' (or two writers') first
        flushes racing here must trace once, not twice — the cache lock
        serializes build + insert (the former in-plane guarded dict,
        found by reprolint's guarded-by rule)."""
        with self._lock.hold("step_build"):
            if key not in self._steps:
                self._steps[key] = build()
            return self._steps[key]

    def append_step(self):
        return self._get_step("append", self._build_append)

    def minor_step(self):
        return self._get_step("minor", self._build_minor)

    def major_step(self):
        return self._get_step("major", self._build_major)

    def fold_one_step(self):
        return self._get_step("fold_one", self._build_fold_one)

    def seal_step(self, seal_rows: int):
        return self._get_step(
            ("seal", seal_rows), lambda: self._build_seal(seal_rows)
        )

    # --------------------------------------------------------- step builders
    def _build_append(self):
        mesh, tl = self.mesh, self.tablets_per_device
        families = self.families
        fids = self.indexed_fids
        bucket_s = self.agg_bucket_s
        names = self._append_names()

        def scatter_append(mem_k, mem_c, n, keys, cols, mask):
            """Scatter-append masked entries: dest = running fill; foreign
            and overflow entries map out of bounds and drop."""
            m = mem_k.shape[0]
            dest = jnp.where(
                mask, n + jnp.cumsum(mask.astype(jnp.int32)) - 1, jnp.int32(m)
            )
            mem_k = mem_k.at[dest].set(keys, mode="drop")
            mem_c = mem_c.at[dest].set(cols, mode="drop")
            want = n + mask.sum(dtype=jnp.int32)
            new_n = jnp.minimum(want, jnp.int32(m))
            return mem_k, mem_c, new_n, new_n - n, want - new_n

        def device_fn(st, b_rts, b_cols, b_tab):
            dev = _linear_device_index(mesh)
            # Index/aggregate entries synthesized from the event rows —
            # index maintenance rides the ingest path (module docstring).
            if fids:
                rts64 = b_rts.astype(jnp.int64)
                ts64 = jnp.int64(keypack.TS_MAX) - rts64
                bucket = ts64 // jnp.int64(bucket_s)
                # Traceable twins of keypack.pack_index_key/pack_agg_key
                # (those are numpy; the bit layout constants are shared).
                ix_f = keypack.VALUE_BITS + keypack.TS_BITS
                ag_f = keypack.VALUE_BITS + keypack.BUCKET_BITS
                ik_parts, ak_parts = [], []
                for fid in fids:
                    code = b_cols[:, fid].astype(jnp.int64)
                    ik_parts.append(
                        (jnp.int64(fid) << ix_f) | (code << keypack.TS_BITS) | rts64
                    )
                    ak_parts.append(
                        (jnp.int64(fid) << ag_f) | (code << keypack.BUCKET_BITS) | bucket
                    )
                ikeys = jnp.concatenate(ik_parts)
                akeys = jnp.concatenate(ak_parts)
                icols = jnp.zeros((ikeys.shape[0], 0), jnp.int32)
                acols = jnp.ones((akeys.shape[0], 1), jnp.int64)

            def one(i, loc):
                gid = dev * jnp.int32(tl) + i
                mine = b_tab == gid
                out = dict(loc)
                entries = {"ev": (b_rts, b_cols, mine)}
                if fids:
                    mine_t = jnp.tile(mine, len(fids))
                    entries["ix"] = (ikeys, icols, mine_t)
                    entries["ag"] = (akeys, acols, mine_t)
                for f in families:
                    p = f.name
                    keys, cols, mask = entries[p]
                    mem_k, mem_c, new_n, appended, lost = scatter_append(
                        loc[f"{p}_mem_k"], loc[f"{p}_mem_c"], loc[f"{p}_mem_n"],
                        keys, cols, mask,
                    )
                    out[f"{p}_mem_k"] = mem_k
                    out[f"{p}_mem_c"] = mem_c
                    out[f"{p}_mem_n"] = new_n
                    out[f"{p}_overflow"] = loc[f"{p}_overflow"] + lost
                    if p == "ev":
                        out["rows"] = loc["rows"] + appended.astype(loc["rows"].dtype)
                return out

            idx = jnp.arange(tl, dtype=jnp.int32)
            return jax.vmap(one, in_axes=(0, 0))(idx, st)

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(self._specs(names), P(None), P(None, None), P(None)),
            out_specs=self._specs(names),
            check_rep=False,
        )
        # The ONE allowed donation in the planes: the append step donates
        # only the live memtable slabs, which publish() never aliases — a
        # snapshot seals a sorted COPY of the memtable (_sort_level), so
        # no published DistStore can see the donated buffers.
        return jax.jit(smapped, donate_argnums=(0,))  # reprolint: disable=no-donate-in-plane

    def _build_minor(self):
        mesh, k = self.mesh, self.max_runs
        families = self.families
        names = self._minor_names()

        def device_fn(st):
            def one(loc):
                nr = loc["n_runs"]
                # All families flush in lockstep: a tablet holds event rows
                # iff it holds index/aggregate entries for them.
                do = (loc["ev_mem_n"] > 0) & (nr < jnp.int32(k))
                slot = jnp.clip(nr, 0, k - 1)
                out = dict(loc)
                for f in families:
                    p = f.name
                    n = loc[f"{p}_mem_n"]
                    skeys, scols = _sort_masked(
                        loc[f"{p}_mem_k"], loc[f"{p}_mem_c"], n, f.sentinel
                    )
                    rk, rc, rn = loc[f"{p}_run_k"], loc[f"{p}_run_c"], loc[f"{p}_run_n"]
                    out[f"{p}_run_k"] = rk.at[slot].set(jnp.where(do, skeys, rk[slot]))
                    out[f"{p}_run_c"] = rc.at[slot].set(jnp.where(do, scols, rc[slot]))
                    out[f"{p}_run_n"] = rn.at[slot].set(jnp.where(do, n, rn[slot]))
                    out[f"{p}_mem_n"] = jnp.where(do, 0, n)
                out["n_runs"] = nr + do.astype(nr.dtype)
                out["minor"] = loc["minor"] + do.astype(jnp.int32)
                return out

            return jax.vmap(one)(st)

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(self._specs(names),),
            out_specs=self._specs(names),
            check_rep=False,
        )
        # NOT donated: publish() hands out DistStore views of the run
        # slabs (run-aware reads), and on backends that implement donation
        # a donated minor would delete arrays a caller may still hold.
        return jax.jit(smapped)

    def _build_major(self):
        from ..kernels.merge_runs import merge_sorted_device

        mesh, k = self.mesh, self.max_runs
        families = self.families
        backend = self.kernel_backend
        run_names, base_names = self._major_names()

        def device_fn(rst, bst):
            def one(rloc, bloc):
                nr = rloc["n_runs"]
                do = nr > 0
                out_r = dict(rloc)
                out_b = {}
                for f in families:
                    p, m, c, w = f.name, f.mem_rows, f.capacity, f.width
                    # Two-stage merge: the K runs (m rows each) first, then
                    # the result against the base — pad both sides of the
                    # 2-way merge to one power-of-two length.
                    l2 = _pow2(max(c, k * m))
                    rn = rloc[f"{p}_run_n"]
                    bk, bc, bn = bloc[f"{p}_base_k"], bloc[f"{p}_base_c"], bloc[f"{p}_base_n"]
                    # Mask stale slots/rows (run_n is authoritative; slots
                    # past n_runs were zeroed at the previous major).
                    within = jnp.arange(m, dtype=jnp.int32)[None, :] < rn[:, None]
                    ck = jnp.where(within, rloc[f"{p}_run_k"], f.sentinel)
                    cc = jnp.where(within[..., None], rloc[f"{p}_run_c"], 0)
                    mk, mc = merge_sorted_device(ck, cc, backend=backend)
                    pad_a = jnp.full((l2,), f.sentinel, mk.dtype).at[:c].set(bk)
                    pad_b = jnp.full((l2,), f.sentinel, mk.dtype).at[: k * m].set(mk)
                    ca = jnp.zeros((l2, w), mc.dtype).at[:c].set(bc)
                    cb = jnp.zeros((l2, w), mc.dtype).at[: k * m].set(mc)
                    fk, fc = merge_sorted_device(
                        jnp.stack([pad_a, pad_b]), jnp.stack([ca, cb]), backend=backend
                    )
                    if f.combine == "sum":
                        # Aggregate family: sum duplicate (field, value,
                        # bucket) keys — Accumulo's combiner at compaction
                        # scope. The base stays at unique-key cardinality.
                        fk, sums, total = _combine_dup_keys(fk, fc[:, 0], f.sentinel)
                        fc = sums[:, None].astype(fc.dtype)
                    elif f.combine == "dedup":
                        # Index family: repeated field|value|rev_ts keys
                        # collapse (the same key compaction, payload
                        # discarded — ix rows are zero-width) — without
                        # this the ix base accumulates duplicate postings
                        # forever. Exactness holds because the row fetch
                        # expands a candidate rev_ts by binary search over
                        # the event levels: ONE posting finds EVERY
                        # matching row.
                        fk, _, total = _combine_dup_keys(
                            fk, jnp.zeros(fk.shape, jnp.int32), f.sentinel
                        )
                    else:
                        total = bn + rn.sum()
                    new_bn = jnp.where(do, jnp.minimum(total, jnp.int32(c)), bn)
                    lost = jnp.where(do, total - jnp.minimum(total, jnp.int32(c)), 0)
                    out_b[f"{p}_base_k"] = jnp.where(do, fk[:c], bk)
                    out_b[f"{p}_base_c"] = jnp.where(do, fc[:c], bc)
                    out_b[f"{p}_base_n"] = new_bn
                    out_r[f"{p}_run_n"] = jnp.where(do, jnp.zeros_like(rn), rn)
                    out_r[f"{p}_overflow"] = rloc[f"{p}_overflow"] + lost
                out_r["n_runs"] = jnp.where(do, 0, nr)
                out_r["major"] = rloc["major"] + do.astype(jnp.int32)
                return out_r, out_b

            return jax.vmap(one)(rst, bst)

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(self._specs(run_names), self._specs(base_names)),
            out_specs=(self._specs(run_names), self._specs(base_names)),
            check_rep=False,
        )
        # Deliberately NOT donated (neither runs nor bases): publish()
        # hands out DistStore views of run slabs AND base runs, and on
        # backends that implement donation (TPU/GPU) a donated major
        # would delete arrays a caller may still hold. Majors are rare;
        # one copy each is the price of stable published views.
        return jax.jit(smapped)

    def _build_fold_one(self):
        """One INCREMENT of major compaction: every tablet folds its TOP
        run slot (n_runs - 1) into its base — one bounded 2-way merge of
        O(capacity + mem_rows) rows per family via the resumable
        merge_pair_device entry point, instead of the all-runs k-way
        fold. Folding the top slot keeps the remaining slots a contiguous
        [0, n_runs) prefix, so ANY prefix of increments leaves the exact
        LSM invariants every read primitive in dist_query.py already
        handles (sorted levels, live counts authoritative, combine folded
        at the base): an interrupted major is just a database with fewer
        runs. Fold order across slots only permutes equal keys — the
        per-key combines (sum / dedup) are commutative and event rows
        with equal rev_ts are order-free for every query primitive — so
        K increments agree with one compact() as a multiset (asserted
        against the numpy oracle in tests)."""
        from ..kernels.merge_runs import merge_pair_device

        mesh = self.mesh
        families = self.families
        backend = self.kernel_backend
        run_names, base_names = self._major_names()

        def device_fn(rst, bst):
            def one(rloc, bloc):
                nr = rloc["n_runs"]
                do = nr > 0
                slot = jnp.maximum(nr - 1, 0)
                out_r = dict(rloc)
                out_b = {}
                for f in families:
                    p, m, c = f.name, f.mem_rows, f.capacity
                    rn_slot = rloc[f"{p}_run_n"][slot]
                    # Mask stale rows past the slot's live count (slots
                    # hold leftovers from before earlier folds).
                    within = jnp.arange(m, dtype=jnp.int32) < rn_slot
                    ck = jnp.where(within, rloc[f"{p}_run_k"][slot], f.sentinel)
                    cc = jnp.where(within[:, None], rloc[f"{p}_run_c"][slot], 0)
                    bk, bc, bn = (
                        bloc[f"{p}_base_k"], bloc[f"{p}_base_c"], bloc[f"{p}_base_n"]
                    )
                    fk, fc = merge_pair_device(bk, bc, ck, cc, backend=backend)
                    if f.combine == "sum":
                        fk, sums, total = _combine_dup_keys(fk, fc[:, 0], f.sentinel)
                        fc = sums[:, None].astype(fc.dtype)
                    elif f.combine == "dedup":
                        fk, _, total = _combine_dup_keys(
                            fk, jnp.zeros(fk.shape, jnp.int32), f.sentinel
                        )
                    else:
                        total = bn + rn_slot
                    new_bn = jnp.where(do, jnp.minimum(total, jnp.int32(c)), bn)
                    lost = jnp.where(do, total - jnp.minimum(total, jnp.int32(c)), 0)
                    out_b[f"{p}_base_k"] = jnp.where(do, fk[:c], bk)
                    out_b[f"{p}_base_c"] = jnp.where(do, fc[:c], bc)
                    out_b[f"{p}_base_n"] = new_bn
                    out_r[f"{p}_run_n"] = rloc[f"{p}_run_n"].at[slot].set(
                        jnp.where(do, 0, rn_slot)
                    )
                    out_r[f"{p}_overflow"] = rloc[f"{p}_overflow"] + lost
                out_r["n_runs"] = nr - do.astype(nr.dtype)
                # The increment that folds the LAST run completes one
                # major — the per-tablet counter keeps its meaning
                # (number of run->base folds brought to empty).
                out_r["major"] = rloc["major"] + (do & (nr == 1)).astype(jnp.int32)
                return out_r, out_b

            return jax.vmap(one)(rst, bst)

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(self._specs(run_names), self._specs(base_names)),
            out_specs=(self._specs(run_names), self._specs(base_names)),
            check_rep=False,
        )
        # NOT donated, same as the full major: published views alias the
        # run/base buffers and must survive the fold.
        return jax.jit(smapped)

    def _build_seal(self, seal_rows: int):
        """FILL-BOUNDED sorted snapshot of the memtables — the only
        per-publish device work. Only the first `seal_rows` slots of each
        event memtable (scaled per family: ix/ag slabs are n_indexed x
        wider) are sorted — O(fill log fill), not O(mem_rows log
        mem_rows): a publish right after a flush or a compact() pays for
        the handful of live rows, not the slab capacity. The sealed
        OUTPUT keeps the full (T, mem_rows) shape — sorted head +
        sentinel tail — so published DistStore level shapes never change
        and the compiled read programs never re-trace. Reads the live
        memtable slabs (no donation) and writes fresh sealed arrays, so
        later appends can't tear a published view."""
        mesh = self.mesh
        families = self.families
        names = self._seal_names()
        # Per-family head length: ix/ag fills are exactly n_indexed x the
        # event fill (one entry per indexed field per event).
        heads = {
            f.name: int(min(seal_rows * (f.mem_rows // self.mem_rows), f.mem_rows))
            for f in families
        }
        out_specs = {}
        for f in families:
            p = f.name
            out_specs[f"{p}_sealed_k"] = P(self.axes, None)
            out_specs[f"{p}_sealed_c"] = P(self.axes, None, None)
            out_specs[f"{p}_sealed_n"] = P(self.axes)

        def device_fn(st):
            def one(loc):
                out = {}
                for f in families:
                    p, m, h = f.name, f.mem_rows, heads[f.name]
                    n = loc[f"{p}_mem_n"]
                    # Same mask-past-fill + sort as a minor flush — over
                    # the live head only (publish() guarantees n <= h);
                    # the sentinel tail keeps the sealed level's sorted +
                    # sentinel-tailed invariant at full slab shape.
                    head_k, head_c = _sort_masked(
                        loc[f"{p}_mem_k"][:h], loc[f"{p}_mem_c"][:h], n, f.sentinel
                    )
                    out[f"{p}_sealed_k"] = jnp.concatenate(
                        [head_k, jnp.full((m - h,), f.sentinel, head_k.dtype)]
                    )
                    out[f"{p}_sealed_c"] = jnp.concatenate(
                        [head_c, jnp.zeros((m - h, f.width), head_c.dtype)]
                    )
                    out[f"{p}_sealed_n"] = n
                return out

            return jax.vmap(one)(st)

        smapped = shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(self._specs(names),),
            out_specs=out_specs,
            check_rep=False,
        )
        return jax.jit(smapped)


class TabletGroup:
    """One shard of the ingest plane: a contiguous range of
    ``programs.n_tablets`` global tablets with its OWN lock, device
    state, host mirrors, generation tags and fold-debt accounting.

    A group is the former whole-plane DistIngestPlane body with the
    plane-global bits factored out: step programs come from the shared
    :class:`_PlanePrograms` cache (identical shapes across groups — one
    compile serves all), and counters land on the plane's shared metrics
    registry (per-writer blocked cells therefore still sum to the plane
    scalar no matter how waits split across groups). Everything below is
    guarded by ``self.lock`` — writers on DIFFERENT groups never contend.

    Global tablet ``t`` belongs to group ``t // n_tablets`` and is this
    group's local tablet ``t - t0``; all arrays here index local ids."""

    def __init__(
        self,
        gid: int,
        n_groups: int,
        programs: _PlanePrograms,
        m_seal,
        m_blocked,
        m_folds,
        m_last_seal_rows,
        m_group_stall=None,
        m_group_stall_events=None,
    ):
        self.gid = int(gid)
        self.programs = programs
        self.mesh = programs.mesh
        self.n_tablets = programs.n_tablets  # local (per-group) count
        self.t0 = self.gid * self.n_tablets  # global id of local tablet 0
        self._m_seal = m_seal
        self._m_blocked = m_blocked
        self._m_folds = m_folds
        self._m_last_seal_rows = m_last_seal_rows
        self._m_group_stall = m_group_stall
        self._m_group_stall_events = m_group_stall_events
        # The single-group plane keeps the historic lock name (occupancy
        # reports, benches and CI key on "plane_lock"); sharded planes
        # name each group's lock so the books attribute contention to the
        # group that serialized it.
        name = "plane_lock" if n_groups == 1 else f"plane_lock_g{self.gid}"
        self.lock = OwnedLock(name)
        # Exact host-side mirrors of the device memtable fills, run-slot
        # counts and per-tablet counters (see module docstring) — updated
        # in lockstep with the device programs' own guards, never read
        # back from the device. One fill mirror serves all families:
        # ix/ag fills are exactly n_indexed x the event fill per tablet.
        self._fill = np.zeros(self.n_tablets, np.int64)  # guarded-by: lock
        self._runs_host = np.zeros(self.n_tablets, np.int32)  # guarded-by: lock
        self._rows_host = np.zeros(self.n_tablets, np.int64)  # guarded-by: lock
        self._minor_host = np.zeros(self.n_tablets, np.int32)  # guarded-by: lock
        self._major_host = np.zeros(self.n_tablets, np.int32)  # guarded-by: lock
        self._dirty = True  # guarded-by: lock
        self._published: Optional[DistStore] = None  # guarded-by: lock
        # Generation tag per LSM level (shared by all families — they move
        # in lockstep): appends bump "mem"; a minor flush bumps "mem" +
        # "runs"; any fold into the base (full major or one compact_step
        # increment) bumps "runs" + "base". snapshot() keys its sealed-
        # memtable cache on the "mem" generation, so a publish after a
        # fold-only increment ALIASES the previous sealed arrays instead
        # of re-running the seal sort — snapshots never pay per-increment
        # device work for levels the increment didn't touch.
        self._gen: Dict[str, int] = {"mem": 0, "runs": 0, "base": 0}  # guarded-by: lock
        # (mem generation, sealed arrays, seal_rows) of the last seal run.
        self._sealed_cache: Optional[Tuple[int, Dict[str, jax.Array], int]] = None  # guarded-by: lock
        self.state = self._init_state()  # guarded-by: lock

    def _init_state(self) -> Dict[str, jax.Array]:
        pr = self.programs
        t, k = self.n_tablets, pr.max_runs
        host: Dict[str, np.ndarray] = {
            "n_runs": np.zeros((t,), np.int32),
            "rows": np.zeros((t,), np.int64),
            "minor": np.zeros((t,), np.int32),
            "major": np.zeros((t,), np.int32),
        }
        for f in pr.families:
            p, m, c = f.name, f.mem_rows, f.capacity
            host[f"{p}_mem_k"] = np.zeros((t, m), f.key_dtype)
            host[f"{p}_mem_c"] = np.zeros((t, m, f.width), f.col_dtype)
            host[f"{p}_mem_n"] = np.zeros((t,), np.int32)
            host[f"{p}_run_k"] = np.full((t, k, m), f.sentinel, f.key_dtype)
            host[f"{p}_run_c"] = np.zeros((t, k, m, f.width), f.col_dtype)
            host[f"{p}_run_n"] = np.zeros((t, k), np.int32)
            host[f"{p}_base_k"] = np.full((t, c), f.sentinel, f.key_dtype)
            host[f"{p}_base_c"] = np.zeros((t, c, f.width), f.col_dtype)
            host[f"{p}_base_n"] = np.zeros((t,), np.int32)
            host[f"{p}_overflow"] = np.zeros((t,), np.int32)
        return {
            name: jax.device_put(arr, NamedSharding(self.mesh, pr._spec_of(name)))
            for name, arr in host.items()
        }

    def _sub(self, names) -> Dict[str, jax.Array]:  # holds: lock
        return {n: self.state[n] for n in names}

    # --------------------------------------------------------- compaction
    def _run_minor(self) -> None:  # holds: lock
        pr = self.programs
        step = pr.minor_step()
        self.state.update(step(self._sub(pr._minor_names())))
        # Mirror the device guard exactly: a tablet flushes iff it holds
        # rows AND has a free run slot.
        flushed = (self._fill > 0) & (self._runs_host < pr.max_runs)
        self._runs_host += flushed
        self._minor_host += flushed
        self._fill = np.where(flushed, 0, self._fill)
        if flushed.any():
            self._gen["mem"] += 1  # memtables drained
            self._gen["runs"] += 1  # run slabs gained a slot

    def _run_major(self) -> None:  # holds: lock
        pr = self.programs
        step = pr.major_step()
        run_names, base_names = pr._major_names()
        out_r, out_b = step(self._sub(run_names), self._sub(base_names))
        self.state.update(out_r)
        self.state.update(out_b)
        self._major_host += self._runs_host > 0
        if self._runs_host.max() > 0:
            self._gen["runs"] += 1
            self._gen["base"] += 1
        self._runs_host[:] = 0

    def _run_fold_one(self) -> None:  # holds: lock
        """One increment: every tablet with runs folds its top run slot
        into its base (see _build_fold_one). Host run mirror drops by one
        where it was positive — exactly the device guard."""
        pr = self.programs
        step = pr.fold_one_step()
        run_names, base_names = pr._major_names()
        out_r, out_b = step(self._sub(run_names), self._sub(base_names))
        self.state.update(out_r)
        self.state.update(out_b)
        # The increment that folds a tablet's LAST run completes a major.
        self._major_host += self._runs_host == 1
        if self._runs_host.max() > 0:
            self._gen["runs"] += 1
            self._gen["base"] += 1
        self._runs_host = np.maximum(self._runs_host - 1, 0).astype(self._runs_host.dtype)

    # ------------------------------------------------------------- ingest
    def ingest(
        self, rts: np.ndarray, cols: np.ndarray, tab: np.ndarray, writer_id: int = 0
    ) -> float:
        """Append a pre-encoded batch whose `tab` ids are GROUP-LOCAL
        (facade callers subtract t0). Returns seconds this writer spent
        blocked on major compactions it tripped in THIS group; accrued to
        the plane-shared per-writer blocked counter, so the plane scalar
        stays the sum over writers no matter how waits split across
        groups. Ordinary lock wait (peer appends, jit tracing) is
        deliberately NOT counted: the metric is compaction-attributed,
        like the host Tablet's (the group lock's own wait books cover
        lock contention — see obs.occupancy)."""
        n = len(rts)
        if n == 0:
            return 0.0
        rts = np.asarray(rts, np.int32)
        cols = np.asarray(cols, np.int32)
        tab = np.asarray(tab, np.int32)
        with self.lock.hold("ingest_append"):
            append = self.programs.append_step()
            with span(
                "ingest.append", cat="ingest", rows=n, writer=writer_id,
                group=self.gid,
            ) as sp:
                blocked = self._ingest_locked(append, rts, cols, tab, n)
                sp.set(blocked_s=blocked)
            self._m_blocked.inc(blocked, writer=writer_id)
            if blocked > 0.0 and self._m_group_stall is not None:
                # Group-attributed stall event: same seconds as the
                # per-writer cells, keyed by WHERE the major tripped.
                self._m_group_stall.inc(blocked, group=self.gid)
                self._m_group_stall_events.inc(group=self.gid)
            return blocked

    def _ingest_locked(self, append, rts, cols, tab, n: int) -> float:  # holds: lock
        pr = self.programs
        s = self.state
        blocked = 0.0
        b = pr.append_rows
        names = pr._append_names()
        for off in range(0, n, b):
            chunk = min(b, n - off)
            tab_chunk = tab[off : off + chunk]
            cb = np.bincount(tab_chunk, minlength=self.n_tablets)
            # Exact room check from the host-side fill mirror: flush only
            # the moment some tablet's memtable would actually overflow.
            if np.any(self._fill + cb > pr.mem_rows):
                if np.any((self._fill > 0) & (self._runs_host >= pr.max_runs)):
                    # No free run slot for a tablet that must flush: major
                    # compaction first — it BLOCKS the writer that tripped
                    # it, Accumulo's backpressure reproduced on the mesh.
                    # For the occupancy books this stretch of the ingest
                    # hold is fold work, not append work.
                    t0 = time.perf_counter()
                    with self.lock.reowner("fold_increment"):
                        with span("ingest.major", cat="ingest", group=self.gid):
                            self._run_major()
                            jax.block_until_ready(self.state["ev_base_n"])
                    blocked += time.perf_counter() - t0
                    self._m_folds.inc(source="ingest")
                with span("ingest.minor", cat="ingest", group=self.gid):
                    self._run_minor()
            pad_rts = np.zeros((b,), np.int32)
            pad_cols = np.zeros((b, pr.n_fields), np.int32)
            pad_tab = np.full((b,), -1, np.int32)  # -1: no tablet claims it
            pad_rts[:chunk] = rts[off : off + chunk]
            pad_cols[:chunk] = cols[off : off + chunk]
            pad_tab[:chunk] = tab_chunk
            s.update(
                append(
                    self._sub(names),
                    jnp.asarray(pad_rts), jnp.asarray(pad_cols), jnp.asarray(pad_tab),
                )
            )
            self._fill += cb
            self._rows_host += cb
        self._dirty = True
        self._gen["mem"] += 1  # appends touch only the memtable level
        return blocked

    # -------------------------------------------------------------- reads
    def snapshot(self) -> DistStore:
        """Snapshot this group into a query-visible DistStore — ALL levels
        of every family: base runs, sorted-run slabs, and a sealed (sorted)
        copy of the memtables. NO fold happens here: the run-aware read
        path searches every level, so a snapshot costs O(live memtable
        fill) device work (the seal sort) + a metadata flip, independent
        of base fill AND of memtable capacity — major compaction,
        threshold-driven during ingest or batched via compact(), is the
        only point where runs merge into the base.

        The whole snapshot — seal program, state references, cache flip —
        happens under the GROUP lock only (no global stop-the-world: other
        groups keep appending), so a snapshot racing concurrent writer
        ingest can never observe a torn state: every ingest call mutates
        this group's state under the same lock. Cheap no-op when nothing
        was ingested since the last snapshot."""
        with self.lock.hold("publish_seal"):
            if not self._dirty and self._published is not None:
                return self._published
            pr = self.programs
            # Fill-bounded seal: the host fill mirror is exact, so the
            # seal program sorts only the live head of each memtable
            # (pow2-bucketed to bound compilations) — a near-empty
            # memtable seals in O(fill), not O(mem_rows).
            #
            # Generation-keyed reuse: the seal depends ONLY on memtable
            # contents, so when the "mem" generation is unchanged since
            # the cached seal (the publish was forced by a fold-only
            # compact_step increment), the previous sealed arrays are
            # ALIASED — snapshots across K increments pay zero seal
            # sorts, and tests assert array identity on the reuse path.
            gen_mem = self._gen["mem"]
            if self._sealed_cache is not None and self._sealed_cache[0] == gen_mem:
                _, sealed, seal_rows = self._sealed_cache
                self._m_last_seal_rows.set_value(seal_rows)
                self._m_seal.inc(event="reuse")
            else:
                seal_rows = pr._seal_bucket(int(self._fill.max()))
                self._m_last_seal_rows.set_value(seal_rows)
                with span(
                    "ingest.seal", cat="ingest", seal_rows=seal_rows, group=self.gid
                ):
                    sealed = pr.seal_step(seal_rows)(self._sub(pr._seal_names()))
                self._sealed_cache = (gen_mem, sealed, seal_rows)
                self._m_seal.inc(event="seal")
            s = self.state
            has_ix = len(pr.families) > 1
            self._published = DistStore(
                rev_ts=s["ev_base_k"],
                cols=s["ev_base_c"],
                counts=s["ev_base_n"],
                mesh=self.mesh,
                run_rev_ts=s["ev_run_k"],
                run_cols=s["ev_run_c"],
                run_counts=s["ev_run_n"],
                mem_rev_ts=sealed["ev_sealed_k"],
                mem_cols=sealed["ev_sealed_c"],
                mem_counts=sealed["ev_sealed_n"],
                ix_keys=s["ix_base_k"] if has_ix else None,
                ix_counts=s["ix_base_n"] if has_ix else None,
                ix_run_k=s["ix_run_k"] if has_ix else None,
                ix_run_n=s["ix_run_n"] if has_ix else None,
                ix_mem_k=sealed["ix_sealed_k"] if has_ix else None,
                ix_mem_n=sealed["ix_sealed_n"] if has_ix else None,
                ag_keys=s["ag_base_k"] if has_ix else None,
                ag_vals=s["ag_base_c"] if has_ix else None,
                ag_counts=s["ag_base_n"] if has_ix else None,
                ag_run_k=s["ag_run_k"] if has_ix else None,
                ag_run_c=s["ag_run_c"] if has_ix else None,
                ag_run_n=s["ag_run_n"] if has_ix else None,
                ag_mem_k=sealed["ag_sealed_k"] if has_ix else None,
                ag_mem_c=sealed["ag_sealed_c"] if has_ix else None,
                ag_mem_n=sealed["ag_sealed_n"] if has_ix else None,
                agg_bucket_s=pr.agg_bucket_s if has_ix else None,
                gens=dict(self._gen),
            )
            self._dirty = False
            return self._published

    # ------------------------------------------------------------- warmup
    def warm_seal(self) -> None:
        with self.lock.hold("warmup"):
            pr = self.programs
            seal_rows = 8
            while True:
                pr.seal_step(seal_rows)(self._sub(pr._seal_names()))
                if seal_rows >= pr.mem_rows:
                    break
                seal_rows = min(seal_rows * 2, pr.mem_rows)

    def warm_compaction(self) -> None:
        with self.lock.hold("warmup"):
            staged = bool(int(self._fill.max()) or int(self._runs_host.max()))
            self._run_minor()
            self._run_fold_one()
            self._run_major()
            if staged:
                self._dirty = True
                self._m_folds.inc(source="explicit")

    # -------------------------------------------------------- bookkeeping
    def has_unfolded(self) -> bool:
        """True when this group's memtables or run slots hold rows — i.e.
        compact() on it would fold something. Exact from the host-side
        mirrors: zero device syncs."""
        with self.lock.hold("bookkeeping"):
            return bool(int(self._fill.max()) or int(self._runs_host.max()))

    def fold_debt(self) -> int:
        """Deepest run-slot usage across this group's tablets (host
        mirror, free): how close its ingest is to tripping a blocking
        major (at max_runs). The facade's compact_step picks the
        most-indebted group by this signal."""
        with self.lock.hold("bookkeeping"):
            return int(self._runs_host.max())

    def counter_mirrors(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Copies of the exact per-tablet (rows, minor, major) host
        mirrors — the zero-sync source for the plane's per-tablet
        registry gauges at publish()/telemetry() boundaries."""
        with self.lock.hold("bookkeeping"):
            return self._rows_host.copy(), self._minor_host.copy(), self._major_host.copy()

    def gen_snapshot(self) -> Dict[str, int]:
        with self.lock.hold("bookkeeping"):
            return dict(self._gen)

    # --------------------------------------------------------------- fold
    def compact(self, source: str = "explicit") -> int:
        """Batched background fold of THIS group: drain memtables into
        runs (minor) and runs into the base (major) for every family —
        see DistIngestPlane.compact. Returns minor+major passes run (0
        for the no-op)."""
        with self.lock.hold("fold_increment"):
            if int(self._fill.max()) == 0 and int(self._runs_host.max()) == 0:
                return 0  # exact mirrors: nothing in memtables or run slots
            passes = 0
            with span("ingest.compact", cat="ingest", source=source, group=self.gid) as sp:
                for _ in range(3):
                    self._run_minor()
                    self._run_major()
                    passes += 1
                    if int(self._fill.max()) == 0:  # exact mirror: no device sync
                        break
                else:  # pragma: no cover — the invariant bounds this to 2 passes
                    raise RuntimeError("compact did not drain the memtables")
                sp.set(passes=passes)
            self._m_folds.inc(passes, source=source)
            self._dirty = True  # published view now points at stale levels
            return passes

    def compact_step(self, source: str = "explicit") -> int:
        """ONE bounded increment of compaction for THIS group, under only
        this group's lock — see DistIngestPlane.compact_step. Returns 1
        when an increment ran, else 0."""
        with self.lock.hold("fold_increment"):
            if int(self._runs_host.max()) > 0:
                with span(
                    "ingest.fold_increment", cat="ingest", source=source,
                    kind="fold", group=self.gid,
                ):
                    self._run_fold_one()
            elif int(self._fill.max()) > 0:
                with span(
                    "ingest.fold_increment", cat="ingest", source=source,
                    kind="minor", group=self.gid,
                ):
                    self._run_minor()
            else:
                return 0  # exact mirrors: nothing staged anywhere
            self._m_folds.inc(source=source)
            self._dirty = True  # published view now points at stale levels
            return 1

    # ---------------------------------------------------------- telemetry
    def telemetry_arrays(self) -> Dict[str, np.ndarray]:
        """Device counters of this group's tablets, fetched under the
        group lock (local tablet order == a contiguous global slice)."""
        with self.lock.hold("bookkeeping"):
            pr = self.programs
            alias = {
                "rows": "rows", "minor": "minor", "major": "major",
                "n_runs": "n_runs", "overflow": "ev_overflow",
                "mem_n": "ev_mem_n", "base_n": "ev_base_n",
            }
            out = {
                name: np.asarray(jax.device_get(self.state[key]))
                for name, key in alias.items()
            }
            for f in pr.families[1:]:
                out[f"{f.name}_overflow"] = np.asarray(
                    jax.device_get(self.state[f"{f.name}_overflow"])
                )
                out[f"{f.name}_base_n"] = np.asarray(
                    jax.device_get(self.state[f"{f.name}_base_n"])
                )
            return out


class DistIngestPlane:
    """Device-resident LSM tablet grid + its jitted ingest/compaction
    programs, sharded into ``n_groups`` independently-locked
    :class:`TabletGroup`s. T = n_devices * tablets_per_device global
    tablets; group g owns the contiguous range
    [g * T/G, (g+1) * T/G), each tablet with a memtable slab (mem_rows),
    max_runs sorted-run slots (mem_rows each) and a base run (capacity
    rows) — per family (see module docstring).

    This class is a thin FACADE: it routes batches to groups by tablet
    id, composes per-group snapshots at publish(), picks the
    most-indebted group for compact_step(), and aggregates telemetry.
    All device state and locking live in the groups; with the default
    ``n_groups=1`` every legacy single-lock behavior (state dict
    identity, "plane_lock" occupancy books, publish aliasing) is
    preserved exactly."""

    def __init__(
        self,
        mesh: Mesh,
        n_fields: int,
        capacity: int,
        tablets_per_device: int = 1,
        mem_rows: int = 4096,
        max_runs: int = 4,
        append_rows: int = 1024,
        indexed_fids: Sequence[int] = (),
        agg_bucket_s: int = DEFAULT_AGG_BUCKET_SECONDS,
        kernel_backend: str = "auto",
        n_groups: int = 1,
    ):
        if n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {n_groups}")
        if tablets_per_device % n_groups:
            raise ValueError(
                f"n_groups={n_groups} must divide tablets_per_device="
                f"{tablets_per_device}: each group owns an equal, contiguous "
                "per-device tablet slice"
            )
        self.mesh = mesh
        self.axes = tuple(mesh.axis_names)
        self.n_fields = int(n_fields)
        self.tablets_per_device = int(tablets_per_device)
        self.n_tablets = _n_devices(mesh) * self.tablets_per_device
        self.n_groups = int(n_groups)
        self.tablets_per_group = self.n_tablets // self.n_groups
        self.capacity = int(capacity)
        self.mem_rows = int(mem_rows)
        self.max_runs = int(max_runs)
        self.append_rows = int(min(append_rows, mem_rows))
        self.indexed_fids = tuple(int(f) for f in indexed_fids)
        self.agg_bucket_s = int(agg_bucket_s)
        self.kernel_backend = kernel_backend
        # All plane counters live on a PRIVATE metrics registry (plane
        # instances in one process never share cells) and are SHARED by
        # every group; the legacy names (seal_events, blocked_seconds,
        # fold_events, ...) remain as properties over these metrics.
        # Fold accounting: every run->base fold is attributed to whoever
        # drove it — "ingest" counts BLOCKING majors tripped by a
        # writer's flush (one per major), and each `source` passed to
        # compact() ("explicit" callers, "background" for the serve
        # plane's compactor) counts that call's drain passes. Routine
        # minor flushes are not folds and are not attributed (the
        # per-tablet `minor` counter already tracks them). What matters
        # for the serve plane: the query path NEVER appears here — reads
        # cannot fold by construction — and telemetry()["fold_events"]
        # proves it.
        self.metrics = MetricsRegistry(f"plane{next(_plane_seq)}")
        self._m_seal = self.metrics.counter(
            "plane_seal_total", "publishes that ran (event=seal) vs aliased (event=reuse)"
        )
        self._m_blocked = self.metrics.counter(
            "plane_blocked_seconds_total", "writer seconds blocked on tripped majors"
        )
        # Group-attributed view of the same stalls: the per-writer cells
        # answer WHO paid, these answer WHERE — a hot tablet group whose
        # majors keep tripping shows up as one label here (and as the SLO
        # watchdog's compaction-stall rule input).
        self._m_group_stall = self.metrics.counter(
            "plane_group_stall_seconds_total",
            "writer seconds blocked on tripped majors, by tablet group",
        )
        self._m_group_stall_events = self.metrics.counter(
            "plane_group_stall_events_total",
            "ingest appends that tripped a blocking major, by tablet group",
        )
        self._m_folds = self.metrics.counter(
            "plane_fold_events_total", "run->base folds by driving source"
        )
        self._m_last_seal_rows = self.metrics.gauge(
            "plane_last_seal_rows", "event-family slots the last publish sorted"
        )
        # Per-tablet device counters surfaced WITHOUT a device sync: set
        # from the groups' exact host mirrors at publish()/telemetry()
        # boundaries only (labels carry the GLOBAL tablet id).
        self._m_tab_rows = self.metrics.gauge(
            "plane_tablet_rows", "rows appended per tablet (host mirror)"
        )
        self._m_tab_minor = self.metrics.gauge(
            "plane_tablet_minor", "minor compactions per tablet (host mirror)"
        )
        self._m_tab_major = self.metrics.gauge(
            "plane_tablet_major", "major compactions per tablet (host mirror)"
        )
        programs = _PlanePrograms(
            mesh, n_fields, capacity, self.tablets_per_device // self.n_groups,
            mem_rows, max_runs, append_rows, self.indexed_fids,
            self.agg_bucket_s, kernel_backend,
        )
        self.programs = programs
        self.families = programs.families
        self.groups: Tuple[TabletGroup, ...] = tuple(
            TabletGroup(
                g, self.n_groups, programs,
                self._m_seal, self._m_blocked, self._m_folds,
                self._m_last_seal_rows,
                m_group_stall=self._m_group_stall,
                m_group_stall_events=self._m_group_stall_events,
            )
            for g in range(self.n_groups)
        )
        # Facade-global bits: session stats and the composite-snapshot
        # cache sit under a META lock (never held across device work, and
        # never nested inside a group lock), so they stay race-free while
        # group locks split the ingest path.
        self._meta_lock = OwnedLock("plane_meta_lock")
        # Serve-plane sessions report through the same telemetry structure
        # as ingest writers (record_session); key = session id.
        self.session_stats: Dict[int, Dict[str, float]] = {}  # guarded-by: _meta_lock
        self._composite: Optional[DistStore] = None  # guarded-by: _meta_lock

    # ------------------------------------------------- legacy metric views
    # Thin views over the plane registry, kept so six PRs of tests and
    # benches read the same names they always did. blocked_seconds also
    # accepts `= 0.0` (benches zero it between rounds) — anything else
    # would silently desync the per-writer cells, so it raises.
    @property
    def seal_events(self) -> int:
        return int(self._m_seal.value(event="seal"))

    @property
    def seal_reuses(self) -> int:
        return int(self._m_seal.value(event="reuse"))

    @property
    def blocked_seconds(self) -> float:
        return self._m_blocked.total()

    @blocked_seconds.setter
    def blocked_seconds(self, v: float) -> None:
        if v != 0:
            raise ValueError("blocked_seconds can only be reset to 0")
        self._m_blocked.reset()

    @property
    def blocked_by_writer(self) -> Dict[int, float]:
        return {
            int(dict(key)["writer"]): v for key, v in self._m_blocked.cells().items()
        }

    @property
    def fold_events(self) -> Dict[str, int]:
        return {dict(key)["source"]: int(v) for key, v in self._m_folds.cells().items()}

    @property
    def last_seal_rows(self) -> int:
        return int(self._m_last_seal_rows.value())

    # -------------------------------------------- legacy single-group views
    @property
    def state(self) -> Dict[str, jax.Array]:
        """The device state dict — single-group planes only (a sharded
        plane has one state dict PER GROUP; address plane.groups[g].state
        explicitly there)."""
        if self.n_groups != 1:
            raise RuntimeError(
                "plane.state is ambiguous with n_groups > 1; "
                "use plane.groups[g].state"
            )
        return self.groups[0].state

    @property
    def _lock(self) -> OwnedLock:
        """The legacy plane lock — group 0's lock. Meaningful as THE
        plane lock only when n_groups == 1 (benches/tests key on it); a
        sharded plane has one lock per group."""
        return self.groups[0].lock

    @property
    def _dirty(self) -> bool:
        return any(g._dirty for g in self.groups)

    @_dirty.setter
    def _dirty(self, v: bool) -> None:
        for g in self.groups:
            g._dirty = bool(v)

    @property
    def _fill(self) -> np.ndarray:
        if self.n_groups == 1:
            return self.groups[0]._fill
        return np.concatenate([g._fill for g in self.groups])

    @property
    def _runs_host(self) -> np.ndarray:
        if self.n_groups == 1:
            return self.groups[0]._runs_host
        return np.concatenate([g._runs_host for g in self.groups])

    @classmethod
    def for_store(cls, store, mesh: Mesh, capacity: int, **kw) -> "DistIngestPlane":
        """Plane bound to a host store's schema: maintains index postings
        and aggregate counts for the store's indexed fields, with the
        store's aggregate bucketing (so host and dist densities agree)."""
        kw.setdefault(
            "indexed_fids", tuple(int(f) for f in store._indexed_field_ids)
        )
        kw.setdefault("agg_bucket_s", store.agg_bucket_seconds)
        return cls(mesh, store.schema.n_fields, capacity, **kw)

    # ------------------------------------------------------------- ingest
    def ingest(
        self, rts: np.ndarray, cols: np.ndarray, tab: np.ndarray, writer_id: int = 0
    ) -> float:
        """Append a pre-encoded, pre-sharded batch. rts int32 reversed
        timestamps; cols (n, F) int32 codes; tab (n,) int32 GLOBAL tablet
        ids. Routes each row to the group owning its tablet (group =
        tab // tablets_per_group) — rows for different groups append
        under different locks, so writers whose batches land on disjoint
        groups proceed fully concurrently. Returns seconds this writer
        spent blocked on major compactions it tripped (backpressure),
        summed across the groups this batch touched; also accrued to
        blocked_by_writer[writer_id], with the plane scalar kept as the
        sum over writers."""
        n = len(rts)
        if n == 0:
            return 0.0
        rts = np.asarray(rts, np.int32)
        cols = np.asarray(cols, np.int32)
        tab = np.asarray(tab, np.int32)
        if self.n_groups == 1:
            return self.groups[0].ingest(rts, cols, tab, writer_id=writer_id)
        gids = tab // np.int32(self.tablets_per_group)
        blocked = 0.0
        for g in self.groups:
            m = gids == g.gid
            if not m.any():
                continue
            blocked += g.ingest(
                rts[m], cols[m], (tab[m] - np.int32(g.t0)), writer_id=writer_id
            )
        return blocked

    # -------------------------------------------------------------- reads
    def publish(self) -> DistStore:
        """Snapshot the plane into a query-visible DistStore — ALL levels
        of every family, composed from per-group zero-copy snapshots with
        NO global stop-the-world: each group seals under only its own
        lock (concurrent writers on other groups never stall), and a
        group that is clean since its last snapshot ALIASES its previous
        arrays. Single-group planes return the group's DistStore directly
        (the legacy zero-copy snapshot, identity-preserving); sharded
        planes return a COMPOSITE DistStore whose ``groups`` tuple holds
        the per-group sub-stores in global tablet order, with per-group
        generation tags under ``gens["g<i>"]`` — the read path
        (core/dist_query.py) fans out over the sub-stores and each
        sub-store keeps its own planner density cache, so untouched
        groups' caches survive publishes of busy ones."""
        with span("ingest.publish", cat="ingest"):
            if self.n_groups == 1:
                out = self.groups[0].snapshot()
                self._update_tablet_gauges()
                return out
            subs = tuple(g.snapshot() for g in self.groups)
            self._update_tablet_gauges()
            with self._meta_lock.hold("publish_compose"):
                cached = self._composite
                if cached is not None and all(
                    a is b for a, b in zip(cached.groups, subs)
                ):
                    return cached
                self._composite = DistStore(
                    mesh=self.mesh,
                    groups=subs,
                    gens={
                        f"g{g.gid}": dict(sub.gens)
                        for g, sub in zip(self.groups, subs)
                    },
                )
                return self._composite

    def warm_seal(self) -> None:
        """Pre-compile (and once-execute) the fill-bounded seal program
        for every pow2 bucket up to mem_rows — log2-many variants.
        Serving deployments call this once at startup so no publish ever
        pays an XLA compile mid-query (a cold bucket otherwise lands its
        compile time in some session's time-to-first-result). The step
        cache is shared across groups, so later groups replay compiled
        programs (one device execution each, no new traces)."""
        for g in self.groups:
            g.warm_seal()

    def warm_compaction(self) -> None:
        """Pre-compile (and once-execute) every compaction program —
        minor flush, incremental fold step, full major — so no later
        background increment or blocking major pays an XLA compile (a
        cold fold program otherwise lands its whole compile time inside
        one \"bounded\" increment). Runs the real programs on each
        group's current state: anything staged gets drained exactly like
        compact(), and is attributed the same way; on a drained plane
        all three are device no-ops."""
        for g in self.groups:
            g.warm_compaction()

    def has_unfolded(self) -> bool:
        """True when ANY group's memtables or run slots hold rows — i.e.
        compact() would actually fold something. Exact from the host-side
        fill/run mirrors: zero device syncs, so the serve plane's
        background compactor can poll it from its idle loop for free."""
        return any(g.has_unfolded() for g in self.groups)

    def fold_debt(self) -> int:
        """Deepest run-slot usage across ALL tablets of ALL groups (host
        mirrors, free): how close ingest is to tripping a blocking major
        (at max_runs). The background compactor folds urgently above its
        debt threshold and otherwise waits for a sustained idle window —
        a major costs seconds of device time at scale, so WHEN it runs
        is the whole game."""
        return max(g.fold_debt() for g in self.groups)

    def compact(self, source: str = "explicit") -> int:
        """Batched background fold of EVERY group: drain memtables into
        runs (minor) and runs into the base (major) for every family.
        This — plus the threshold-driven majors ingest itself trips — is
        the ONLY place runs fold into the base; publish() never does.
        Call it off the query path (the serve plane's
        BackgroundCompactor, an idle writer) to keep run counts low;
        queries stay exact either way, the fold only moves where rows
        live. No-op (and keeps the published-view caches) when there is
        nothing to fold.

        `source` attributes the fold in telemetry()["fold_events"]
        (see __init__); returns the number of minor+major passes run
        summed over groups (0 for the no-op), so callers like the
        compactor can count real folds without a telemetry round trip."""
        return sum(g.compact(source) for g in self.groups)

    def compact_step(self, source: str = "explicit") -> int:
        """ONE bounded increment of compaction — the preemptible unit the
        serve plane's BackgroundCompactor interleaves between session
        turns. The MOST-INDEBTED group is picked (deepest run-slot
        usage, ties broken toward staged memtable rows then lower group
        id) and exactly one device program runs under ONLY that group's
        lock — a fold increment never stalls writers on the other G-1
        groups. Per group the increment is the same preemptible unit as
        before:

          * run slots occupied  -> fold every tablet's TOP run slot into
            its base (one 2-way O(capacity + mem_rows) merge per family,
            vs compact()'s all-runs k-way fold),
          * else memtable rows  -> one minor flush (memtables -> a run
            slot; the next calls fold it),
          * else                -> no-op, return 0.

        Any prefix of increments leaves a fully consistent LSM (base +
        fewer runs) that every dist_query read primitive already handles
        — an interrupted major is just a database with lower fold debt,
        so a fresh query can preempt between ANY two increments and
        still read exact results. Calling it until 0 is equivalent to
        compact() (per-tablet multiset agreement; equal-key order may
        differ, which no query primitive observes — asserted against the
        numpy oracle in tests). Returns 1 when an increment ran, else 0;
        increments are attributed to fold_events[source] like compact()
        passes."""
        if self.n_groups == 1:
            return self.groups[0].compact_step(source)
        # Debt signals are read per group under its own lock; the pick can
        # race a concurrent writer, so each candidate re-checks under its
        # lock (compact_step returns 0 if its group drained meanwhile) and
        # the scan falls through to the next-most-indebted group.
        ranked = sorted(
            self.groups,
            key=lambda g: (g.fold_debt(), g.has_unfolded()),
            reverse=True,
        )
        for g in ranked:
            if g.compact_step(source):
                return 1
        return 0

    def record_session(self, session_id: int, stats: Dict[str, float]) -> None:
        """Serve-plane hook: a QuerySession reports its telemetry (batches
        served, time-to-first-result, queue-wait seconds, ...) into the
        plane, so clients of the query-serving plane and ingest writers
        surface through ONE structure — telemetry()["sessions"] next to
        ["blocked_seconds_per_writer"]. Guarded by the facade's meta
        lock, NOT any group lock: session merges stay race-free no matter
        which groups concurrent turns touch. Bounded: only the most
        recent 1024 sessions are retained (insertion order), so
        per-connection sessions on a long-lived service don't grow the
        plane without limit."""
        with self._meta_lock.hold("bookkeeping"):
            self.session_stats.pop(int(session_id), None)  # refresh position
            self.session_stats[int(session_id)] = dict(stats)
            while len(self.session_stats) > 1024:
                self.session_stats.pop(next(iter(self.session_stats)))

    def _update_tablet_gauges(self) -> None:
        """Snapshot the groups' exact per-tablet host mirrors into the
        plane registry gauges (labels = GLOBAL tablet id). Zero device
        syncs: the mirrors are maintained in lockstep with the device
        programs, and this runs only at publish()/telemetry() boundaries."""
        for g in self.groups:
            rows, minor, major = g.counter_mirrors()
            for i in range(len(rows)):
                t = g.t0 + i
                self._m_tab_rows.set(float(rows[i]), tablet=t)
                self._m_tab_minor.set(float(minor[i]), tablet=t)
                self._m_tab_major.set(float(major[i]), tablet=t)

    def telemetry(self) -> Dict[str, np.ndarray]:
        """Per-tablet device counters (the paper's backpressure signals)
        in GLOBAL tablet order (groups own contiguous ranges, so
        per-group arrays concatenate in group order), plus per-writer
        blocked-seconds (the §IV-A per-client curve — the per-writer
        cells are plane-shared, so they sum to the scalar even when one
        writer's waits split across several groups).

        Since the observability PR the scalar counters here are views of
        the plane's metrics registry (`self.metrics`); this dict remains
        the stable legacy surface, and repro.obs.metrics_snapshot() sees
        the same cells without a device sync."""
        parts = [g.telemetry_arrays() for g in self.groups]
        out: Dict[str, np.ndarray] = {
            name: np.concatenate([p[name] for p in parts]) for name in parts[0]
        }
        out["blocked_seconds"] = np.float64(self.blocked_seconds)
        out["blocked_seconds_per_writer"] = dict(self.blocked_by_writer)
        # One reporting structure for both planes: ingest writers
        # above, serve-plane query sessions + fold attribution below.
        with self._meta_lock.hold("bookkeeping"):
            out["sessions"] = {k: dict(v) for k, v in self.session_stats.items()}
        out["fold_events"] = dict(self.fold_events)
        # Snapshot-aliasing counters: level generations plus how many
        # publishes re-ran vs aliased the seal sort (flat seal_events
        # across fold-only increments == no per-increment device
        # work, the acceptance bar for bounded-stall compaction).
        if self.n_groups == 1:
            out["level_gen"] = self.groups[0].gen_snapshot()
        else:
            out["level_gen"] = {
                f"g{g.gid}": g.gen_snapshot() for g in self.groups
            }
        out["seal_events"] = int(self.seal_events)
        out["seal_reuses"] = int(self.seal_reuses)
        self._update_tablet_gauges()
        return out


class DistBatchWriter(BatchWriter):
    """Client-side ingest writer for the device plane (paper §II: one
    BatchWriter per parallel ingest client). Buffers parsed events exactly
    like the host BatchWriter; a flush encodes via the store's dictionaries,
    shards by row hash, and appends through the plane — the row hash picks
    a GLOBAL tablet, whose owning TabletGroup's lock is the only one the
    append takes, so writers whose hashes land on disjoint groups proceed
    concurrently; a flush still blocks while a major compaction it
    tripped drains, which is the measured backpressure.

    writer_id keys the plane's per-writer blocked-seconds telemetry (and
    salts the row hash); when omitted, each writer gets a fresh unique id,
    so parallel clients never collapse into one telemetry bucket."""

    _next_id = itertools.count()

    def __init__(
        self,
        store,
        plane: DistIngestPlane,
        batch_rows: int = 4096,
        metrics: Optional[IngestMetrics] = None,
        writer_id: Optional[int] = None,
    ):
        super().__init__(store, batch_rows=batch_rows, metrics=metrics)
        self.plane = plane
        if writer_id is None:
            writer_id = next(DistBatchWriter._next_id)
        self._writer_id = np.int64(writer_id)
        self._count = 0

    def _write(self, ts: np.ndarray, values) -> float:
        ts = np.asarray(ts, dtype=np.int64)
        if np.any(ts < 0) or np.any(ts > keypack.TS_MAX):
            # Same contract as EventStore.ingest_encoded — out-of-range
            # timestamps must not silently wrap into negative rev_ts.
            raise ValueError("timestamp out of 30-bit store range")
        cols = self.store.encode_events(ts, values)
        n = len(ts)
        # Row hash decides the tablet: content + per-writer nonce, so
        # identical events still spread uniformly (the paper's random
        # sharding; shard id is implicit in tablet choice here).
        nonce = np.arange(self._count, self._count + n, dtype=np.int64)
        self._count += n
        h = keypack.short_hash(
            *(cols[:, j] for j in range(cols.shape[1])), ts, nonce, self._writer_id
        )
        tab = (h % self.plane.n_tablets).astype(np.int32)
        rts = keypack.rev_ts(np.asarray(ts, np.int64)).astype(np.int32)
        return self.plane.ingest(rts, cols, tab, writer_id=int(self._writer_id))


def check_tablet_guidance(n_tablets: int, n_writers: int) -> bool:
    """Paper sizing guidance, lifted to the mesh: tablet count at least
    half the parallel writer count (the shard-vs-client rule, one home)."""
    return check_shard_guidance(n_tablets, n_writers)
