"""Query processor — executes planned, adaptively batched queries.

Paper §III: queries specify (event table, time range, optional column
projection, optional filter syntax tree). Execution composes:

  plan      (planner.py: index scans vs tablet filtering)
  batching  (batching.py: Algs 1-2 over the time range)
  scans     (scan.py + kernels: index lookups, range scans, filters)

The four experimental schemes of §IV-B map to flags:
  Scan          use_index=False, batched=False
  Batched Scan  use_index=False, batched=True
  Index         use_index=True,  batched=False
  Batched Index use_index=True,  batched=True   (the paper's winner)

A fifth, beyond the paper: Combine Scan (`aggregate=AggregateSpec(...)`) —
the server-side iterator stack's terminal combiner. Instead of shipping
matching rows, each batch runs the fused filter+combine kernel
(kernels/combine_scan) and yields per-group partial aggregates
(AggregateBlocks); the client merge is over group cardinality, not row
cardinality. "Count events per src_ip per hour" runs at scan speed and
returns kilobytes.

Results stream to the caller as RowBlocks per (batch, shard) — matching the
BatchScanner's unordered-across-shards / newest-first-within-shard
semantics. Responsiveness metrics (time to 1st/100th/1000th row) are
measured by the benchmark harness around this iterator.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .batching import DEFAULT_K0, AdaptiveBatcher, HitRateTracker
from .filter import Node, TrueNode, compile_tree
from .iterators import (
    AggregateResult,
    AggregateSpec,
    CombinerIterator,
    merge_aggregate_blocks,
    resolve_grouping,
)
from .planner import QueryPlan, plan_query
from .scan import RowBlock, fetch_rows_by_keys, index_scan, scan_events
from .store import EventStore
from ..kernels.filter_scan import filter_scan
from ..kernels.merge_intersect import intersect_sorted, union_sorted
from ..obs import span


@dataclass
class QueryStats:
    batches: int = 0
    rows: int = 0
    index_keys_scanned: int = 0
    rows_filtered: int = 0
    plan: Optional[QueryPlan] = None
    batch_log: List[Tuple[float, float, float, int]] = field(default_factory=list)


@dataclass
class HostBatch:
    """One adaptive batch's worth of host-path results, as stepped by a
    HostQueryRun: the batch time sub-range, its blocks (RowBlocks, or an
    AggregateBlock with aggregate=), and the matched-row count that drove
    the Alg-1 update."""

    lo: float
    hi: float
    blocks: List
    runtime: float
    rows: int


class QueryProcessor:
    def __init__(self, store: EventStore, w: float = 10.0, kernel_backend: str = "auto"):
        self.store = store
        self.w = w
        self.kernel_backend = kernel_backend
        self.hit_rates = HitRateTracker(default_rate=store.rows_per_second())

    # ----------------------------------------------------------- internals
    def _execute_range(
        self,
        plan: QueryPlan,
        t0: int,
        t1: int,
        shards: Optional[Sequence[int]] = None,
        prog=None,
        combiner: Optional[CombinerIterator] = None,
    ) -> Iterator[RowBlock]:
        """Run one (possibly partial) time range of a planned query.
        `prog`: pre-compiled residual filter program (compiled once per
        query by execute(), not per batch). `combiner`: terminal iterator
        of the server-side stack — rows become per-group aggregates."""
        store = self.store
        residual_trivial = isinstance(plan.residual, TrueNode) or plan.residual is None
        if prog is None and not residual_trivial:
            prog = compile_tree(store, plan.residual)
        if plan.mode == "filter":
            # Concatenate per-shard blocks and filter in ONE kernel
            # dispatch (adaptive batching issues many small ranges; a
            # dispatch per shard per batch dominated time-to-first-result).
            blocks = list(scan_events(store, t0, t1, shards))
            if not blocks:
                return
            if combiner is not None:
                # Fused path: residual filter + segment-combine in one
                # kernel pass — the separate filter_scan dispatch vanishes.
                keys_all = np.concatenate([b.keys for b in blocks])
                cols_all = np.concatenate([b.cols for b in blocks])
                agg = combiner.combine_rows(keys_all, cols_all)
                if agg.n:
                    yield agg
                return
            if residual_trivial:
                yield from blocks
                return
            cols_all = np.concatenate([b.cols for b in blocks])
            mask_all = filter_scan(cols_all, prog, backend=self.kernel_backend)
            off = 0
            for blk in blocks:
                mask = mask_all[off : off + blk.n]
                off += blk.n
                if mask.any():
                    yield RowBlock(blk.shard, blk.keys[mask], blk.cols[mask])
            return

        # Index mode: per shard, scan the index table for every condition,
        # combine key sets, then fetch event rows + apply the residual.
        # With a combiner, fetched rows accumulate and the residual is
        # fused into the terminal combine dispatch instead.
        fetched: List[RowBlock] = []
        shard_list = list(shards) if shards is not None else list(range(store.n_shards))
        per_cond: List[List[np.ndarray]] = []
        for cond in plan.index_conds:
            code = store.dictionaries[cond.field].lookup(cond.value)
            codes = (
                np.empty(0, np.int32) if code is None else np.asarray([code], np.int32)
            )
            per_cond.append(index_scan(store, cond.field, codes, t0, t1, shard_list))
        for si, shard in enumerate(shard_list):
            sets = [np.unique(c[si]) for c in per_cond]
            if not sets:
                continue
            if plan.combine == "union":
                keys = sets[0]
                for s in sets[1:]:
                    keys = union_sorted(keys, s)
            else:
                sets.sort(key=len)  # smallest first: cheapest intersections
                keys = sets[0]
                for s in sets[1:]:
                    if keys.size == 0:
                        break
                    keys = intersect_sorted(keys, s, backend=self.kernel_backend)
            if keys.size == 0:
                continue
            blk = fetch_rows_by_keys(store, shard, keys)
            if blk.n == 0:
                continue
            if combiner is not None:
                fetched.append(blk)
                continue
            if prog is not None:
                mask = filter_scan(blk.cols, prog, backend=self.kernel_backend)
                if not mask.any():
                    continue
                blk = RowBlock(blk.shard, blk.keys[mask], blk.cols[mask])
            yield blk
        if combiner is not None and fetched:
            keys_all = np.concatenate([b.keys for b in fetched])
            cols_all = np.concatenate([b.cols for b in fetched])
            agg = combiner.combine_rows(keys_all, cols_all)
            if agg.n:
                yield agg

    # ------------------------------------------------------------- public
    def execute(
        self,
        t_start: int,
        t_stop: int,
        tree: Optional[Node] = None,
        use_index: bool = True,
        batched: bool = True,
        stats: Optional[QueryStats] = None,
        aggregate: Optional[AggregateSpec] = None,
        _grouping=None,
    ) -> Iterator[RowBlock]:
        """Stream result RowBlocks for a query. See module docstring for the
        scheme flags. With `aggregate=AggregateSpec(...)` the server-side
        iterator stack terminates in a fused combiner and the stream yields
        AggregateBlocks (per-group partials) instead of rows. `_grouping`:
        an already-resolved grouping for `aggregate` (aggregate() passes its
        own so value tables are not rebuilt). Implemented over HostQueryRun
        (one adaptive batch per step) — the serve plane drives the run
        directly to interleave many sessions."""
        run = HostQueryRun(
            self, t_start, t_stop, tree,
            use_index=use_index, batched=batched, stats=stats,
            aggregate=aggregate, _grouping=_grouping,
        )
        yield from run.stream()

    def aggregate(
        self,
        spec: AggregateSpec,
        t_start: int,
        t_stop: int,
        tree: Optional[Node] = None,
        use_index: bool = False,
        batched: bool = True,
        stats: Optional[QueryStats] = None,
    ) -> AggregateResult:
        """Run a scan-time aggregation to completion and merge the partial
        AggregateBlocks client-side. The heavy reduction already happened
        on the server; this merge is over group cardinality only."""
        grouping = resolve_grouping(self.store, spec, t_start, t_stop)
        blocks = list(
            self.execute(
                t_start, t_stop, tree,
                use_index=use_index, batched=batched, stats=stats, aggregate=spec,
                _grouping=grouping,
            )
        )
        return merge_aggregate_blocks(grouping, blocks)

    def run_scheme(
        self, scheme: str, t_start: int, t_stop: int, tree: Optional[Node] = None, **kw
    ) -> Iterator[RowBlock]:
        """The paper's four experimental schemes by name, plus the iterator
        stack's 'combine_scan' (requires aggregate=AggregateSpec(...))."""
        flags = {
            "scan": dict(use_index=False, batched=False),
            "batched_scan": dict(use_index=False, batched=True),
            "index": dict(use_index=True, batched=False),
            "batched_index": dict(use_index=True, batched=True),
            "combine_scan": dict(use_index=False, batched=True),
        }[scheme]
        if scheme == "combine_scan" and kw.get("aggregate") is None:
            raise ValueError("combine_scan scheme requires aggregate=AggregateSpec(...)")
        return self.execute(t_start, t_stop, tree, **flags, **kw)


class HostQueryRun:
    """QueryProcessor.execute, reified: one planned host query stepped one
    adaptive batch at a time — the host twin of dist_query.QueryRun.

    The serve plane's scheduler drives host-path sessions through this
    exactly like distributed ones (fair per-batch interleaving), which is
    what makes the host path usable as the live oracle for concurrent
    dist sessions. Per-run state (plan, compiled residual program,
    combiner, batcher, stats) is all local, so any number of runs against
    one QueryProcessor step concurrently; the shared HitRateTracker is
    the only cross-run state and is thread-safe."""

    def __init__(
        self,
        qp: QueryProcessor,
        t_start: int,
        t_stop: int,
        tree: Optional[Node] = None,
        use_index: bool = True,
        batched: bool = True,
        stats: Optional[QueryStats] = None,
        aggregate: Optional[AggregateSpec] = None,
        _grouping=None,
    ):
        self.qp = qp
        self.t_start = t_start
        self.t_stop = t_stop
        self.stats = stats
        store = qp.store
        with span("query.plan", cat="query", host=True) as sp:
            self.plan = plan_query(
                store, tree, t_start, t_stop, w=qp.w, use_index=use_index
            )
            sp.set(mode=self.plan.mode)
        if stats is not None:
            stats.plan = self.plan
        # Provably empty (zero-density index condition): no scans, no
        # batching loop — the whole time range is answered from the
        # aggregate table alone.
        self._empty = self.plan.mode == "empty"
        residual_trivial = (
            isinstance(self.plan.residual, TrueNode) or self.plan.residual is None
        )
        self.prog = None if residual_trivial else compile_tree(store, self.plan.residual)
        self.combiner = None
        if aggregate is not None:
            grouping = _grouping or resolve_grouping(store, aggregate, t_start, t_stop)
            self.combiner = CombinerIterator(
                grouping, prog=self.prog, backend=qp.kernel_backend
            )
        self._single_done = False
        if batched and not self._empty:
            # Alg 2 drive loop. b0 from the per-table historical hit rate.
            self.batcher: Optional[AdaptiveBatcher] = AdaptiveBatcher(
                t_start=t_start, t_stop=t_stop, b0=qp.hit_rates.initial_b(DEFAULT_K0)
            )
        else:
            self.batcher = None

    @property
    def done(self) -> bool:
        if self._empty:
            return True
        if self.batcher is None:
            return self._single_done
        return self.batcher.done

    def stream(self):
        """Lazily yield the run's blocks to completion — execute()'s
        form. The unbatched schemes run the whole range as ONE batch, so
        they stream block-by-block as _execute_range produces them (the
        first row must not wait for the last — the paper's Table I
        metric is measured around this iterator); batched schemes yield
        per completed adaptive batch, which Alg-1 keeps small. The serve
        plane deliberately uses step() instead: one materialized batch
        is its bounded unit of device work."""
        while not self.done:
            if self.batcher is None:
                lo, hi = float(self.t_start), float(self.t_stop)
                t_begin = time.perf_counter()
                rows = 0
                for blk in self.qp._execute_range(
                    self.plan, int(lo), int(hi), prog=self.prog,
                    combiner=self.combiner,
                ):
                    rows += getattr(blk, "matched", blk.n)
                    yield blk
                self._single_done = True
                if self.stats is not None:
                    self.stats.batches += 1
                    self.stats.rows += rows
                    self.stats.batch_log.append(
                        (lo, hi, time.perf_counter() - t_begin, rows)
                    )
                return
            hb = self.step()
            if hb is not None:
                yield from hb.blocks

    def step(self) -> Optional[HostBatch]:
        """Execute the next adaptive batch and return its HostBatch; None
        once the run is done. The matched-row count drives the adaptive
        batcher: for aggregate blocks that is the rows combined, not the
        groups shipped."""
        if self.done:
            return None
        if self.batcher is None:
            lo, hi = float(self.t_start), float(self.t_stop)
        else:
            lo, hi = self.batcher.next_range()
        t_begin = time.perf_counter()
        with span("query.step", cat="query", mode=self.plan.mode, host=True) as sp:
            blocks = list(
                self.qp._execute_range(
                    self.plan, int(lo), int(hi), prog=self.prog, combiner=self.combiner
                )
            )
            sp.set(rows=sum(getattr(b, "matched", b.n) for b in blocks))
        runtime = time.perf_counter() - t_begin
        rows = sum(getattr(b, "matched", b.n) for b in blocks)
        if self.batcher is None:
            self._single_done = True
        else:
            self.batcher.update(runtime, rows)
            self.qp.hit_rates.observe(rows, hi - lo + 1)
        if self.stats is not None:
            self.stats.batches += 1
            self.stats.rows += rows
            self.stats.batch_log.append((lo, hi, runtime, rows))
        return HostBatch(float(lo), float(hi), blocks, runtime, rows)
