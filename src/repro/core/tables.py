"""Tablets: sorted columnar storage for one shard of one table.

Accumulo's tablet server keeps an in-memory map (memtable) that is flushed
to sorted ISAM files (minor compaction) and periodically merges files (major
compaction). We keep the same LSM structure — it is what produces the
paper's ingest backpressure dynamics (§IV-A: "tablet servers create
backpressure by blocking ingest processes while memory-cached entries must
be written to disk"):

    memtable  (unsorted append buffer, host)
      --flush/minor-compact-->  new SortedRun (jnp.sort on device)
    runs > max_runs
      --major-compact (BLOCKING = backpressure)--> single merged run

Scans search every run (runs are few: <= max_runs). All data-plane compute
(sort, merge, searchsorted, filter, combine) runs under jit; host Python
only orchestrates, exactly as Accumulo's Java orchestrates its iterators.
Major compaction merges with the dedicated k-way rank kernel
(kernels/merge_runs) — the inputs are already sorted, so the former
concatenate + argsort re-sort is retired.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import keypack
from ..kernels.merge_runs import merge_sorted_runs

KEY_PAD = np.iinfo(np.int64).max  # +inf key: pads sorted runs


@jax.jit
def _sort_run(keys, cols):
    """Sort a (keys, cols) batch by key — minor compaction."""
    order = jnp.argsort(keys)
    return keys[order], cols[order]


@jax.jit
def _combine_sorted(keys, vals):
    """Combiner (paper §II: 'aggregated on the server side using Accumulo's
    combiner framework'): sum vals of equal adjacent keys in a sorted run.
    Accumulates in int64 — long-running ingest must not wrap 32-bit counts.
    Returns (unique_keys_padded, summed_vals int64, n_unique)."""
    n = keys.shape[0]
    is_head = jnp.concatenate([jnp.ones((1,), bool), keys[1:] != keys[:-1]])
    seg = jnp.cumsum(is_head) - 1
    sums = jax.ops.segment_sum(vals.astype(jnp.int64), seg, num_segments=n)
    n_unique = is_head.sum()
    # Scatter unique keys to the front, pad the tail.
    idx = jnp.where(is_head, seg, n - 1)
    ukeys = jnp.full((n,), KEY_PAD, dtype=keys.dtype).at[idx].set(
        jnp.where(is_head, keys, KEY_PAD)
    )
    return ukeys, sums, n_unique


@dataclass
class SortedRun:
    """One immutable sorted file (ISAM analogue)."""

    keys: np.ndarray  # int64 [n], ascending
    cols: np.ndarray  # int32 [n, width] payload columns

    @property
    def n(self) -> int:
        return int(self.keys.shape[0])

    def range_slice(self, lo: int, hi: int) -> Tuple[int, int]:
        """Row span [a, b) with lo <= key < hi — the vectorized form of an
        Accumulo range scan inside one file."""
        a = int(np.searchsorted(self.keys, lo, side="left"))
        b = int(np.searchsorted(self.keys, hi, side="left"))
        return a, b


class Tablet:
    """One shard of one table. Thread-safe for concurrent BatchWriter
    flushes (paper: many parallel ingest clients per tablet server)."""

    def __init__(
        self,
        shard: int,
        width: int,
        flush_rows: int = 32768,
        max_runs: int = 8,
        col_dtype=np.int32,
    ):
        self.shard = shard
        self.width = width
        self.flush_rows = flush_rows
        self.max_runs = max_runs
        self.col_dtype = np.dtype(col_dtype)
        self.runs: List[SortedRun] = []
        self._mem_keys: List[np.ndarray] = []
        self._mem_cols: List[np.ndarray] = []
        self._mem_rows = 0
        self.lock = threading.Lock()
        # Telemetry for the ingest-scaling experiments.
        self.minor_compactions = 0
        self.major_compactions = 0
        self.blocked_seconds = 0.0
        self.rows_ingested = 0

    # ------------------------------------------------------------- ingest
    def insert(self, keys: np.ndarray, cols: np.ndarray) -> float:
        """Append a batch of entries. Returns seconds spent blocked on
        compaction (the backpressure signal)."""
        import time

        assert cols.shape == (keys.shape[0], self.width), (cols.shape, self.width)
        blocked = 0.0
        with self.lock:
            self._mem_keys.append(np.asarray(keys, dtype=np.int64))
            self._mem_cols.append(np.asarray(cols, dtype=self.col_dtype))
            self._mem_rows += len(keys)
            self.rows_ingested += len(keys)
            if self._mem_rows >= self.flush_rows:
                t0 = time.perf_counter()
                self._minor_compact()
                if len(self.runs) > self.max_runs:
                    # Major compaction blocks the writer that tripped it —
                    # Accumulo's backpressure, reproduced.
                    self._major_compact()
                    blocked = time.perf_counter() - t0
                    self.blocked_seconds += blocked
        return blocked

    def _minor_compact(self) -> None:
        keys = np.concatenate(self._mem_keys)
        cols = np.concatenate(self._mem_cols)
        self._mem_keys, self._mem_cols, self._mem_rows = [], [], 0
        k, c = _sort_run(keys, cols)
        self.runs.append(SortedRun(np.asarray(k), np.asarray(c)))
        self.minor_compactions += 1

    def _major_compact(self) -> None:
        k, c = merge_sorted_runs([(r.keys, r.cols) for r in self.runs])
        self.runs = [SortedRun(k, c)]
        self.major_compactions += 1

    def flush(self) -> None:
        """Force memtable to a run (used at end of ingest)."""
        with self.lock:
            if self._mem_rows:
                self._minor_compact()

    def compact(self) -> None:
        with self.lock:
            if self._mem_rows:
                self._minor_compact()
            if len(self.runs) > 1:
                self._major_compact()

    # -------------------------------------------------------------- reads
    @property
    def n_rows(self) -> int:
        with self.lock:
            return sum(r.n for r in self.runs) + self._mem_rows

    def snapshot_runs(self) -> List[SortedRun]:
        """Runs visible to a scan. Accumulo scans see flushed files plus the
        in-memory map; we flush-on-read for simplicity (scans are rare
        relative to inserts in this pipeline)."""
        with self.lock:
            if self._mem_rows:
                self._minor_compact()
            return list(self.runs)

    def scan_range(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        """All entries with lo <= key < hi, sorted by key."""
        runs = self.snapshot_runs()
        parts_k, parts_c = [], []
        for r in runs:
            a, b = r.range_slice(lo, hi)
            if b > a:
                parts_k.append(r.keys[a:b])
                parts_c.append(r.cols[a:b])
        if not parts_k:
            return (
                np.empty(0, np.int64),
                np.empty((0, self.width), self.col_dtype),
            )
        keys = np.concatenate(parts_k)
        cols = np.concatenate(parts_c)
        if len(runs) > 1:
            order = np.argsort(keys, kind="stable")
            keys, cols = keys[order], cols[order]
        return keys, cols


class AggregateTablet(Tablet):
    """Aggregate table tablet: cols = [count], int64 — aggregate counts
    accumulate for the life of the store and must not wrap at 2^31 rows.
    Major compaction additionally combines (sums) duplicate keys, matching
    Accumulo's combiner-on-compaction semantics."""

    def __init__(self, shard: int, **kw):
        kw.setdefault("col_dtype", np.int64)
        super().__init__(shard, width=1, **kw)

    def _major_compact(self) -> None:
        k, c = merge_sorted_runs([(r.keys, r.cols) for r in self.runs])
        ukeys, sums, n_unique = _combine_sorted(jnp.asarray(k), jnp.asarray(c[:, 0]))
        n = int(n_unique)
        self.runs = [
            SortedRun(
                np.asarray(ukeys)[:n],
                np.asarray(sums)[:n, None].astype(self.col_dtype),
            )
        ]
        self.major_compactions += 1

    def count_range(self, lo: int, hi: int) -> int:
        """Total count over an aggregate-key range (combines across runs +
        any not-yet-combined duplicates)."""
        _, cols = self.scan_range(lo, hi)
        return int(cols[:, 0].astype(np.int64).sum()) if cols.size else 0
