"""BatchWriter — client-side ingest batching (paper §II).

"Entries are sent to Accumulo using the BatchWriter API class, which
automatically batches and sends bulk updates to the database instance for
efficiency." Each parallel ingest worker owns one BatchWriter. The writer
buffers parsed events and flushes them to the store in bulk; flushes that
trip a tablet major compaction BLOCK the caller — that is the backpressure
the paper measures as ingest-rate variance (§IV-A).

The paper's sizing guidance is enforced here: "experiments have indicated
that N [shards] should be at least as large as half the number of parallel
client processes used for ingest" — `check_shard_guidance`.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from .store import EventStore


@dataclass
class IngestMetrics:
    """Per-writer telemetry; the benchmark harness aggregates across
    writers into the Fig 3/4 curves."""

    rows: int = 0
    bytes: int = 0
    flushes: int = 0
    blocked_seconds: float = 0.0
    flush_seconds: float = 0.0
    # (wall_time, rows_flushed) samples — the instantaneous-rate series.
    samples: List = field(default_factory=list)


def check_shard_guidance(n_shards: int, n_clients: int) -> bool:
    """Paper: N >= clients / 2."""
    return n_shards >= n_clients / 2


class BatchWriter:
    """Buffers parsed events; flushes in bulk to the sharded store."""

    def __init__(
        self,
        store: EventStore,
        batch_rows: int = 4096,
        metrics: Optional[IngestMetrics] = None,
    ):
        self.store = store
        self.batch_rows = batch_rows
        self.metrics = metrics if metrics is not None else IngestMetrics()
        self._ts: List[np.ndarray] = []
        self._vals: List[Dict[str, Sequence[str]]] = []
        self._rows = 0

    def add(self, ts: np.ndarray, values: Dict[str, Sequence[str]], nbytes: int = 0) -> None:
        """Queue a parsed batch of events (ts int seconds + field values).
        nbytes: raw input size, for MB/s accounting."""
        self._ts.append(np.asarray(ts, dtype=np.int64))
        self._vals.append(values)
        self._rows += len(ts)
        self.metrics.bytes += nbytes
        if self._rows >= self.batch_rows:
            self.flush()

    def _write(self, ts: np.ndarray, values: Dict[str, List[str]]) -> float:
        """Sink one flushed batch; returns seconds blocked on compaction.
        Subclasses (DistBatchWriter) retarget this at the device plane."""
        return self.store.ingest(ts, values)

    def flush(self) -> None:
        if not self._rows:
            return
        ts = np.concatenate(self._ts)
        merged: Dict[str, List[str]] = {}
        for v in self._vals:
            for k, vv in v.items():
                merged.setdefault(k, []).extend(vv)
        n = len(ts)
        self._ts, self._vals, self._rows = [], [], 0
        t0 = time.perf_counter()
        blocked = self._write(ts, merged)
        dt = time.perf_counter() - t0
        m = self.metrics
        m.rows += n
        m.flushes += 1
        m.blocked_seconds += blocked
        m.flush_seconds += dt
        m.samples.append((time.perf_counter(), n))

    def close(self) -> None:
        self.flush()


def rate_series(metrics_list: Sequence[IngestMetrics], bucket_s: float = 0.25):
    """Aggregate flush samples across writers into an instantaneous
    rows/sec time series (the paper's Fig 4 signal)."""
    samples = sorted(s for m in metrics_list for s in m.samples)
    if not samples:
        return np.zeros(0), np.zeros(0)
    t0 = samples[0][0]
    t_end = samples[-1][0]
    n_b = max(int((t_end - t0) / bucket_s) + 1, 1)
    rate = np.zeros(n_b)
    for t, rows in samples:
        rate[min(int((t - t0) / bucket_s), n_b - 1)] += rows
    return np.arange(n_b) * bucket_s, rate / bucket_s
