"""BatchWriter — client-side ingest batching (paper §II).

"Entries are sent to Accumulo using the BatchWriter API class, which
automatically batches and sends bulk updates to the database instance for
efficiency." Each parallel ingest worker owns one BatchWriter. The writer
buffers parsed events and flushes them to the store in bulk; flushes that
trip a tablet major compaction BLOCK the caller — that is the backpressure
the paper measures as ingest-rate variance (§IV-A).

The paper's sizing guidance is enforced here: "experiments have indicated
that N [shards] should be at least as large as half the number of parallel
client processes used for ingest" — `check_shard_guidance`.
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..obs import get_registry, span
from .store import EventStore

_writer_seq = itertools.count()


class IngestMetrics:
    """Per-writer telemetry; the benchmark harness aggregates across
    writers into the Fig 3/4 curves.

    Since the observability PR this is a *view* over counters on the
    default metrics registry (``ingest_rows_total`` etc., labelled by a
    per-instance writer id), so ``repro.obs.metrics_snapshot()`` sees
    every writer without the benches changing how they read
    ``m.rows``/``m.blocked_seconds``. Field mutation (``m.rows += n``)
    still works via property setters."""

    _FIELDS = {
        "rows": "ingest_rows_total",
        "bytes": "ingest_bytes_total",
        "flushes": "ingest_flushes_total",
        "blocked_seconds": "ingest_blocked_seconds_total",
        "flush_seconds": "ingest_flush_seconds_total",
    }

    def __init__(self) -> None:
        self._label = f"w{next(_writer_seq)}"
        reg = get_registry()
        self._counters = {f: reg.counter(n) for f, n in self._FIELDS.items()}
        # (wall_time, rows_flushed) samples — the instantaneous-rate series.
        self.samples: List = []

    def _get(self, f: str) -> float:
        return self._counters[f].value(writer=self._label)

    def _set(self, f: str, v: float) -> None:
        self._counters[f].set_value(v, writer=self._label)

    rows = property(lambda s: int(s._get("rows")), lambda s, v: s._set("rows", v))
    bytes = property(lambda s: int(s._get("bytes")), lambda s, v: s._set("bytes", v))
    flushes = property(lambda s: int(s._get("flushes")), lambda s, v: s._set("flushes", v))
    blocked_seconds = property(
        lambda s: s._get("blocked_seconds"), lambda s, v: s._set("blocked_seconds", v)
    )
    flush_seconds = property(
        lambda s: s._get("flush_seconds"), lambda s, v: s._set("flush_seconds", v)
    )

    def __repr__(self) -> str:
        return (
            f"IngestMetrics(rows={self.rows}, bytes={self.bytes}, "
            f"flushes={self.flushes}, blocked_seconds={self.blocked_seconds:.4f}, "
            f"flush_seconds={self.flush_seconds:.4f}, samples={len(self.samples)})"
        )


def check_shard_guidance(n_shards: int, n_clients: int) -> bool:
    """Paper: N >= clients / 2."""
    return n_shards >= n_clients / 2


class BatchWriter:
    """Buffers parsed events; flushes in bulk to the sharded store."""

    def __init__(
        self,
        store: EventStore,
        batch_rows: int = 4096,
        metrics: Optional[IngestMetrics] = None,
    ):
        self.store = store
        self.batch_rows = batch_rows
        self.metrics = metrics if metrics is not None else IngestMetrics()
        self._ts: List[np.ndarray] = []
        self._vals: List[Dict[str, Sequence[str]]] = []
        self._rows = 0

    def add(self, ts: np.ndarray, values: Dict[str, Sequence[str]], nbytes: int = 0) -> None:
        """Queue a parsed batch of events (ts int seconds + field values).
        nbytes: raw input size, for MB/s accounting."""
        self._ts.append(np.asarray(ts, dtype=np.int64))
        self._vals.append(values)
        self._rows += len(ts)
        self.metrics.bytes += nbytes
        if self._rows >= self.batch_rows:
            self.flush()

    def _write(self, ts: np.ndarray, values: Dict[str, List[str]]) -> float:
        """Sink one flushed batch; returns seconds blocked on compaction.
        Subclasses (DistBatchWriter) retarget this at the device plane."""
        return self.store.ingest(ts, values)

    def flush(self) -> None:
        if not self._rows:
            return
        ts = np.concatenate(self._ts)
        merged: Dict[str, List[str]] = {}
        for v in self._vals:
            for k, vv in v.items():
                merged.setdefault(k, []).extend(vv)
        n = len(ts)
        self._ts, self._vals, self._rows = [], [], 0
        t0 = time.perf_counter()
        with span("ingest.flush", cat="ingest", rows=n) as sp:
            blocked = self._write(ts, merged)
            sp.set(blocked_s=blocked)
        dt = time.perf_counter() - t0
        m = self.metrics
        m.rows += n
        m.flushes += 1
        m.blocked_seconds += blocked
        m.flush_seconds += dt
        m.samples.append((time.perf_counter(), n))

    def close(self) -> None:
        self.flush()


def rate_series(metrics_list: Sequence[IngestMetrics], bucket_s: float = 0.25):
    """Aggregate flush samples across writers into an instantaneous
    rows/sec time series (the paper's Fig 4 signal)."""
    samples = sorted(s for m in metrics_list for s in m.samples)
    if not samples:
        return np.zeros(0), np.zeros(0)
    t = np.asarray([s[0] for s in samples], dtype=np.float64)
    rows = np.asarray([s[1] for s in samples], dtype=np.float64)
    t0, t_end = t[0], t[-1]
    n_b = max(int((t_end - t0) / bucket_s) + 1, 1)
    # Half-open buckets [edge_i, edge_{i+1}): an event exactly on a
    # boundary belongs to the bucket it opens, never to both. Explicit
    # edges + searchsorted make that deterministic, where per-event
    # float division (t - t0) / bucket_s rounded inconsistently at the
    # boundaries.
    edges = t0 + bucket_s * np.arange(n_b + 1)
    idx = np.clip(np.searchsorted(edges, t, side="right") - 1, 0, n_b - 1)
    rate = np.bincount(idx, weights=rows, minlength=n_b)
    return np.arange(n_b) * bucket_s, rate / bucket_s
