"""Distributed query execution — the paper's tablet-server scan on the
production TPU mesh.

The host-side EventStore (store.py) is the single-node reference; this
module is the scale-out data plane: every device of the (data, model) mesh
acts as one tablet server holding a fixed-capacity sorted columnar tablet,
and a query executes as ONE jitted shard_map program:

    time-range restriction   sorted rev_ts -> per-tablet searchsorted
    filter                   the same postfix predicate program the
                             Pallas filter_scan kernel executes
    project + count          local; global count via psum
    top-k newest             local top-k, then a gathered cross-tablet
                             merge on the host (BatchScanner semantics:
                             unordered across tablets)
    iterator-stack combine   the server-side CombinerIterator lowered into
                             the shard_map program: per-tablet fused
                             filter + dense segment aggregation, merged
                             across tablets with psum/pmin/pmax (the
                             group-id space is dense by construction —
                             see core/iterators.py ResolvedGrouping)

RUN-AWARE READS: every read primitive searches ALL LSM LEVELS of a
published DistIngestPlane snapshot — the major-compacted base, the K
sorted-run slabs from minor compactions, and a sealed (sorted) copy of
the memtable — for all three table families. Each level is sorted, so
the same searchsorted/filter/top-k machinery applies per level and the
per-tablet partials merge device-side (scan: rev_ts-ordered top-k merge
across levels; index: postings from every level feed the
intersect/union; aggregate/density: sums across levels, duplicates only
ever fold at major compaction). This is what lets
DistIngestPlane.publish() be a metadata flip instead of an O(capacity)
re-merge: freshness costs O(delta), not O(database), per the
high-rate-ingest literature (arXiv:1406.4923).

The adaptive batcher (Algs 1-2) drives this exactly like the host path:
each batch is one device-program invocation over a time sub-range — the
paper's design, 256 tablets wide. dryrun.py lowers + compiles it on the
single-pod and multi-pod meshes as the extra `llcysa-store` cells.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from . import keypack
from .batching import AdaptiveBatcher
from .filter import FilterProgram, compile_tree
from .iterators import AggregateResult, AggregateSpec, ResolvedGrouping, resolve_grouping
from .planner import QueryPlan, plan_query
from .store import EventStore
from ..obs import OwnedLock, span

INVALID_TS = jnp.int32(-1)
_I32_MAX = np.iinfo(np.int32).max


@dataclass
class DistStore:
    """Device-resident tablet grid — the paper's three tables per source,
    snapshotted at ALL LSM levels (base + sorted runs + sealed memtable).

    Event family (always present):

    rev_ts:  (T, R) int32   base run: reversed timestamps, ascending per
                            tablet (newest first), sentinel-padded
    cols:    (T, R, F) int32 dictionary codes, pad rows carry junk codes
                            (masked by counts in every scan)
    counts:  (T,) int32     live rows in the BASE level per tablet
    run_rev_ts: (T, K, M) int32  minor-compaction sorted-run slabs
    run_cols:   (T, K, M, F) int32
    run_counts: (T, K) int32     live rows per run slot (0 = empty/stale)
    mem_rev_ts: (T, M) int32     sealed memtable: sorted snapshot taken at
    mem_cols:   (T, M, F) int32  publish() time (the only per-publish
    mem_counts: (T,) int32       device work — O(memtable), not O(base))

    T = number of tablets = n_devices * tablets_per_device (T must divide
    evenly across the mesh); R = tablet capacity. The grid is either a
    bulk replay of a host store (from_event_store) or a live snapshot of
    a DistIngestPlane (dist_ingest.publish) — the latter updates
    incrementally as writers ingest, no re-scatter and NO fold: rows
    may live at any level and every read searches them all.

    Planes that maintain the index/aggregate families additionally expose
    the same three levels per family:

    ix_keys:  (T, Ci) int64  sorted packed index keys (field|value|rev_ts)
                             — postings for one (field, value) over a time
                             range are one contiguous slice, INT64_MAX pad
    ix_counts: (T,) int32    live postings in the base per tablet
    ix_run_k / ix_run_n, ix_mem_k / ix_mem_n — run + sealed levels
    ag_keys:  (T, Ca) int64  sorted packed aggregate keys
                             (field|value|bucket), unique per tablet AT
                             THE BASE level only (duplicates fold at
                             major); run/mem levels may repeat keys and
                             readers sum across levels
    ag_vals:  (T, Ca, 1) int64 occurrence counts per aggregate key
    ag_counts: (T,) int32    live aggregate keys in the base per tablet
    ag_run_k / ag_run_c / ag_run_n, ag_mem_k / ag_mem_c / ag_mem_n
    agg_bucket_s: int        the bucketing the densities were counted at

    Index/aggregate fields are None for index-less stores (a plane built
    without indexed_fids); DistQueryProcessor then falls back to
    filter-scan. Run/mem fields are None for base-only grids (a
    from_event_store bulk replay — folded up front, nothing unfolded to
    search — hand-built stores, dry-run shapes); reads then search the
    base alone.

    COMPOSITE snapshots: a sharded DistIngestPlane (n_groups > 1)
    publishes one DistStore whose ``groups`` tuple holds the per-group
    sub-snapshots in GLOBAL tablet order (group g owns the contiguous
    range [g * T/G, (g+1) * T/G)); the level arrays here are then None
    and every read primitive fans out over the sub-stores, summing
    counts and concatenating top-k slates host-side. Each sub-store
    keeps its OWN density_cache, so the planner's memoized densities for
    an untouched group survive publishes that only re-seal busy groups
    (sub-snapshots alias across publishes when a group is clean).
    ``gens`` maps "g<i>" to that group's level-generation dict.
    """

    rev_ts: Optional[jax.Array] = None
    cols: Optional[jax.Array] = None
    counts: Optional[jax.Array] = None
    mesh: Optional[Mesh] = None
    run_rev_ts: Optional[jax.Array] = None
    run_cols: Optional[jax.Array] = None
    run_counts: Optional[jax.Array] = None
    mem_rev_ts: Optional[jax.Array] = None
    mem_cols: Optional[jax.Array] = None
    mem_counts: Optional[jax.Array] = None
    ix_keys: Optional[jax.Array] = None
    ix_counts: Optional[jax.Array] = None
    ix_run_k: Optional[jax.Array] = None
    ix_run_n: Optional[jax.Array] = None
    ix_mem_k: Optional[jax.Array] = None
    ix_mem_n: Optional[jax.Array] = None
    ag_keys: Optional[jax.Array] = None
    ag_vals: Optional[jax.Array] = None
    ag_counts: Optional[jax.Array] = None
    ag_run_k: Optional[jax.Array] = None
    ag_run_c: Optional[jax.Array] = None
    ag_run_n: Optional[jax.Array] = None
    ag_mem_k: Optional[jax.Array] = None
    ag_mem_c: Optional[jax.Array] = None
    ag_mem_n: Optional[jax.Array] = None
    agg_bucket_s: Optional[int] = None
    # Level-generation tags at publish time ({"mem","runs","base"}):
    # which LSM levels this snapshot's buffers came from. Two snapshots
    # sharing a generation for a level ALIAS that level's arrays (the
    # plane's publish reuses untouched buffers across compact_step
    # increments instead of re-copying) — tests assert the identity.
    # None for hand-built / base-only stores. Composite snapshots nest
    # per-group dicts under "g<i>" keys instead.
    gens: Optional[Dict[str, object]] = None
    # Per-group sub-snapshots of a sharded plane publish (None for a
    # single-group or hand-built store): global tablet order, each a
    # complete single-group DistStore that reads recurse into.
    groups: Optional[Tuple["DistStore", ...]] = None
    # Per-snapshot memo for planner density reads (_agg_count_on): a
    # published snapshot is immutable, so a density within it never goes
    # stale; the memo dies with the snapshot at the next publish flip.
    density_cache: Dict[Tuple, int] = field(default_factory=dict, repr=False)

    @property
    def is_composite(self) -> bool:
        return self.groups is not None

    @property
    def n_tablets(self) -> int:
        if self.groups is not None:
            return sum(g.n_tablets for g in self.groups)
        return self.rev_ts.shape[0]

    @property
    def capacity(self) -> int:
        if self.groups is not None:
            return self.groups[0].capacity
        return self.rev_ts.shape[1]

    @property
    def has_index(self) -> bool:
        if self.groups is not None:
            return self.groups[0].has_index
        return self.ix_keys is not None

    @property
    def has_runs(self) -> bool:
        """True when the snapshot carries run + sealed-memtable levels
        (a plane publish); False for base-only grids."""
        if self.groups is not None:
            return self.groups[0].has_runs
        return self.run_rev_ts is not None


def tablet_specs(mesh: Mesh) -> Dict[str, P]:
    """Tablets shard over ALL mesh axes (every chip is a tablet server)."""
    axes = tuple(mesh.axis_names)
    return {
        "rev_ts": P(axes, None),
        "cols": P(axes, None, None),
        "counts": P(axes),
    }


def _ev_level_specs(axes) -> Tuple[P, ...]:
    """Partition specs for the event family's run + sealed-mem levels:
    (run_rev_ts, run_cols, run_counts, mem_rev_ts, mem_cols, mem_counts)."""
    return (
        P(axes, None, None), P(axes, None, None, None), P(axes, None),
        P(axes, None), P(axes, None, None), P(axes),
    )


def _ix_level_specs(axes) -> Tuple[P, ...]:
    """(ix_run_k, ix_run_n, ix_mem_k, ix_mem_n)."""
    return (P(axes, None, None), P(axes, None), P(axes, None), P(axes))


def _ag_level_specs(axes) -> Tuple[P, ...]:
    """(ag_run_k, ag_run_c, ag_run_n, ag_mem_k, ag_mem_c, ag_mem_n)."""
    return (
        P(axes, None, None), P(axes, None, None, None), P(axes, None),
        P(axes, None), P(axes, None, None), P(axes),
    )


def dist_store_shapes(mesh: Mesh, rows_per_tablet: int, n_fields: int, tablets_per_device: int = 1):
    """Abstract ShapeDtypeStructs for the dry-run (no allocation)."""
    t = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) * tablets_per_device
    return {
        "rev_ts": jax.ShapeDtypeStruct((t, rows_per_tablet), jnp.int32),
        "cols": jax.ShapeDtypeStruct((t, rows_per_tablet, n_fields), jnp.int32),
        "counts": jax.ShapeDtypeStruct((t,), jnp.int32),
    }


def from_event_store(
    store: EventStore,
    mesh: Mesh,
    capacity: Optional[int] = None,
    tablets_per_device: int = 1,
) -> DistStore:
    """Re-shard a host EventStore's event tables onto the mesh by row hash
    (the paper's uniform random sharding) — implemented as a bulk replay
    through the distributed ingest plane: the host rows stream through
    DistIngestPlane.ingest and the device-side compaction programs build
    the sorted tablets (the former host-side NumPy scatter loop is gone)."""
    from .dist_ingest import DistIngestPlane

    t = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) * tablets_per_device
    rows_k, rows_c = [], []
    for tab in store.event_tablets:
        for run in tab.snapshot_runs():
            _, rts, h = keypack.unpack_event_key(run.keys)
            rows_k.append(np.stack([rts, h], 1))
            rows_c.append(run.cols)
    if rows_k:
        rk = np.concatenate(rows_k)
        rc = np.concatenate(rows_c)
    else:
        rk = np.zeros((0, 2), np.int64)
        rc = np.zeros((0, store.schema.n_fields), np.int32)
    assign = (rk[:, 1] % t).astype(np.int64)  # hash-uniform tablet choice
    per_tablet = np.bincount(assign, minlength=t)
    cap = capacity or max(int(per_tablet.max()), 1)
    if int(per_tablet.max()) > cap:
        # An explicitly undersized capacity must fail loudly BEFORE the
        # replay: publish() no longer folds runs into the base, so the
        # device overflow counter would only trip at some later major —
        # the host-side assignment counts are exact now, use them.
        raise ValueError(
            f"tablet overflow: {int(per_tablet.max())} rows for one tablet "
            f"over capacity {cap}"
        )
    # The plane's flush triggers are exact per tablet (host-side fill
    # mirror), so fixed per-tablet buffers suffice: a tablet majors every
    # max_runs * mem_rows of ITS OWN rows — run-slab memory stays
    # O(T * max_runs * mem_rows), independent of replay size. for_store
    # binds the store's indexed fields + aggregate bucketing, so the
    # replay also builds live index postings and planner densities.
    plane = DistIngestPlane.for_store(
        store,
        mesh,
        capacity=cap,
        tablets_per_device=tablets_per_device,
        mem_rows=8192,
        max_runs=8,
        append_rows=2048,
    )
    plane.ingest(rk[:, 0].astype(np.int32), rc, assign.astype(np.int32))
    # A bulk replay is one-shot: fold everything into the base up front
    # and snapshot ONLY the base level. The replay plane's big run slabs
    # (8 slots x 8192 rows) would otherwise ride along empty in every
    # compiled read — fixed-shape level work with nothing in it. Live
    # planes (DistQueryProcessor(plane=...)) keep the full run-aware
    # snapshot; this static view has nothing unfolded to search.
    plane.compact()
    overflow = int(plane.telemetry()["overflow"].sum())
    if overflow:  # pragma: no cover — the pre-check above bounds this
        raise ValueError(f"tablet overflow: {overflow} rows over capacity {cap}")
    s = plane.state
    has_ix = len(plane.families) > 1
    return DistStore(
        rev_ts=s["ev_base_k"],
        cols=s["ev_base_c"],
        counts=s["ev_base_n"],
        mesh=mesh,
        ix_keys=s["ix_base_k"] if has_ix else None,
        ix_counts=s["ix_base_n"] if has_ix else None,
        ag_keys=s["ag_base_k"] if has_ix else None,
        ag_vals=s["ag_base_c"] if has_ix else None,
        ag_counts=s["ag_base_n"] if has_ix else None,
        agg_bucket_s=plane.agg_bucket_s if has_ix else None,
    )


def _program_eval(cols, opcodes, arg0, arg1, codesets):
    """Postfix predicate program over (R, F) codes — identical semantics
    to kernels/filter_scan (jnp form, shard-local)."""
    from ..kernels.program_eval import program_eval_rows

    return program_eval_rows(cols, opcodes, arg0, arg1, codesets)


def _merge_level_topk(rev_parts, col_parts, top_k):
    """Device-side merge of per-level top-k candidates: concatenate the
    (sentinel-padded, _I32_MAX) rev_ts slates and keep the k smallest —
    smallest rev_ts == newest row, matching per-level order."""
    all_rev = jnp.concatenate(rev_parts)
    all_cols = jnp.concatenate(col_parts)
    order = jnp.argsort(all_rev)[:top_k]
    return all_rev[order], all_cols[order]


def build_scan_step(
    mesh: Mesh,
    n_fields: int,
    prog_len: int,
    set_shape: Tuple[int, int],
    top_k: int = 128,
    runs: bool = False,
):
    """Jitted distributed scan: (store, program, t-range) -> (global count,
    per-tablet top-k newest matches). One invocation per adaptive batch.
    Each device vmaps over its local tablets (tablets_per_device may
    exceed 1 — the ingest plane's W x T sweeps size T independently of
    the mesh), then psums across the mesh.

    With runs=True the scan is RUN-AWARE: the same range-restrict +
    filter + top-k runs per LSM level (base, each sorted-run slab, the
    sealed memtable), counts sum, and the per-level top-k slates merge by
    rev_ts on device — unfolded rows are exactly as visible as the base."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)

    def tablet_scan(*args):
        if runs:
            (rev_ts, cols, counts, run_k, run_c, run_n, mem_k, mem_c, mem_n,
             opcodes, arg0, arg1, codesets, rts_lo, rts_hi) = args
        else:
            (rev_ts, cols, counts,
             opcodes, arg0, arg1, codesets, rts_lo, rts_hi) = args

        def one(rev_l, cols_l, n, *lv):
            def level(rev, cl, nn):
                r = rev.shape[0]
                # Range restriction on sorted rev_ts: [lo, hi) via
                # searchsorted; nn masks pad rows AND stale run slots.
                a = jnp.searchsorted(rev, rts_lo, side="left")
                b = jnp.searchsorted(rev, rts_hi, side="left")
                idx = jnp.arange(r, dtype=jnp.int32)
                in_range = (idx >= a) & (idx < b) & (idx < nn)
                hit = _program_eval(cl, opcodes, arg0, arg1, codesets) & in_range
                count = hit.sum(dtype=jnp.int32)
                # Top-k newest matches (smallest rev_ts == newest).
                rank = jnp.where(hit, idx, r)
                top = jnp.sort(rank)[:top_k]
                valid = top < r
                safe = jnp.clip(top, 0, r - 1)
                out_rev = jnp.where(valid, rev[safe], jnp.int32(_I32_MAX))
                out_cols = jnp.where(valid[:, None], cl[safe], -1)
                return count, out_rev, out_cols

            count, out_rev, out_cols = level(rev_l, cols_l, n)
            if runs:
                rk, rc, rn, mk, mc, mn = lv
                rcnt, rrev, rcols = jax.vmap(level)(rk, rc, rn)
                mcnt, mrev, mcols = level(mk, mc, mn)
                count = count + rcnt.sum(dtype=jnp.int32) + mcnt
                out_rev, out_cols = _merge_level_topk(
                    [out_rev, rrev.reshape(-1), mrev],
                    [out_cols, rcols.reshape(-1, out_cols.shape[1]), mcols],
                    top_k,
                )
            out_ts = jnp.where(out_rev < jnp.int32(_I32_MAX), out_rev, INVALID_TS)
            return count, out_ts, out_cols

        if runs:
            count_l, out_ts, out_cols = jax.vmap(one)(
                rev_ts, cols, counts, run_k, run_c, run_n, mem_k, mem_c, mem_n
            )
        else:
            count_l, out_ts, out_cols = jax.vmap(one)(rev_ts, cols, counts)
        total = jax.lax.psum(count_l.sum(dtype=jnp.int32), axes)
        return total, out_ts, out_cols

    in_specs = (specs["rev_ts"], specs["cols"], specs["counts"])
    if runs:
        in_specs += _ev_level_specs(axes)
    in_specs += (
        P(None), P(None), P(None), P(None, None),  # program: replicated
        P(), P(),
    )
    smapped = shard_map(
        tablet_scan,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P(axes, None), P(axes, None, None)),
        check_rep=False,
    )
    return jax.jit(smapped)


def _segment_aggregate(r_rev, r_cols, hit, fids, strides, n_groups, bucket_s,
                       bucket_lo, op, value_fid, value_table, identity):
    """Fused dense segment aggregation over one slab of gathered rows —
    the CombinerIterator body shared by the scan-time and index-time
    aggregate steps. Junk codes on masked rows clamp into range; their
    contribution is the identity anyway."""
    r = r_rev.shape[0]
    gid = jnp.zeros((r,), jnp.int32)
    for fid, stride in zip(fids, strides):
        gid = gid + r_cols[:, fid] * jnp.int32(stride)
    if bucket_s is not None:
        ts_l = jnp.int32(keypack.TS_MAX) - r_rev
        gid = gid + ts_l // jnp.int32(bucket_s) - bucket_lo
    gid = jnp.clip(gid, 0, n_groups - 1)
    if value_fid is not None:
        codes = jnp.clip(r_cols[:, value_fid], 0, value_table.shape[0] - 1)
        val = value_table[codes]
    else:
        val = jnp.ones((r,), jnp.int32)
    if op in ("count", "sum"):
        # Sums accumulate in int64, matching the host iterator stack — a
        # tablet of large int32 values must not wrap before the psum
        # (min/max are order statistics).
        contrib = jnp.where(hit, val.astype(jnp.int64), jnp.int64(identity))
        aggs = jax.ops.segment_sum(contrib, gid, num_segments=n_groups)
    elif op == "min":
        contrib = jnp.where(hit, val, jnp.int32(identity))
        aggs = jax.ops.segment_min(contrib, gid, num_segments=n_groups)
    else:
        contrib = jnp.where(hit, val, jnp.int32(identity))
        aggs = jax.ops.segment_max(contrib, gid, num_segments=n_groups)
    cnts = jax.ops.segment_sum(hit.astype(jnp.int64), gid, num_segments=n_groups)
    return aggs, cnts


def _fold_runs_axis(raggs, rcnts, op):
    """Fold the leading run-slot axis of vmapped per-run (aggs, cnts)
    partials into one level part — same dispatch as the cross-level merge
    (counts always add; only the aggregate folds per op)."""
    if op in ("count", "sum"):
        return raggs.sum(axis=0), rcnts.sum(axis=0)
    if op == "min":
        return raggs.min(axis=0), rcnts.sum(axis=0)
    return raggs.max(axis=0), rcnts.sum(axis=0)


def _combine_level_aggs(parts, op):
    """Merge per-level (aggs, cnts) partials: rows are disjoint across
    levels, so sum/count add and min/max fold elementwise."""
    aggs_parts = [a for a, _ in parts]
    cnts = sum(c for _, c in parts)
    if op in ("count", "sum"):
        aggs = sum(aggs_parts)
    elif op == "min":
        aggs = aggs_parts[0]
        for a in aggs_parts[1:]:
            aggs = jnp.minimum(aggs, a)
    else:
        aggs = aggs_parts[0]
        for a in aggs_parts[1:]:
            aggs = jnp.maximum(aggs, a)
    return aggs, cnts


def build_aggregate_step(
    mesh: Mesh,
    fids: Tuple[int, ...],
    strides: Tuple[int, ...],
    n_groups: int,
    n_buckets: int,
    bucket_s: Optional[int],
    op: str,
    value_fid: Optional[int],
    runs: bool = False,
):
    """Jitted distributed scan-time aggregation: the iterator stack's
    terminal CombinerIterator lowered into the mesh program. Each tablet
    evaluates the fused filter + dense segment aggregation locally — per
    LSM level when runs=True, partials summed across levels (rows are
    disjoint between levels; the agg FAMILY only folds duplicates at
    major, but this step aggregates event rows, which never duplicate) —
    then the dense group-id space (mixed-radix codes x time buckets, see
    ResolvedGrouping) makes the cross-tablet merge a single psum (sum /
    count) or pmin/pmax — no gather of raw rows ever happens."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)
    int32_max = jnp.iinfo(jnp.int32).max
    int32_min = jnp.iinfo(jnp.int32).min
    identity = {"count": 0, "sum": 0, "min": int32_max, "max": int32_min}[op]

    def tablet_agg(*args):
        if runs:
            (rev_ts, cols, counts, run_k, run_c, run_n, mem_k, mem_c, mem_n,
             opcodes, arg0, arg1, codesets, value_table,
             rts_lo, rts_hi, bucket_lo) = args
        else:
            (rev_ts, cols, counts,
             opcodes, arg0, arg1, codesets, value_table,
             rts_lo, rts_hi, bucket_lo) = args

        def one(rev_l, cols_l, n, *lv):
            def level(rev, cl, nn):
                r = rev.shape[0]
                a = jnp.searchsorted(rev, rts_lo, side="left")
                b = jnp.searchsorted(rev, rts_hi, side="left")
                idx = jnp.arange(r, dtype=jnp.int32)
                in_range = (idx >= a) & (idx < b) & (idx < nn)
                hit = _program_eval(cl, opcodes, arg0, arg1, codesets) & in_range
                return _segment_aggregate(
                    rev, cl, hit, fids, strides, n_groups, bucket_s,
                    bucket_lo, op, value_fid, value_table, identity,
                )

            parts = [level(rev_l, cols_l, n)]
            if runs:
                rk, rc, rn, mk, mc, mn = lv
                raggs, rcnts = jax.vmap(level)(rk, rc, rn)
                parts.append(_fold_runs_axis(raggs, rcnts, op))
                parts.append(level(mk, mc, mn))
            return _combine_level_aggs(parts, op)

        # Local tablets first (vmap + reduce), then one mesh collective.
        if runs:
            aggs_l, cnts_l = jax.vmap(one)(
                rev_ts, cols, counts, run_k, run_c, run_n, mem_k, mem_c, mem_n
            )
        else:
            aggs_l, cnts_l = jax.vmap(one)(rev_ts, cols, counts)
        if op in ("count", "sum"):
            aggs = jax.lax.psum(aggs_l.sum(axis=0), axes)
        elif op == "min":
            aggs = jax.lax.pmin(aggs_l.min(axis=0), axes)
        else:
            aggs = jax.lax.pmax(aggs_l.max(axis=0), axes)
        cnts = jax.lax.psum(cnts_l.sum(axis=0), axes)
        return aggs, cnts

    in_specs = (specs["rev_ts"], specs["cols"], specs["counts"])
    if runs:
        in_specs += _ev_level_specs(axes)
    in_specs += (
        P(None), P(None), P(None), P(None, None),  # program: replicated
        P(None),  # value table: replicated
        P(), P(), P(),
    )
    smapped = shard_map(
        tablet_agg,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(None), P(None)),
        check_rep=False,
    )
    return jax.jit(smapped)


def _posting_slabs(ik_l, ix_lv, cond_lo, cond_hi, n_conds, max_postings, runs):
    """Per-condition candidate rev_ts slabs from EVERY index level.

    For one tablet: the postings for condition i over the batch's rev_ts
    range are one contiguous slice of each sorted index level (two binary
    searches per level, clamped by the level's live count — run slots can
    hold stale rows past run_n after a major). Each level contributes up
    to min(max_postings, level size) newest-first rev_ts values (a small
    level can't yield more postings than it holds); the per-level slates
    sort into one slab per condition. Returns (slabs (n_conds, S),
    overflow) where S sums the per-level caps."""

    def posting(ik, nn, lo_i, hi_i):
        ci = ik.shape[0]
        cap = min(max_postings, ci)  # static per level
        a = jnp.minimum(jnp.searchsorted(ik, lo_i, side="left").astype(jnp.int32), nn)
        b = jnp.minimum(jnp.searchsorted(ik, hi_i, side="left").astype(jnp.int32), nn)
        cnt = b - a
        j = jnp.arange(cap, dtype=jnp.int32)
        valid = j < cnt
        kk = ik[jnp.clip(a + j, 0, ci - 1)]
        rts = jnp.where(
            valid, (kk & jnp.int64(keypack.TS_MAX)).astype(jnp.int32),
            jnp.int32(_I32_MAX),
        )
        return rts, jnp.maximum(cnt - jnp.int32(cap), 0)

    def cond_slab(i):
        s0, over = posting(ik_l, jnp.int32(ik_l.shape[0]), cond_lo[i], cond_hi[i])
        if runs:
            xrk, xrn, xmk, xmn = ix_lv
            sr, orr = jax.vmap(lambda k, nr: posting(k, nr, cond_lo[i], cond_hi[i]))(
                xrk, xrn
            )
            sm, om = posting(xmk, xmn, cond_lo[i], cond_hi[i])
            slab = jnp.sort(jnp.concatenate([s0, sr.reshape(-1), sm]))
            over = over + orr.sum() + om
        else:
            slab = s0
        return slab, over

    slabs, over = jax.vmap(cond_slab)(jnp.arange(n_conds, dtype=jnp.int32))
    return slabs, over.sum()


def _combine_postings(slabs, combine, n_conds):
    """Device-side key-set combine (paper Fig 2): k-way intersect via
    merge_intersect membership searches (AND) or a sorted merge (OR).
    Returns (cand sorted ascending, live mask) — duplicates masked out,
    since equal rev_ts candidates expand to the same base rows."""
    from ..kernels.merge_intersect import member_mask_keys

    if combine == "intersect":
        cand = slabs[0]
        keep = cand < jnp.int32(_I32_MAX)
        for i in range(1, n_conds):
            keep &= member_mask_keys(cand, slabs[i])
        cand = jnp.sort(jnp.where(keep, cand, jnp.int32(_I32_MAX)))
    else:
        cand = jnp.sort(slabs.reshape(-1))
    is_dup = jnp.concatenate([jnp.zeros((1,), bool), cand[1:] == cand[:-1]])
    live = (cand < jnp.int32(_I32_MAX)) & ~is_dup
    return cand, live


def _expand_levels(consume, cand, live, rev_l, cols_l, ev_lv, max_rows, runs):
    """Expand the candidate rev_ts set against EVERY event level and feed
    each level's gathered row slab to `consume(r_rev, r_cols, valid_m)`.

    Per level: candidate j covers rows [lo_pos[j], hi_pos[j]) by binary
    search (clamped by the level's live count — stale run slots), and the
    prefix-sum expansion maps output slot m back through one binary
    search; rows come out ascending in rev_ts (newest first). The slab is
    min(max_rows, level size) — a run or sealed-mem level can never yield
    more rows than it holds, so the compiled gather + predicate work per
    small level is bounded by the level, not the global cap. Returns
    (outs, totals, truncs), each as (base, runs | None, mem | None) with
    runs carrying a leading K axis — the caller merges the outs and sums
    totals/truncs."""
    cc = cand.shape[0]

    def expand(rev, cl, nn):
        r = rev.shape[0]
        cap = min(max_rows, r)  # static per level
        lo_pos = jnp.minimum(
            jnp.searchsorted(rev, cand, side="left").astype(jnp.int32), nn
        )
        hi_pos = jnp.minimum(
            jnp.searchsorted(rev, cand, side="right").astype(jnp.int32), nn
        )
        cnt_rows = jnp.where(live, hi_pos - lo_pos, 0)
        offs = jnp.cumsum(cnt_rows)
        total = offs[-1]
        start = offs - cnt_rows
        m = jnp.arange(cap, dtype=jnp.int32)
        j = jnp.searchsorted(offs, m, side="right").astype(jnp.int32)
        jc = jnp.clip(j, 0, cc - 1)
        row_idx = lo_pos[jc] + (m - start[jc])
        valid_m = m < total
        safe = jnp.clip(row_idx, 0, r - 1)
        r_rev = jnp.where(valid_m, rev[safe], jnp.int32(_I32_MAX))
        r_cols = jnp.where(valid_m[:, None], cl[safe], -1)
        trunc = jnp.maximum(total - jnp.int32(cap), 0)
        return consume(r_rev, r_cols, valid_m), total, trunc

    base_out, base_total, base_trunc = expand(
        rev_l, cols_l, jnp.int32(rev_l.shape[0])
    )
    if not runs:
        return (base_out, None, None), (base_total, None, None), (base_trunc, None, None)
    rk, rc, rn, mk, mc, mn = ev_lv
    runs_out, runs_total, runs_trunc = jax.vmap(expand)(rk, rc, rn)
    mem_out, mem_total, mem_trunc = expand(mk, mc, mn)
    return (
        (base_out, runs_out, mem_out),
        (base_total, runs_total, mem_total),
        (base_trunc, runs_trunc, mem_trunc),
    )


def _sum_levels(parts):
    """Sum a (base, runs | None, mem | None) scalar triple — runs carries
    the K axis."""
    base, run_part, mem_part = parts
    total = base
    if run_part is not None:
        total = total + run_part.sum()
    if mem_part is not None:
        total = total + mem_part
    return total


def build_index_step(
    mesh: Mesh,
    n_conds: int,
    combine: str,
    prog_len: int,
    set_shape: Tuple[int, int],
    top_k: int = 128,
    max_postings: int = 2048,
    max_rows: int = 4096,
    runs: bool = False,
):
    """Jitted distributed index scan — the paper's winning batched-index
    scheme lowered to the mesh (Fig 2: index lookups -> key-set combine ->
    row fetch -> residual filter, all device-side), RUN-AWARE: postings
    come from every index level (base + run slabs + sealed memtable) and
    candidates expand against every event level, so unfolded rows are
    index-visible with no fold at publish.

    Per tablet, per condition, per level: the postings for (field, value)
    over the batch's rev_ts range are ONE contiguous slice of that sorted
    level (two binary searches), gathered into a fixed max_postings slab;
    the per-level slates sort into one slab per condition. The slabs
    combine device-side — k-way intersect via kernels/merge_intersect
    membership searches (AND), or a sorted merge (OR). Candidate rev_ts
    values then expand to rows of each event level by binary search +
    prefix-sum expansion, and the predicate program runs ONLY on the
    gathered candidate rows (max_rows per level) — never on the full
    tablet, which is the whole latency win over filter-scan.

    Correctness does not rest on the index: the FULL query tree re-checks
    every candidate row, so rev_ts collisions between distinct rows cost a
    wasted candidate, never a wrong result (and the ix family's
    dedup-at-major never loses a row for the same reason). Slab overflow
    is reported in the `truncated` output; the executor falls back to the
    exact filter-scan step for that batch (adaptive batching keeps
    per-batch result sets small, so this is rare).

    Returns (global_count, per-tablet top-k (ts, cols), truncated,
    candidate_rows) — the last is the diagnostic 'index entries actually
    used' count (psum'd)."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)

    # Base slabs are ALWAYS sentinel-padded past *_base_n (init, merges,
    # and non-donated majors all preserve it) and every probe key is below
    # the sentinel, so base binary searches never land in the pad tail.
    # Run slots DO hold stale rows past run_n after a major — the level
    # helpers clamp by the live counts.
    def tablet_ix(*args):
        if runs:
            (rev_ts, cols, ix_keys,
             run_k, run_c, run_n, mem_k, mem_c, mem_n,
             ix_run_k, ix_run_n, ix_mem_k, ix_mem_n,
             opcodes, arg0, arg1, codesets, cond_lo, cond_hi) = args
        else:
            (rev_ts, cols, ix_keys,
             opcodes, arg0, arg1, codesets, cond_lo, cond_hi) = args

        def one(rev_l, cols_l, ik_l, *lv):
            ev_lv, ix_lv = (lv[:6], lv[6:]) if runs else (None, None)
            slabs, post_over = _posting_slabs(
                ik_l, ix_lv, cond_lo, cond_hi, n_conds, max_postings, runs
            )
            cand, live = _combine_postings(slabs, combine, n_conds)

            def consume(r_rev, r_cols, valid_m):
                # Exactness: the FULL tree re-checks candidates (residual
                # AND indexed conditions), so over-approximate candidate
                # sets are filtered here, at candidate cardinality.
                n = r_rev.shape[0]  # this level's slab size (<= max_rows)
                hit = _program_eval(r_cols, opcodes, arg0, arg1, codesets) & valid_m
                count = hit.sum(dtype=jnp.int32)
                m = jnp.arange(n, dtype=jnp.int32)
                rank = jnp.where(hit, m, jnp.int32(n))
                top = jnp.sort(rank)[:top_k]
                tvalid = top < n
                tsafe = jnp.clip(top, 0, n - 1)
                out_rev = jnp.where(tvalid, r_rev[tsafe], jnp.int32(_I32_MAX))
                out_cols = jnp.where(tvalid[:, None], r_cols[tsafe], -1)
                return count, out_rev, out_cols

            outs, totals, truncs = _expand_levels(
                consume, cand, live, rev_l, cols_l, ev_lv, max_rows, runs
            )
            (c0, rev0, cols0), runs_out, mem_out = outs
            count = c0
            rev_parts, col_parts = [rev0], [cols0]
            if runs:
                cr, revr, colsr = runs_out
                cm, revm, colsm = mem_out
                count = count + cr.sum(dtype=jnp.int32) + cm
                rev_parts += [revr.reshape(-1), revm]
                col_parts += [colsr.reshape(-1, cols0.shape[1]), colsm]
            out_rev, out_cols = _merge_level_topk(rev_parts, col_parts, top_k)
            out_ts = jnp.where(out_rev < jnp.int32(_I32_MAX), out_rev, INVALID_TS)
            trunc = post_over + _sum_levels(truncs)
            return count, out_ts, out_cols, trunc, _sum_levels(totals)

        if runs:
            count_l, ts_l, cols_l, trunc_l, cand_l = jax.vmap(one)(
                rev_ts, cols, ix_keys,
                run_k, run_c, run_n, mem_k, mem_c, mem_n,
                ix_run_k, ix_run_n, ix_mem_k, ix_mem_n,
            )
        else:
            count_l, ts_l, cols_l, trunc_l, cand_l = jax.vmap(one)(
                rev_ts, cols, ix_keys
            )
        total = jax.lax.psum(count_l.sum(dtype=jnp.int32), axes)
        truncated = jax.lax.psum(trunc_l.sum(dtype=jnp.int32), axes)
        candidates = jax.lax.psum(cand_l.sum(dtype=jnp.int32), axes)
        return total, ts_l, cols_l, truncated, candidates

    in_specs = (specs["rev_ts"], specs["cols"], P(axes, None))
    if runs:
        in_specs += _ev_level_specs(axes) + _ix_level_specs(axes)
    in_specs += (
        P(None), P(None), P(None), P(None, None),  # program: replicated
        P(None), P(None),  # per-condition packed key ranges
    )
    smapped = shard_map(
        tablet_ix,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P(axes, None), P(axes, None, None), P(), P()),
        check_rep=False,
    )
    return jax.jit(smapped)


def build_index_aggregate_step(
    mesh: Mesh,
    n_conds: int,
    combine: str,
    prog_len: int,
    set_shape: Tuple[int, int],
    fids: Tuple[int, ...],
    strides: Tuple[int, ...],
    n_groups: int,
    bucket_s: Optional[int],
    op: str,
    value_fid: Optional[int],
    max_postings: int = 2048,
    max_rows: int = 4096,
    runs: bool = False,
):
    """Jitted index-driven aggregation: the batched-index candidate gather
    of build_index_step feeding the CombinerIterator segment aggregation
    of build_aggregate_step — selective aggregates combine over ONLY the
    gathered candidate rows instead of filter-scanning the full tablet.
    Same exactness contract: the FULL tree re-checks every candidate, and
    slab overflow reports in `truncated` so the caller can fall back to
    the exact scan-time aggregation.

    Returns (aggs (n_groups,), cnts (n_groups,), truncated, candidates)."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)
    int32_max = jnp.iinfo(jnp.int32).max
    int32_min = jnp.iinfo(jnp.int32).min
    identity = {"count": 0, "sum": 0, "min": int32_max, "max": int32_min}[op]

    def tablet_ixagg(*args):
        if runs:
            (rev_ts, cols, ix_keys,
             run_k, run_c, run_n, mem_k, mem_c, mem_n,
             ix_run_k, ix_run_n, ix_mem_k, ix_mem_n,
             opcodes, arg0, arg1, codesets, value_table,
             cond_lo, cond_hi, bucket_lo) = args
        else:
            (rev_ts, cols, ix_keys,
             opcodes, arg0, arg1, codesets, value_table,
             cond_lo, cond_hi, bucket_lo) = args

        def one(rev_l, cols_l, ik_l, *lv):
            ev_lv, ix_lv = (lv[:6], lv[6:]) if runs else (None, None)
            slabs, post_over = _posting_slabs(
                ik_l, ix_lv, cond_lo, cond_hi, n_conds, max_postings, runs
            )
            cand, live = _combine_postings(slabs, combine, n_conds)

            def consume(r_rev, r_cols, valid_m):
                hit = _program_eval(r_cols, opcodes, arg0, arg1, codesets) & valid_m
                return _segment_aggregate(
                    r_rev, r_cols, hit, fids, strides, n_groups, bucket_s,
                    bucket_lo, op, value_fid, value_table, identity,
                )

            outs, totals, truncs = _expand_levels(
                consume, cand, live, rev_l, cols_l, ev_lv, max_rows, runs
            )
            base_out, runs_out, mem_out = outs
            parts = [base_out]
            if runs:
                raggs, rcnts = runs_out
                parts.append(_fold_runs_axis(raggs, rcnts, op))
                parts.append(mem_out)
            aggs, cnts = _combine_level_aggs(parts, op)
            trunc = post_over + _sum_levels(truncs)
            return aggs, cnts, trunc, _sum_levels(totals)

        if runs:
            aggs_l, cnts_l, trunc_l, cand_l = jax.vmap(one)(
                rev_ts, cols, ix_keys,
                run_k, run_c, run_n, mem_k, mem_c, mem_n,
                ix_run_k, ix_run_n, ix_mem_k, ix_mem_n,
            )
        else:
            aggs_l, cnts_l, trunc_l, cand_l = jax.vmap(one)(rev_ts, cols, ix_keys)
        if op in ("count", "sum"):
            aggs = jax.lax.psum(aggs_l.sum(axis=0), axes)
        elif op == "min":
            aggs = jax.lax.pmin(aggs_l.min(axis=0), axes)
        else:
            aggs = jax.lax.pmax(aggs_l.max(axis=0), axes)
        cnts = jax.lax.psum(cnts_l.sum(axis=0), axes)
        truncated = jax.lax.psum(trunc_l.sum(dtype=jnp.int32), axes)
        candidates = jax.lax.psum(cand_l.sum(dtype=jnp.int32), axes)
        return aggs, cnts, truncated, candidates

    in_specs = (specs["rev_ts"], specs["cols"], P(axes, None))
    if runs:
        in_specs += _ev_level_specs(axes) + _ix_level_specs(axes)
    in_specs += (
        P(None), P(None), P(None), P(None, None),  # program: replicated
        P(None),  # value table: replicated
        P(None), P(None), P(),  # cond ranges + bucket origin
    )
    smapped = shard_map(
        tablet_ixagg,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(None), P(None), P(), P()),
        check_rep=False,
    )
    return jax.jit(smapped)


def build_density_step(mesh: Mesh, runs: bool = False):
    """Jitted distributed density read for the query planner: total count
    over one packed aggregate-key range — per-tablet searchsorted + masked
    sum per LSM level (the agg family folds duplicate keys only at major
    compaction, so unfolded levels may repeat a key: the counts are
    additive by construction and SUM across levels), merged with a single
    psum. This is how plan_query's d_i estimates come off the mesh instead
    of the host aggregate table."""
    axes = tuple(mesh.axis_names)

    def fn(*args):
        if runs:
            (ag_keys, ag_vals, ag_run_k, ag_run_c, ag_run_n,
             ag_mem_k, ag_mem_c, ag_mem_n, lo, hi) = args
        else:
            ag_keys, ag_vals, lo, hi = args

        def level(k_l, v_l, nn):
            ca = k_l.shape[0]
            a = jnp.searchsorted(k_l, lo, side="left")
            b = jnp.searchsorted(k_l, hi, side="left")
            idx = jnp.arange(ca)
            in_r = (idx >= a) & (idx < b) & (idx < nn)
            return jnp.where(in_r, v_l[:, 0], 0).sum()

        def one(k_l, v_l, *lv):
            total = level(k_l, v_l, jnp.int32(k_l.shape[0]))
            if runs:
                rk, rc, rn, mk, mc, mn = lv
                total = total + jax.vmap(level)(rk, rc, rn).sum()
                total = total + level(mk, mc, mn)
            return total

        if runs:
            local = jax.vmap(one)(
                ag_keys, ag_vals, ag_run_k, ag_run_c, ag_run_n,
                ag_mem_k, ag_mem_c, ag_mem_n,
            )
        else:
            local = jax.vmap(one)(ag_keys, ag_vals)
        return jax.lax.psum(local.sum(), axes)

    in_specs = (P(axes, None), P(axes, None, None))
    if runs:
        in_specs += _ag_level_specs(axes)
    in_specs += (P(), P())
    smapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(smapped)


@dataclass
class DistBatch:
    """One batch's result from the distributed executor: the exact global
    matching-row count plus the per-tablet top-k newest rows (BatchScanner
    semantics: unordered across tablets, newest-first within). lo/hi are
    the adaptive batch's time sub-range when stepped through a QueryRun
    (the serve plane streams these to clients and checks monotonicity)."""

    count: int
    ts: np.ndarray
    cols: np.ndarray
    lo: float = 0.0
    hi: float = 0.0

    @property
    def n(self) -> int:
        return self.count

    @property
    def nbytes(self) -> int:
        return self.ts.nbytes + self.cols.nbytes


class _PinnedSource:
    """plan_query density source bound to ONE published snapshot: an
    in-flight query's planning reads d_i from the same LSM state its
    batches will execute against, even while publishes and background
    compactions race the query (per-call isolation for the serve plane)."""

    def __init__(self, proc: "DistQueryProcessor", dist: DistStore, profile=None):
        self._proc = proc
        self._dist = dist
        self._profile = profile  # serve_db QueryProfile: density stage clock

    @property
    def schema(self):
        return self._proc.store.schema

    @property
    def dictionaries(self):
        return self._proc.store.dictionaries

    def agg_count(self, field: str, value: str, t_start: int, t_stop: int) -> int:
        if self._profile is None:
            return self._proc._agg_count_on(self._dist, field, value, t_start, t_stop)
        t0 = time.perf_counter()
        out = self._proc._agg_count_on(self._dist, field, value, t_start, t_stop)
        self._profile.density_acc_s += time.perf_counter() - t0
        return out


class QueryRun:
    """One planned query pinned to one published snapshot, stepped one
    adaptive batch at a time — the re-entrant form of
    DistQueryProcessor.execute().

    The serve plane's scheduler (repro.serve_db) interleaves many
    sessions' QueryRuns under a device lock: step() executes exactly ONE
    Alg-2 batch (one device program in filter mode; index mode adds the
    filter-scan redo only on slab overflow) and feeds the observed
    (runtime, rows) back into the run's own AdaptiveBatcher. Nothing here
    mutates processor state beyond the lock-guarded jit step caches, so
    any number of runs step concurrently; and because the snapshot is
    pinned at construction — published levels are stable, compaction
    programs never donate their buffers — a background compact() or a
    concurrent publish can never change this run's results mid-flight."""

    def __init__(
        self,
        proc: "DistQueryProcessor",
        tree,
        t_start: int,
        t_stop: int,
        use_index: bool = True,
        batched: bool = True,
        stats=None,
        profile=None,
    ):
        self.proc = proc
        self.tree = tree
        self.t_start = t_start
        self.t_stop = t_stop
        self.stats = stats
        # serve_db QueryProfile (or None): the execution layer adds its
        # density reads and device-program sections into the profile's
        # accumulators so the serve plane can tile TTFR into stages.
        self.profile = profile
        self.dist = proc._sync()  # pinned for the whole run
        source = (
            _PinnedSource(proc, self.dist, profile=profile)
            if self.dist.has_index else proc.store
        )
        with span("query.plan", cat="query") as sp:
            self.plan = plan_query(
                source, tree, t_start, t_stop, w=proc.w,
                use_index=use_index and self.dist.has_index,
            )
            sp.set(mode=self.plan.mode)
        if stats is not None:
            stats.plan = self.plan
        self._empty = self.plan.mode == "empty"
        self._single_done = False
        if batched and not self._empty:
            rps = proc.store.rows_per_second()
            self.batcher: Optional[AdaptiveBatcher] = AdaptiveBatcher(
                t_start=t_start, t_stop=t_stop, b0=rps and 10.0 / rps
            )
        else:
            self.batcher = None

    @property
    def done(self) -> bool:
        if self._empty:
            return True
        if self.batcher is None:
            return self._single_done
        return self.batcher.done

    # reprolint: hot-path — one serve-plane turn == N of these steps
    def step(self) -> Optional[DistBatch]:
        """Execute the next adaptive batch and return it (lo/hi carry the
        batch's time sub-range); None once the run is done — provably
        empty plans never dispatch a device program at all."""
        if self.done:
            return None
        if self.batcher is None:
            lo, hi = float(self.t_start), float(self.t_stop)
        else:
            lo, hi = self.batcher.next_range()
        t0 = time.perf_counter()
        with span("query.step", cat="query", mode=self.plan.mode) as sp:
            blk = self.proc._exec_range(
                self.plan, self.tree, int(lo), int(hi), self.stats,
                dist=self.dist, profile=self.profile,
            )
            sp.set(rows=int(blk.count))
        runtime = time.perf_counter() - t0
        if self.batcher is None:
            self._single_done = True
        else:
            self.batcher.update(runtime, blk.count)
        if self.stats is not None:
            self.stats.batches += 1
            self.stats.rows += blk.count
            self.stats.batch_log.append((lo, hi, runtime, blk.count))
        blk.lo, blk.hi = float(lo), float(hi)
        return blk


class DistQueryProcessor:
    """Planner-driven, adaptively batched queries over the mesh — all four
    of the paper's §IV-B schemes (scan / batched_scan / index /
    batched_index) running distributed.

    With `plane=` (a DistIngestPlane), every query first syncs to the
    plane's latest published snapshot — rows written through
    DistBatchWriter become query-visible with no host round trip: publish
    is a sealed-memtable sort plus a metadata flip (never a fold into the
    base — every read here searches base + runs + sealed memtable), and a
    no-op when nothing was ingested. Planes that maintain the
    index/aggregate families (DistIngestPlane.for_store /
    from_event_store) additionally enable the index schemes: plan_query
    reads densities from the distributed aggregate tablets (agg_count, a
    psum over all levels) and index-mode plans execute as build_index_step
    programs — including selective AGGREGATES, which combine over the
    gathered index candidates only (build_index_aggregate_step).
    Index-less stores fall back to filter-scan for every plan."""

    def __init__(
        self,
        store: EventStore,
        dist: Optional[DistStore] = None,
        top_k: int = 128,
        plane=None,
        w: float = 10.0,
        index_postings: int = 2048,
        index_rows: int = 4096,
    ):
        if dist is None:
            if plane is None:
                raise ValueError("need dist= or plane=")
            dist = plane.publish()
        self.store = store
        self.dist = dist
        self.plane = plane
        self.top_k = top_k
        self.w = w
        self.index_postings = index_postings
        self.index_rows = index_rows
        self._step_cache: Dict[Tuple, object] = {}  # guarded-by: _cache_lock
        # Re-entrancy: many serve-plane sessions step queries through ONE
        # processor concurrently. The cache lock guards the jit-step dict;
        # per-query state (plan, batcher, stats, the pinned snapshot)
        # lives in each QueryRun, never on self. OwnedLock (not a bare
        # threading.Lock) so first-trace stalls show up attributed in the
        # occupancy report next to the plane and device locks.
        self._cache_lock = OwnedLock("step_cache_lock")

    def _sync(self) -> DistStore:
        """Refresh to the plane's latest published snapshot and return it.
        Callers pin the RETURNED snapshot for the duration of one
        operation (self.dist may be re-flipped by a concurrent caller at
        any time; a published snapshot itself is immutable)."""
        if self.plane is not None:
            self.dist = self.plane.publish()
        return self.dist

    # ------------------------------------------------- level input helpers
    @staticmethod
    def _ev_levels(d: DistStore) -> Tuple[jax.Array, ...]:
        return (d.run_rev_ts, d.run_cols, d.run_counts,
                d.mem_rev_ts, d.mem_cols, d.mem_counts)

    @staticmethod
    def _ix_levels(d: DistStore) -> Tuple[jax.Array, ...]:
        return (d.ix_run_k, d.ix_run_n, d.ix_mem_k, d.ix_mem_n)

    @staticmethod
    def _ag_levels(d: DistStore) -> Tuple[jax.Array, ...]:
        return (d.ag_run_k, d.ag_run_c, d.ag_run_n,
                d.ag_mem_k, d.ag_mem_c, d.ag_mem_n)

    def _cached_step(self, key: Tuple, build):
        with self._cache_lock.hold("step_cache"):
            if key not in self._step_cache:
                self._step_cache[key] = build()
            return self._step_cache[key]

    # ------------------------------------------------- planner density source
    # plan_query duck-types its store argument: it needs .schema,
    # .dictionaries and .agg_count. Exposing them here makes the processor
    # itself the density source, with d_i read from the mesh.
    @property
    def schema(self):
        return self.store.schema

    @property
    def dictionaries(self):
        return self.store.dictionaries

    # reprolint: hot-path
    def agg_count(self, field: str, value: str, t_start: int, t_stop: int) -> int:
        """Occurrences of field=value in the bucketed time range, from the
        DISTRIBUTED aggregate tablets (psum of per-tablet, per-level
        counts) — the planner's d_i, served by the mesh instead of the
        host store, fresh through unfolded runs."""
        return self._agg_count_on(self._sync(), field, value, t_start, t_stop)

    # reprolint: hot-path — planning reads densities per condition per query
    def _agg_count_on(self, d: DistStore, field: str, value: str,
                      t_start: int, t_stop: int) -> int:
        """agg_count against ONE pinned snapshot (no re-publish): planning
        for an in-flight QueryRun reads densities from the same LSM state
        its batches will execute against. Memoized PER SNAPSHOT (a
        published DistStore is immutable, so a density read never goes
        stale within it): concurrent sessions planning the same
        conditions — the common case on the serve plane — pay the device
        read once, which is most of a follower query's
        time-to-first-result."""
        if not d.has_index:
            return self.store.agg_count(field, value, t_start, t_stop)
        cache = d.density_cache
        ckey = (field, value, int(t_start), int(t_stop))
        hit = cache.get(ckey)
        if hit is not None:
            return hit
        if d.groups is not None:
            # Composite snapshot: densities sum over the disjoint tablet
            # groups. Each recursion memoizes in ITS sub-store's cache —
            # sub-snapshots alias across publishes when their group is
            # clean, so an untouched group's densities stay warm even as
            # busy groups re-seal (the composite-level memo above only
            # lives as long as this exact composition).
            out = sum(
                self._agg_count_on(sub, field, value, t_start, t_stop)
                for sub in d.groups
            )
            cache[ckey] = out
            return out
        code = self.store.dictionaries[field].lookup(value)
        if code is None:
            cache[ckey] = 0
            return 0
        fid = self.store.schema.field_id(field)
        bs = d.agg_bucket_s
        b0 = int(t_start) // bs
        b1 = int(t_stop) // bs
        # keypack packs host-side numpy scalars — no device value, no sync.
        lo = int(keypack.pack_agg_key(fid, code, b0))  # reprolint: disable=no-sync-in-hot-path
        hi = int(keypack.pack_agg_key(fid, code, b1)) + 1  # reprolint: disable=no-sync-in-hot-path
        step = self._cached_step(
            ("density", d.has_runs),
            lambda: build_density_step(d.mesh, runs=d.has_runs),
        )
        args = (d.ag_keys, d.ag_vals)
        if d.has_runs:
            args += self._ag_levels(d)
        with span("query.density", cat="query", field=field, value=value) as sp:
            out = int(sp.fence(step(*args, jnp.int64(lo), jnp.int64(hi))))
        cache[ckey] = out
        return out

    def _step(self, prog: FilterProgram, d: DistStore):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        step = self._cached_step(
            (len(opc), cs.shape, d.has_runs),
            lambda: build_scan_step(
                d.mesh, self.store.schema.n_fields, len(opc), cs.shape,
                self.top_k, runs=d.has_runs,
            ),
        )
        return step, (opc, a0, a1, cs)

    # reprolint: hot-path — the per-batch device program of every scan scheme
    def scan_range(self, tree, t0: int, t1: int, dist: Optional[DistStore] = None,
                   profile=None):
        """One range scan across all tablets and all LSM levels. Returns
        (global_count, top-k rows per tablet as (ts, cols) numpy arrays).
        `dist` pins an already-published snapshot (QueryRun); default
        syncs to the plane's latest. `profile` (serve_db QueryProfile)
        accumulates the device-program section into device_acc_s."""
        d = dist if dist is not None else self._sync()
        if d.groups is not None:
            # Composite snapshot: one device program per tablet group
            # (each group is its own mesh-wide shard_map — same compiled
            # step, cached on identical shapes), counts summed and top-k
            # slates concatenated (BatchScanner semantics are unordered
            # across tablets, so across groups too).
            total = 0
            ts_parts, col_parts = [], []
            for sub in d.groups:
                c, ts, cols = self.scan_range(tree, t0, t1, dist=sub, profile=profile)
                total += c
                ts_parts.append(ts)
                col_parts.append(cols)
            return total, np.concatenate(ts_parts), np.concatenate(col_parts)
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._step(prog, d)
        rts_lo = jnp.int32(keypack.rev_ts(t1))
        rts_hi = jnp.int32(keypack.rev_ts(t0) + 1)
        args = (d.rev_ts, d.cols, d.counts)
        if d.has_runs:
            args += self._ev_levels(d)
        # Materialize INSIDE the span, each wait fenced: the span record
        # is emitted at __exit__, so a sync after the block would charge
        # this batch's device wait to nothing (and np.asarray on a device
        # array is exactly such a sync) — found by reprolint's
        # no-sync-in-hot-path rule.
        tdev = time.perf_counter()
        with span("query.scan_range", cat="query") as sp:
            total, top_ts, top_cols = step(
                *args,
                jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
                rts_lo, rts_hi,
            )
            count = int(sp.fence(total))
            ts = np.asarray(sp.fence(top_ts))
            cols = np.asarray(sp.fence(top_cols))
        if profile is not None:
            profile.device_acc_s += time.perf_counter() - tdev
        valid = ts != int(INVALID_TS)
        return count, keypack.unrev_ts(ts[valid]), cols[valid]

    # -------------------------------------------------------- index path
    def _index_step(self, prog: FilterProgram, n_conds: int, combine: str,
                    d: DistStore):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        step = self._cached_step(
            ("index", n_conds, combine, len(opc), cs.shape, d.has_runs),
            lambda: build_index_step(
                d.mesh, n_conds, combine, len(opc), cs.shape,
                self.top_k, self.index_postings, self.index_rows,
                runs=d.has_runs,
            ),
        )
        return step, (opc, a0, a1, cs)

    def _cond_ranges(self, plan: QueryPlan, t0: int, t1: int):
        """Per-condition packed index-key [lo, hi) ranges for the batch's
        time window (lo == hi for never-seen values: empty posting range)."""
        rts_lo = keypack.rev_ts(t1)
        rts_hi = keypack.rev_ts(t0)
        k = len(plan.index_conds)
        lo = np.zeros(k, np.int64)
        hi = np.zeros(k, np.int64)
        for i, c in enumerate(plan.index_conds):
            code = self.store.dictionaries[c.field].lookup(c.value)
            if code is None:
                continue
            fid = self.store.schema.field_id(c.field)
            lo[i] = keypack.pack_index_key(fid, code, rts_lo)
            hi[i] = keypack.pack_index_key(fid, code, rts_hi) + 1
        return lo, hi

    def _index_args(self, d: DistStore):
        args = (d.rev_ts, d.cols, d.ix_keys)
        if d.has_runs:
            args += self._ev_levels(d) + self._ix_levels(d)
        return args

    # reprolint: hot-path — the per-batch device program of the index schemes
    def scan_index_range(self, plan: QueryPlan, tree, t0: int, t1: int,
                         dist: Optional[DistStore] = None, profile=None):
        """One index-mode range across all tablets (paper Fig 2 on-mesh):
        postings lookup per condition per level, device-side
        intersect/union, candidate-row fetch from every level, and the
        FULL tree re-checked on candidates.
        Returns (global_count, top-k (ts, cols), truncated, candidates);
        `truncated` > 0 means a posting/row slab overflowed and the count
        is a lower bound — the executor falls back to filter-scan then."""
        d = dist if dist is not None else self._sync()
        if d.groups is not None:
            # Composite snapshot: postings of one (field, value) live in
            # whichever groups' tablets hold matching rows — every group
            # is searched, partial counts/truncation/candidates sum.
            total = n_trunc = n_cands = 0
            ts_parts, col_parts = [], []
            for sub in d.groups:
                c, ts, cols, tr, ca = self.scan_index_range(
                    plan, tree, t0, t1, dist=sub, profile=profile
                )
                total += c
                n_trunc += tr
                n_cands += ca
                ts_parts.append(ts)
                col_parts.append(cols)
            return (
                total, np.concatenate(ts_parts), np.concatenate(col_parts),
                n_trunc, n_cands,
            )
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._index_step(
            prog, len(plan.index_conds), plan.combine, d
        )
        lo, hi = self._cond_ranges(plan, t0, t1)
        # Span + fenced materialization (this path had NEITHER: its
        # device wait was invisible to tracing and charged to the caller
        # as host time — found by reprolint's no-sync-in-hot-path rule).
        tdev = time.perf_counter()
        with span("query.scan_index_range", cat="query") as sp:
            total, top_ts, top_cols, truncated, cands = step(
                *self._index_args(d),
                jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
                jnp.asarray(lo), jnp.asarray(hi),
            )
            count = int(sp.fence(total))
            ts = np.asarray(sp.fence(top_ts))
            cols = np.asarray(sp.fence(top_cols))
            n_trunc = int(sp.fence(truncated))
            n_cands = int(sp.fence(cands))
        if profile is not None:
            profile.device_acc_s += time.perf_counter() - tdev
        valid = ts != int(INVALID_TS)
        return (count, keypack.unrev_ts(ts[valid]), cols[valid], n_trunc, n_cands)

    # ---------------------------------------------------- planned execution
    # reprolint: hot-path
    def _exec_range(self, plan: QueryPlan, tree, t0: int, t1: int, stats=None,
                    dist: Optional[DistStore] = None, profile=None) -> DistBatch:
        d = dist if dist is not None else self.dist
        if plan.mode == "index" and d.has_index:
            count, ts, cols, truncated, cands = self.scan_index_range(
                plan, tree, t0, t1, dist=d, profile=profile
            )
            if stats is not None:
                stats.index_keys_scanned += cands
            if not truncated:
                return DistBatch(count, ts, cols)
            # Slab overflow: redo this range with the exact filter-scan
            # step (results identical, just without the candidate cap).
        count, ts, cols = self.scan_range(tree, t0, t1, dist=d, profile=profile)
        return DistBatch(count, ts, cols)

    def execute(
        self,
        tree,
        t_start: int,
        t_stop: int,
        use_index: bool = True,
        batched: bool = True,
        stats=None,
    ):
        """Stream DistBatch results for a planned query — the distributed
        QueryProcessor.execute. plan_query picks the access path from the
        mesh-resident densities (heuristics 1-4); index-mode plans run
        build_index_step per batch, filter plans the scan step; provably
        empty plans (zero-density intersect branch) never touch a device.
        Implemented over QueryRun: the whole query is pinned to one
        published snapshot."""
        run = QueryRun(
            self, tree, t_start, t_stop,
            use_index=use_index, batched=batched, stats=stats,
        )
        while not run.done:
            blk = run.step()
            if blk is not None:
                yield blk

    def run_scheme(self, scheme: str, t_start: int, t_stop: int, tree=None, **kw):
        """The paper's four experimental schemes by name, distributed —
        mirrors QueryProcessor.run_scheme."""
        flags = {
            "scan": dict(use_index=False, batched=False),
            "batched_scan": dict(use_index=False, batched=True),
            "index": dict(use_index=True, batched=False),
            "batched_index": dict(use_index=True, batched=True),
        }[scheme]
        return self.execute(tree, t_start, t_stop, **flags, **kw)

    def _agg_step(self, prog: FilterProgram, grouping: ResolvedGrouping,
                  d: DistStore):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = (
            "agg", len(opc), cs.shape, grouping.fids, grouping.strides,
            grouping.size, grouping.n_buckets, grouping.spec.time_bucket_s,
            grouping.spec.op, grouping.value_fid, d.has_runs,
        )
        step = self._cached_step(
            key,
            lambda: build_aggregate_step(
                d.mesh,
                grouping.fids,
                grouping.strides,
                grouping.size,
                grouping.n_buckets,
                grouping.spec.time_bucket_s,
                grouping.spec.op,
                grouping.value_fid,
                runs=d.has_runs,
            ),
        )
        return step, (opc, a0, a1, cs)

    def _index_agg_step(self, prog: FilterProgram, grouping: ResolvedGrouping,
                        n_conds: int, combine: str, d: DistStore):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = (
            "aggix", n_conds, combine, len(opc), cs.shape, grouping.fids,
            grouping.strides, grouping.size, grouping.spec.time_bucket_s,
            grouping.spec.op, grouping.value_fid, d.has_runs,
        )
        step = self._cached_step(
            key,
            lambda: build_index_aggregate_step(
                d.mesh, n_conds, combine, len(opc), cs.shape,
                grouping.fids, grouping.strides, grouping.size,
                grouping.spec.time_bucket_s, grouping.spec.op,
                grouping.value_fid, self.index_postings, self.index_rows,
                runs=d.has_runs,
            ),
        )
        return step, (opc, a0, a1, cs)

    @staticmethod
    def _materialize_agg(grouping: ResolvedGrouping, aggs, cnts) -> AggregateResult:
        """Host-side epilogue: only groups with >= 1 matching row exist."""
        aggs = np.asarray(aggs).astype(np.int64)
        cnts = np.asarray(cnts)
        live = cnts > 0
        gids = np.flatnonzero(live).astype(np.int64)
        return AggregateResult(grouping, gids, aggs[live], cnts[live])

    # reprolint: hot-path — one-shot aggregate turns run through here
    def aggregate_range(
        self, spec: AggregateSpec, tree, t0: int, t1: int,
        use_index: bool = True, stats=None, dist: Optional[DistStore] = None,
    ) -> AggregateResult:
        """Scan-time aggregation across all tablets in ONE device program —
        the distributed lowering of QueryProcessor.aggregate(), planner
        driven: selective trees (index-mode plans) aggregate over ONLY the
        gathered index candidates (build_index_aggregate_step), provably
        empty plans skip the device entirely, and everything else — or an
        overflowed candidate slab — runs the exact filter-scan
        aggregation. Returns the already-merged (psum'd) per-group
        result; only groups with at least one matching row materialize
        host-side. `dist` pins an already-published snapshot (serve-plane
        sessions); default syncs to the plane's latest."""
        d = dist if dist is not None else self._sync()
        grouping = resolve_grouping(self.store, spec, t0, t1)
        source = _PinnedSource(self, d) if d.has_index else self.store
        plan = plan_query(
            source, tree, t0, t1, w=self.w,
            use_index=use_index and d.has_index,
        )
        if stats is not None:
            stats.plan = plan
        if plan.mode == "empty":
            e = np.empty(0, np.int64)
            return AggregateResult(grouping, e, e.copy(), e.copy())
        prog = compile_tree(self.store, tree)
        vt = grouping.value_table
        if vt is None:
            vt = np.ones(1, np.int32)  # unused placeholder (count op)
        # One resolve + one plan serve every tablet group; a composite
        # snapshot runs the per-group executor per sub-store (each group
        # falls back to scan-agg INDEPENDENTLY on its own slab overflow)
        # and folds the dense per-group partials on device — rows are
        # disjoint across groups, so sum/count add and min/max fold
        # elementwise against their identities, cnts always add.
        subs = d.groups if d.groups is not None else (d,)
        aggs, cnts = self._agg_range_on(subs[0], plan, grouping, prog, vt, t0, t1, stats)
        op = grouping.spec.op
        for sub in subs[1:]:
            a, c = self._agg_range_on(sub, plan, grouping, prog, vt, t0, t1, stats)
            if op in ("count", "sum"):
                aggs = aggs + a
            elif op == "min":
                aggs = jnp.minimum(aggs, a)
            else:
                aggs = jnp.maximum(aggs, a)
            cnts = cnts + c
        return self._materialize_agg(grouping, aggs, cnts)

    # reprolint: hot-path — aggregate_range's per-group device executor
    def _agg_range_on(self, d: DistStore, plan: QueryPlan,
                      grouping: ResolvedGrouping, prog: FilterProgram,
                      vt, t0: int, t1: int, stats=None):
        """Run one (sub-)snapshot's aggregation and return the DENSE
        per-group (aggs, cnts) device arrays — the caller folds partials
        across tablet groups and materializes once."""
        if plan.mode == "index" and d.has_index:
            step, (opc, a0, a1, cs) = self._index_agg_step(
                prog, grouping, len(plan.index_conds), plan.combine, d
            )
            lo, hi = self._cond_ranges(plan, t0, t1)
            aggs, cnts, truncated, cands = step(
                *self._index_args(d),
                jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
                jnp.asarray(vt),
                jnp.asarray(lo), jnp.asarray(hi),
                jnp.int32(grouping.bucket_lo),
            )
            if stats is not None:
                stats.index_keys_scanned += int(cands)
            if not int(truncated):
                return aggs, cnts
            # Slab overflow: exact filter-scan aggregation below.
        step, (opc, a0, a1, cs) = self._agg_step(prog, grouping, d)
        args = (d.rev_ts, d.cols, d.counts)
        if d.has_runs:
            args += self._ev_levels(d)
        aggs, cnts = step(
            *args,
            jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
            jnp.asarray(vt),
            jnp.int32(keypack.rev_ts(t1)), jnp.int32(keypack.rev_ts(t0) + 1),
            jnp.int32(grouping.bucket_lo),
        )
        return aggs, cnts

    def execute_batched(self, tree, t_start: int, t_stop: int, stats=None):
        """Algorithm 2 over the distributed scan."""
        d = self._sync()
        batcher = AdaptiveBatcher(
            t_start=t_start, t_stop=t_stop, b0=self.store.rows_per_second() and 10.0 / self.store.rows_per_second()
        )
        results = []
        while not batcher.done:
            lo, hi = batcher.next_range()
            t0 = time.perf_counter()
            count, ts, cols = self.scan_range(tree, int(lo), int(hi), dist=d)
            batcher.update(time.perf_counter() - t0, count)
            results.append((count, ts, cols))
            if stats is not None:
                stats.batches += 1
                stats.rows += count
        return results
