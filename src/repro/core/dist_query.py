"""Distributed query execution — the paper's tablet-server scan on the
production TPU mesh.

The host-side EventStore (store.py) is the single-node reference; this
module is the scale-out data plane: every device of the (data, model) mesh
acts as one tablet server holding a fixed-capacity sorted columnar tablet,
and a query executes as ONE jitted shard_map program:

    time-range restriction   sorted rev_ts -> per-tablet searchsorted
    filter                   the same postfix predicate program the
                             Pallas filter_scan kernel executes
    project + count          local; global count via psum
    top-k newest             local top-k, then a gathered cross-tablet
                             merge on the host (BatchScanner semantics:
                             unordered across tablets)
    iterator-stack combine   the server-side CombinerIterator lowered into
                             the shard_map program: per-tablet fused
                             filter + dense segment aggregation, merged
                             across tablets with psum/pmin/pmax (the
                             group-id space is dense by construction —
                             see core/iterators.py ResolvedGrouping)

The adaptive batcher (Algs 1-2) drives this exactly like the host path:
each batch is one device-program invocation over a time sub-range — the
paper's design, 256 tablets wide. dryrun.py lowers + compiles it on the
single-pod and multi-pod meshes as the extra `llcysa-store` cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import keypack
from .filter import FilterProgram, compile_tree
from .iterators import AggregateResult, AggregateSpec, ResolvedGrouping, resolve_grouping
from .planner import QueryPlan, plan_query
from .store import EventStore

INVALID_TS = jnp.int32(-1)
_I32_MAX = np.iinfo(np.int32).max


@dataclass
class DistStore:
    """Device-resident tablet grid — the paper's three tables per source.

    rev_ts:  (T, R) int32   reversed timestamps, ascending per tablet
                            (newest first), padded with TS_MAX+... sentinel
    cols:    (T, R, F) int32 dictionary codes, pad rows carry junk codes
                            (masked by counts in every scan)
    counts:  (T,) int32     live rows per tablet
    T = number of tablets = n_devices * tablets_per_device (T must divide
    evenly across the mesh); R = tablet capacity. The grid is either a
    one-shot scatter of a host store (from_event_store) or the live base
    run of a DistIngestPlane (dist_ingest.publish) — the latter updates
    incrementally as writers ingest, no re-scatter.

    Planes that maintain the index/aggregate families additionally expose:

    ix_keys:  (T, Ci) int64  sorted packed index keys (field|value|rev_ts)
                             — postings for one (field, value) over a time
                             range are one contiguous slice, INT64_MAX pad
    ix_counts: (T,) int32    live postings per tablet
    ag_keys:  (T, Ca) int64  sorted packed aggregate keys
                             (field|value|bucket), unique per tablet
    ag_vals:  (T, Ca, 1) int64 occurrence counts per aggregate key
    ag_counts: (T,) int32    live aggregate keys per tablet
    agg_bucket_s: int        the bucketing the densities were counted at

    These are None for index-less stores (a plane built without
    indexed_fids); DistQueryProcessor then falls back to filter-scan.
    """

    rev_ts: jax.Array
    cols: jax.Array
    counts: jax.Array
    mesh: Mesh
    ix_keys: Optional[jax.Array] = None
    ix_counts: Optional[jax.Array] = None
    ag_keys: Optional[jax.Array] = None
    ag_vals: Optional[jax.Array] = None
    ag_counts: Optional[jax.Array] = None
    agg_bucket_s: Optional[int] = None

    @property
    def n_tablets(self) -> int:
        return self.rev_ts.shape[0]

    @property
    def capacity(self) -> int:
        return self.rev_ts.shape[1]

    @property
    def has_index(self) -> bool:
        return self.ix_keys is not None


def tablet_specs(mesh: Mesh) -> Dict[str, P]:
    """Tablets shard over ALL mesh axes (every chip is a tablet server)."""
    axes = tuple(mesh.axis_names)
    return {
        "rev_ts": P(axes, None),
        "cols": P(axes, None, None),
        "counts": P(axes),
    }


def dist_store_shapes(mesh: Mesh, rows_per_tablet: int, n_fields: int, tablets_per_device: int = 1):
    """Abstract ShapeDtypeStructs for the dry-run (no allocation)."""
    t = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) * tablets_per_device
    return {
        "rev_ts": jax.ShapeDtypeStruct((t, rows_per_tablet), jnp.int32),
        "cols": jax.ShapeDtypeStruct((t, rows_per_tablet, n_fields), jnp.int32),
        "counts": jax.ShapeDtypeStruct((t,), jnp.int32),
    }


def from_event_store(
    store: EventStore,
    mesh: Mesh,
    capacity: Optional[int] = None,
    tablets_per_device: int = 1,
) -> DistStore:
    """Re-shard a host EventStore's event tables onto the mesh by row hash
    (the paper's uniform random sharding) — implemented as a bulk replay
    through the distributed ingest plane: the host rows stream through
    DistIngestPlane.ingest and the device-side compaction programs build
    the sorted tablets (the former host-side NumPy scatter loop is gone)."""
    from .dist_ingest import DistIngestPlane

    t = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) * tablets_per_device
    rows_k, rows_c = [], []
    for tab in store.event_tablets:
        for run in tab.snapshot_runs():
            _, rts, h = keypack.unpack_event_key(run.keys)
            rows_k.append(np.stack([rts, h], 1))
            rows_c.append(run.cols)
    if rows_k:
        rk = np.concatenate(rows_k)
        rc = np.concatenate(rows_c)
    else:
        rk = np.zeros((0, 2), np.int64)
        rc = np.zeros((0, store.schema.n_fields), np.int32)
    assign = (rk[:, 1] % t).astype(np.int64)  # hash-uniform tablet choice
    cap = capacity or max(int(np.bincount(assign, minlength=t).max()), 1)
    # The plane's flush triggers are exact per tablet (host-side fill
    # mirror), so fixed per-tablet buffers suffice: a tablet majors every
    # max_runs * mem_rows of ITS OWN rows — run-slab memory stays
    # O(T * max_runs * mem_rows), independent of replay size. for_store
    # binds the store's indexed fields + aggregate bucketing, so the
    # replay also builds live index postings and planner densities.
    plane = DistIngestPlane.for_store(
        store,
        mesh,
        capacity=cap,
        tablets_per_device=tablets_per_device,
        mem_rows=8192,
        max_runs=8,
        append_rows=2048,
    )
    plane.ingest(rk[:, 0].astype(np.int32), rc, assign.astype(np.int32))
    dist = plane.publish()
    overflow = int(plane.telemetry()["overflow"].sum())
    if overflow:
        # An explicitly undersized capacity must fail loudly, exactly as
        # the pre-plane scatter implementation did.
        raise ValueError(f"tablet overflow: {overflow} rows over capacity {cap}")
    return dist


def _program_eval(cols, opcodes, arg0, arg1, codesets):
    """Postfix predicate program over (R, F) codes — identical semantics
    to kernels/filter_scan (jnp form, shard-local)."""
    from ..kernels.program_eval import program_eval_rows

    return program_eval_rows(cols, opcodes, arg0, arg1, codesets)


def build_scan_step(mesh: Mesh, n_fields: int, prog_len: int, set_shape: Tuple[int, int], top_k: int = 128):
    """Jitted distributed scan: (store, program, t-range) -> (global count,
    per-tablet top-k newest matches). One invocation per adaptive batch.
    Each device vmaps over its local tablets (tablets_per_device may
    exceed 1 — the ingest plane's W x T sweeps size T independently of
    the mesh), then psums across the mesh."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)

    def tablet_scan(rev_ts, cols, counts, opcodes, arg0, arg1, codesets, rts_lo, rts_hi):
        # Local slab: (Tl, R), (Tl, R, F), (Tl,) after shard_map slicing.
        r = rev_ts.shape[1]

        def one(rev_l, cols_l, n):
            # Range restriction on sorted rev_ts: [lo, hi) via searchsorted.
            a = jnp.searchsorted(rev_l, rts_lo, side="left")
            b = jnp.searchsorted(rev_l, rts_hi, side="left")
            idx = jnp.arange(r, dtype=jnp.int32)
            in_range = (idx >= a) & (idx < b) & (idx < n)
            hit = _program_eval(cols_l, opcodes, arg0, arg1, codesets) & in_range
            count = hit.sum(dtype=jnp.int32)
            # Top-k newest matches (smallest rev_ts == newest; rows sorted).
            rank = jnp.where(hit, idx, r)
            top = jnp.sort(rank)[:top_k]
            valid = top < r
            safe = jnp.clip(top, 0, r - 1)
            out_ts = jnp.where(valid, rev_l[safe], INVALID_TS)
            out_cols = jnp.where(valid[:, None], cols_l[safe], -1)
            return count, out_ts, out_cols

        count_l, out_ts, out_cols = jax.vmap(one)(rev_ts, cols, counts)
        total = jax.lax.psum(count_l.sum(dtype=jnp.int32), axes)
        return total, out_ts, out_cols

    smapped = shard_map(
        tablet_scan,
        mesh=mesh,
        in_specs=(
            specs["rev_ts"], specs["cols"], specs["counts"],
            P(None), P(None), P(None), P(None, None),  # program: replicated
            P(), P(),
        ),
        out_specs=(P(), P(axes, None), P(axes, None, None)),
        check_rep=False,
    )
    return jax.jit(smapped)


def build_aggregate_step(
    mesh: Mesh,
    fids: Tuple[int, ...],
    strides: Tuple[int, ...],
    n_groups: int,
    n_buckets: int,
    bucket_s: Optional[int],
    op: str,
    value_fid: Optional[int],
):
    """Jitted distributed scan-time aggregation: the iterator stack's
    terminal CombinerIterator lowered into the mesh program. Each tablet
    evaluates the fused filter + dense segment aggregation locally; the
    dense group-id space (mixed-radix codes x time buckets, see
    ResolvedGrouping) makes the cross-tablet merge a single psum (sum /
    count) or pmin/pmax — no gather of raw rows ever happens."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)
    int32_max = jnp.iinfo(jnp.int32).max
    int32_min = jnp.iinfo(jnp.int32).min
    identity = {"count": 0, "sum": 0, "min": int32_max, "max": int32_min}[op]

    def tablet_agg(rev_ts, cols, counts, opcodes, arg0, arg1, codesets,
                   value_table, rts_lo, rts_hi, bucket_lo):
        r = rev_ts.shape[1]

        def one(rev_l, cols_l, n):
            a = jnp.searchsorted(rev_l, rts_lo, side="left")
            b = jnp.searchsorted(rev_l, rts_hi, side="left")
            idx = jnp.arange(r, dtype=jnp.int32)
            in_range = (idx >= a) & (idx < b) & (idx < n)
            hit = _program_eval(cols_l, opcodes, arg0, arg1, codesets) & in_range
            gid = jnp.zeros((r,), jnp.int32)
            for fid, stride in zip(fids, strides):
                gid = gid + cols_l[:, fid] * jnp.int32(stride)
            if bucket_s is not None:
                ts_l = jnp.int32(keypack.TS_MAX) - rev_l
                gid = gid + ts_l // jnp.int32(bucket_s) - bucket_lo
            # Padded/out-of-range rows can carry junk codes: clamp, their
            # contribution is masked to the identity anyway.
            gid = jnp.clip(gid, 0, n_groups - 1)
            if value_fid is not None:
                codes = jnp.clip(cols_l[:, value_fid], 0, value_table.shape[0] - 1)
                val = value_table[codes]
            else:
                val = jnp.ones((r,), jnp.int32)
            if op in ("count", "sum"):
                # Sums accumulate in int64, matching the host iterator
                # stack — a tablet of large int32 values must not wrap
                # before the psum (min/max are order statistics).
                contrib = jnp.where(hit, val.astype(jnp.int64), jnp.int64(identity))
                aggs = jax.ops.segment_sum(contrib, gid, num_segments=n_groups)
            elif op == "min":
                contrib = jnp.where(hit, val, jnp.int32(identity))
                aggs = jax.ops.segment_min(contrib, gid, num_segments=n_groups)
            else:
                contrib = jnp.where(hit, val, jnp.int32(identity))
                aggs = jax.ops.segment_max(contrib, gid, num_segments=n_groups)
            cnts = jax.ops.segment_sum(hit.astype(jnp.int64), gid, num_segments=n_groups)
            return aggs, cnts

        # Local tablets first (vmap + reduce), then one mesh collective.
        aggs_l, cnts_l = jax.vmap(one)(rev_ts, cols, counts)
        if op in ("count", "sum"):
            aggs = jax.lax.psum(aggs_l.sum(axis=0), axes)
        elif op == "min":
            aggs = jax.lax.pmin(aggs_l.min(axis=0), axes)
        else:
            aggs = jax.lax.pmax(aggs_l.max(axis=0), axes)
        cnts = jax.lax.psum(cnts_l.sum(axis=0), axes)
        return aggs, cnts

    smapped = shard_map(
        tablet_agg,
        mesh=mesh,
        in_specs=(
            specs["rev_ts"], specs["cols"], specs["counts"],
            P(None), P(None), P(None), P(None, None),  # program: replicated
            P(None),  # value table: replicated
            P(), P(), P(),
        ),
        out_specs=(P(None), P(None)),
        check_rep=False,
    )
    return jax.jit(smapped)


def build_index_step(
    mesh: Mesh,
    n_conds: int,
    combine: str,
    prog_len: int,
    set_shape: Tuple[int, int],
    top_k: int = 128,
    max_postings: int = 2048,
    max_rows: int = 4096,
):
    """Jitted distributed index scan — the paper's winning batched-index
    scheme lowered to the mesh (Fig 2: index lookups -> key-set combine ->
    row fetch -> residual filter, all device-side).

    Per tablet, per condition: the postings for (field, value) over the
    batch's rev_ts range are ONE contiguous slice of the sorted index base
    (two binary searches), gathered into a fixed slab of max_postings
    newest-first rev_ts values. The slabs combine device-side — k-way
    intersect via kernels/merge_intersect membership searches (AND), or a
    sorted merge (OR). Candidate rev_ts values then expand to base rows by
    binary search + prefix-sum expansion, and the predicate program runs
    ONLY on the gathered candidate rows (max_rows of them) — never on the
    full tablet, which is the whole latency win over filter-scan.

    Correctness does not rest on the index: the FULL query tree re-checks
    every candidate row, so rev_ts collisions between distinct rows cost a
    wasted candidate, never a wrong result. Slab overflow is reported in
    the `truncated` output; the executor falls back to the exact
    filter-scan step for that batch (adaptive batching keeps per-batch
    result sets small, so this is rare).

    Returns (global_count, per-tablet top-k (ts, cols), truncated,
    candidate_rows) — the last is the diagnostic 'index entries actually
    used' count (psum'd)."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)
    from ..kernels.merge_intersect import member_mask_keys

    # Live-count inputs are deliberately absent: the base and index slabs
    # are ALWAYS sentinel-padded past *_base_n (init, merges, and
    # non-donated majors all preserve it), and every probe key is below
    # the sentinel, so binary searches never land in the pad tail.
    def tablet_ix(rev_ts, cols, ix_keys,
                  opcodes, arg0, arg1, codesets, cond_lo, cond_hi):
        r = rev_ts.shape[1]

        def one(rev_l, cols_l, ik_l):
            ci = ik_l.shape[0]

            def posting(i):
                a = jnp.searchsorted(ik_l, cond_lo[i], side="left").astype(jnp.int32)
                b = jnp.searchsorted(ik_l, cond_hi[i], side="left").astype(jnp.int32)
                cnt = b - a
                j = jnp.arange(max_postings, dtype=jnp.int32)
                valid = j < cnt
                kk = ik_l[jnp.clip(a + j, 0, ci - 1)]
                rts = jnp.where(
                    valid, (kk & jnp.int64(keypack.TS_MAX)).astype(jnp.int32),
                    jnp.int32(_I32_MAX),
                )
                return rts, jnp.maximum(cnt - jnp.int32(max_postings), 0)

            slabs, over = jax.vmap(posting)(jnp.arange(n_conds, dtype=jnp.int32))
            if combine == "intersect":
                # Probe the first condition's slab against every other —
                # the same membership computation the merge_intersect
                # kernel runs for host key sets.
                cand = slabs[0]
                keep = cand < jnp.int32(_I32_MAX)
                for i in range(1, n_conds):
                    keep &= member_mask_keys(cand, slabs[i])
                cand = jnp.sort(jnp.where(keep, cand, jnp.int32(_I32_MAX)))
            else:
                cand = jnp.sort(slabs.reshape(-1))
            cc = cand.shape[0]
            # Distinct candidates only: duplicate rev_ts values (shared
            # postings, OR overlaps) expand to the same base rows.
            is_dup = jnp.concatenate([jnp.zeros((1,), bool), cand[1:] == cand[:-1]])
            live = (cand < jnp.int32(_I32_MAX)) & ~is_dup
            lo_pos = jnp.searchsorted(rev_l, cand, side="left").astype(jnp.int32)
            hi_pos = jnp.searchsorted(rev_l, cand, side="right").astype(jnp.int32)
            cnt_rows = jnp.where(live, hi_pos - lo_pos, 0)
            offs = jnp.cumsum(cnt_rows)
            total = offs[-1]
            start = offs - cnt_rows
            # Prefix-sum expansion: candidate j covers output slots
            # [start[j], offs[j]) — row m maps back through one binary
            # search. Rows come out ascending in rev_ts (newest first).
            m = jnp.arange(max_rows, dtype=jnp.int32)
            j = jnp.searchsorted(offs, m, side="right").astype(jnp.int32)
            jc = jnp.clip(j, 0, cc - 1)
            row_idx = lo_pos[jc] + (m - start[jc])
            valid_m = m < total
            safe = jnp.clip(row_idx, 0, r - 1)
            r_rev = jnp.where(valid_m, rev_l[safe], jnp.int32(_I32_MAX))
            r_cols = jnp.where(valid_m[:, None], cols_l[safe], -1)
            # Exactness: the FULL tree re-checks candidates (residual AND
            # indexed conditions), so over-approximate candidate sets are
            # filtered here, at candidate cardinality.
            hit = _program_eval(r_cols, opcodes, arg0, arg1, codesets) & valid_m
            count = hit.sum(dtype=jnp.int32)
            rank = jnp.where(hit, m, jnp.int32(max_rows))
            top = jnp.sort(rank)[:top_k]
            tvalid = top < max_rows
            tsafe = jnp.clip(top, 0, max_rows - 1)
            out_ts = jnp.where(tvalid, r_rev[tsafe], INVALID_TS)
            out_cols = jnp.where(tvalid[:, None], r_cols[tsafe], -1)
            trunc = over.sum() + jnp.maximum(total - jnp.int32(max_rows), 0)
            return count, out_ts, out_cols, trunc, total

        count_l, ts_l, cols_l, trunc_l, cand_l = jax.vmap(one)(
            rev_ts, cols, ix_keys
        )
        total = jax.lax.psum(count_l.sum(dtype=jnp.int32), axes)
        truncated = jax.lax.psum(trunc_l.sum(dtype=jnp.int32), axes)
        candidates = jax.lax.psum(cand_l.sum(dtype=jnp.int32), axes)
        return total, ts_l, cols_l, truncated, candidates

    smapped = shard_map(
        tablet_ix,
        mesh=mesh,
        in_specs=(
            specs["rev_ts"], specs["cols"],
            P(axes, None),  # index base keys
            P(None), P(None), P(None), P(None, None),  # program: replicated
            P(None), P(None),  # per-condition packed key ranges
        ),
        out_specs=(P(), P(axes, None), P(axes, None, None), P(), P()),
        check_rep=False,
    )
    return jax.jit(smapped)


def build_density_step(mesh: Mesh):
    """Jitted distributed density read for the query planner: total count
    over one packed aggregate-key range — per-tablet searchsorted + masked
    sum, merged with a single psum. This is how plan_query's d_i estimates
    come off the mesh instead of the host aggregate table."""
    axes = tuple(mesh.axis_names)

    def fn(ag_keys, ag_vals, lo, hi):
        ca = ag_keys.shape[1]

        def one(k_l, v_l):
            a = jnp.searchsorted(k_l, lo, side="left")
            b = jnp.searchsorted(k_l, hi, side="left")
            idx = jnp.arange(ca)
            in_r = (idx >= a) & (idx < b)
            return jnp.where(in_r, v_l[:, 0], 0).sum()

        return jax.lax.psum(jax.vmap(one)(ag_keys, ag_vals).sum(), axes)

    smapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None, None), P(), P()),
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(smapped)


@dataclass
class DistBatch:
    """One batch's result from the distributed executor: the exact global
    matching-row count plus the per-tablet top-k newest rows (BatchScanner
    semantics: unordered across tablets, newest-first within)."""

    count: int
    ts: np.ndarray
    cols: np.ndarray

    @property
    def n(self) -> int:
        return self.count

    @property
    def nbytes(self) -> int:
        return self.ts.nbytes + self.cols.nbytes


class DistQueryProcessor:
    """Planner-driven, adaptively batched queries over the mesh — all four
    of the paper's §IV-B schemes (scan / batched_scan / index /
    batched_index) running distributed.

    With `plane=` (a DistIngestPlane), every query first syncs to the
    plane's latest published base — rows written through DistBatchWriter
    become query-visible with no host round trip (publish is device-side
    compaction only, and a no-op when nothing was ingested). Planes that
    maintain the index/aggregate families (DistIngestPlane.for_store /
    from_event_store) additionally enable the index schemes: plan_query
    reads densities from the distributed aggregate tablets (agg_count,
    a psum) and index-mode plans execute as build_index_step programs.
    Index-less stores fall back to filter-scan for every plan."""

    def __init__(
        self,
        store: EventStore,
        dist: Optional[DistStore] = None,
        top_k: int = 128,
        plane=None,
        w: float = 10.0,
        index_postings: int = 2048,
        index_rows: int = 4096,
    ):
        if dist is None:
            if plane is None:
                raise ValueError("need dist= or plane=")
            dist = plane.publish()
        self.store = store
        self.dist = dist
        self.plane = plane
        self.top_k = top_k
        self.w = w
        self.index_postings = index_postings
        self.index_rows = index_rows
        self._step_cache: Dict[Tuple, object] = {}

    def _sync(self) -> None:
        if self.plane is not None:
            self.dist = self.plane.publish()

    # ------------------------------------------------- planner density source
    # plan_query duck-types its store argument: it needs .schema,
    # .dictionaries and .agg_count. Exposing them here makes the processor
    # itself the density source, with d_i read from the mesh.
    @property
    def schema(self):
        return self.store.schema

    @property
    def dictionaries(self):
        return self.store.dictionaries

    def agg_count(self, field: str, value: str, t_start: int, t_stop: int) -> int:
        """Occurrences of field=value in the bucketed time range, from the
        DISTRIBUTED aggregate tablets (psum of per-tablet counts) — the
        planner's d_i, served by the mesh instead of the host store."""
        self._sync()
        if not self.dist.has_index:
            return self.store.agg_count(field, value, t_start, t_stop)
        code = self.store.dictionaries[field].lookup(value)
        if code is None:
            return 0
        fid = self.store.schema.field_id(field)
        bs = self.dist.agg_bucket_s
        b0 = int(t_start) // bs
        b1 = int(t_stop) // bs
        lo = int(keypack.pack_agg_key(fid, code, b0))
        hi = int(keypack.pack_agg_key(fid, code, b1)) + 1
        if "density" not in self._step_cache:
            self._step_cache["density"] = build_density_step(self.dist.mesh)
        step = self._step_cache["density"]
        return int(step(self.dist.ag_keys, self.dist.ag_vals, jnp.int64(lo), jnp.int64(hi)))

    def _step(self, prog: FilterProgram):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = (len(opc), cs.shape)
        if key not in self._step_cache:
            self._step_cache[key] = build_scan_step(
                self.dist.mesh, self.store.schema.n_fields, len(opc), cs.shape, self.top_k
            )
        return self._step_cache[key], (opc, a0, a1, cs)

    def scan_range(self, tree, t0: int, t1: int):
        """One range scan across all tablets. Returns (global_count,
        top-k rows per tablet as (ts, cols) numpy arrays)."""
        self._sync()
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._step(prog)
        rts_lo = jnp.int32(keypack.rev_ts(t1))
        rts_hi = jnp.int32(keypack.rev_ts(t0) + 1)
        total, top_ts, top_cols = step(
            self.dist.rev_ts, self.dist.cols, self.dist.counts,
            jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
            rts_lo, rts_hi,
        )
        ts = np.asarray(top_ts)
        valid = ts != int(INVALID_TS)
        return int(total), keypack.unrev_ts(ts[valid]), np.asarray(top_cols)[valid]

    # -------------------------------------------------------- index path
    def _index_step(self, prog: FilterProgram, n_conds: int, combine: str):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = ("index", n_conds, combine, len(opc), cs.shape)
        if key not in self._step_cache:
            self._step_cache[key] = build_index_step(
                self.dist.mesh, n_conds, combine, len(opc), cs.shape,
                self.top_k, self.index_postings, self.index_rows,
            )
        return self._step_cache[key], (opc, a0, a1, cs)

    def scan_index_range(self, plan: QueryPlan, tree, t0: int, t1: int):
        """One index-mode range across all tablets (paper Fig 2 on-mesh):
        postings lookup per condition, device-side intersect/union,
        candidate-row fetch, and the FULL tree re-checked on candidates.
        Returns (global_count, top-k (ts, cols), truncated, candidates);
        `truncated` > 0 means a posting/row slab overflowed and the count
        is a lower bound — the executor falls back to filter-scan then."""
        self._sync()
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._index_step(
            prog, len(plan.index_conds), plan.combine
        )
        rts_lo = keypack.rev_ts(t1)
        rts_hi = keypack.rev_ts(t0)
        k = len(plan.index_conds)
        lo = np.zeros(k, np.int64)
        hi = np.zeros(k, np.int64)
        for i, c in enumerate(plan.index_conds):
            code = self.store.dictionaries[c.field].lookup(c.value)
            if code is None:
                continue  # lo == hi: empty posting range
            fid = self.store.schema.field_id(c.field)
            lo[i] = keypack.pack_index_key(fid, code, rts_lo)
            hi[i] = keypack.pack_index_key(fid, code, rts_hi) + 1
        total, top_ts, top_cols, truncated, cands = step(
            self.dist.rev_ts, self.dist.cols, self.dist.ix_keys,
            jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
            jnp.asarray(lo), jnp.asarray(hi),
        )
        ts = np.asarray(top_ts)
        valid = ts != int(INVALID_TS)
        return (
            int(total), keypack.unrev_ts(ts[valid]), np.asarray(top_cols)[valid],
            int(truncated), int(cands),
        )

    # ---------------------------------------------------- planned execution
    def _exec_range(self, plan: QueryPlan, tree, t0: int, t1: int, stats=None) -> DistBatch:
        if plan.mode == "index" and self.dist.has_index:
            count, ts, cols, truncated, cands = self.scan_index_range(plan, tree, t0, t1)
            if stats is not None:
                stats.index_keys_scanned += cands
            if not truncated:
                return DistBatch(count, ts, cols)
            # Slab overflow: redo this range with the exact filter-scan
            # step (results identical, just without the candidate cap).
        count, ts, cols = self.scan_range(tree, t0, t1)
        return DistBatch(count, ts, cols)

    def execute(
        self,
        tree,
        t_start: int,
        t_stop: int,
        use_index: bool = True,
        batched: bool = True,
        stats=None,
    ):
        """Stream DistBatch results for a planned query — the distributed
        QueryProcessor.execute. plan_query picks the access path from the
        mesh-resident densities (heuristics 1-4); index-mode plans run
        build_index_step per batch, filter plans the scan step; provably
        empty plans (zero-density intersect branch) never touch a device."""
        import time as _time
        from .batching import AdaptiveBatcher

        self._sync()
        source = self if self.dist.has_index else self.store
        plan = plan_query(
            source, tree, t_start, t_stop, w=self.w,
            use_index=use_index and self.dist.has_index,
        )
        if stats is not None:
            stats.plan = plan
        if plan.mode == "empty":
            return
        if not batched:
            blk = self._exec_range(plan, tree, t_start, t_stop, stats)
            if stats is not None:
                stats.batches += 1
                stats.rows += blk.count
            yield blk
            return
        rps = self.store.rows_per_second()
        batcher = AdaptiveBatcher(
            t_start=t_start, t_stop=t_stop, b0=rps and 10.0 / rps
        )
        while not batcher.done:
            lo, hi = batcher.next_range()
            t0 = _time.perf_counter()
            blk = self._exec_range(plan, tree, int(lo), int(hi), stats)
            runtime = _time.perf_counter() - t0
            batcher.update(runtime, blk.count)
            if stats is not None:
                stats.batches += 1
                stats.rows += blk.count
                stats.batch_log.append((lo, hi, runtime, blk.count))
            yield blk

    def run_scheme(self, scheme: str, t_start: int, t_stop: int, tree=None, **kw):
        """The paper's four experimental schemes by name, distributed —
        mirrors QueryProcessor.run_scheme."""
        flags = {
            "scan": dict(use_index=False, batched=False),
            "batched_scan": dict(use_index=False, batched=True),
            "index": dict(use_index=True, batched=False),
            "batched_index": dict(use_index=True, batched=True),
        }[scheme]
        return self.execute(tree, t_start, t_stop, **flags, **kw)

    def _agg_step(self, prog: FilterProgram, grouping: ResolvedGrouping):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = (
            "agg", len(opc), cs.shape, grouping.fids, grouping.strides,
            grouping.size, grouping.n_buckets, grouping.spec.time_bucket_s,
            grouping.spec.op, grouping.value_fid,
        )
        if key not in self._step_cache:
            self._step_cache[key] = build_aggregate_step(
                self.dist.mesh,
                grouping.fids,
                grouping.strides,
                grouping.size,
                grouping.n_buckets,
                grouping.spec.time_bucket_s,
                grouping.spec.op,
                grouping.value_fid,
            )
        return self._step_cache[key], (opc, a0, a1, cs)

    def aggregate_range(
        self, spec: AggregateSpec, tree, t0: int, t1: int
    ) -> AggregateResult:
        """Scan-time aggregation across all tablets in ONE device program —
        the distributed lowering of QueryProcessor.aggregate(). Returns the
        already-merged (psum'd) per-group result; only groups with at least
        one matching row are materialized host-side."""
        self._sync()
        grouping = resolve_grouping(self.store, spec, t0, t1)
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._agg_step(prog, grouping)
        vt = grouping.value_table
        if vt is None:
            vt = np.ones(1, np.int32)  # unused placeholder (count op)
        aggs, cnts = step(
            self.dist.rev_ts, self.dist.cols, self.dist.counts,
            jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
            jnp.asarray(vt),
            jnp.int32(keypack.rev_ts(t1)), jnp.int32(keypack.rev_ts(t0) + 1),
            jnp.int32(grouping.bucket_lo),
        )
        aggs = np.asarray(aggs).astype(np.int64)
        cnts = np.asarray(cnts)
        live = cnts > 0
        gids = np.flatnonzero(live).astype(np.int64)
        return AggregateResult(grouping, gids, aggs[live], cnts[live])

    def execute_batched(self, tree, t_start: int, t_stop: int, stats=None):
        """Algorithm 2 over the distributed scan."""
        from .batching import AdaptiveBatcher
        import time as _time

        batcher = AdaptiveBatcher(
            t_start=t_start, t_stop=t_stop, b0=self.store.rows_per_second() and 10.0 / self.store.rows_per_second()
        )
        results = []
        while not batcher.done:
            lo, hi = batcher.next_range()
            t0 = _time.perf_counter()
            count, ts, cols = self.scan_range(tree, int(lo), int(hi))
            batcher.update(_time.perf_counter() - t0, count)
            results.append((count, ts, cols))
            if stats is not None:
                stats.batches += 1
                stats.rows += count
        return results
