"""Distributed query execution — the paper's tablet-server scan on the
production TPU mesh.

The host-side EventStore (store.py) is the single-node reference; this
module is the scale-out data plane: every device of the (data, model) mesh
acts as one tablet server holding a fixed-capacity sorted columnar tablet,
and a query executes as ONE jitted shard_map program:

    time-range restriction   sorted rev_ts -> per-tablet searchsorted
    filter                   the same postfix predicate program the
                             Pallas filter_scan kernel executes
    project + count          local; global count via psum
    top-k newest             local top-k, then a gathered cross-tablet
                             merge on the host (BatchScanner semantics:
                             unordered across tablets)
    iterator-stack combine   the server-side CombinerIterator lowered into
                             the shard_map program: per-tablet fused
                             filter + dense segment aggregation, merged
                             across tablets with psum/pmin/pmax (the
                             group-id space is dense by construction —
                             see core/iterators.py ResolvedGrouping)

The adaptive batcher (Algs 1-2) drives this exactly like the host path:
each batch is one device-program invocation over a time sub-range — the
paper's design, 256 tablets wide. dryrun.py lowers + compiles it on the
single-pod and multi-pod meshes as the extra `llcysa-store` cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import keypack
from .filter import FilterProgram, compile_tree
from .iterators import AggregateResult, AggregateSpec, ResolvedGrouping, resolve_grouping
from .store import EventStore

INVALID_TS = jnp.int32(-1)


@dataclass
class DistStore:
    """Device-resident tablet grid.

    rev_ts:  (T, R) int32   reversed timestamps, ascending per tablet
                            (newest first), padded with TS_MAX+... sentinel
    cols:    (T, R, F) int32 dictionary codes, pad rows carry junk codes
                            (masked by counts in every scan)
    counts:  (T,) int32     live rows per tablet
    T = number of tablets = n_devices * tablets_per_device (T must divide
    evenly across the mesh); R = tablet capacity. The grid is either a
    one-shot scatter of a host store (from_event_store) or the live base
    run of a DistIngestPlane (dist_ingest.publish) — the latter updates
    incrementally as writers ingest, no re-scatter.
    """

    rev_ts: jax.Array
    cols: jax.Array
    counts: jax.Array
    mesh: Mesh

    @property
    def n_tablets(self) -> int:
        return self.rev_ts.shape[0]

    @property
    def capacity(self) -> int:
        return self.rev_ts.shape[1]


def tablet_specs(mesh: Mesh) -> Dict[str, P]:
    """Tablets shard over ALL mesh axes (every chip is a tablet server)."""
    axes = tuple(mesh.axis_names)
    return {
        "rev_ts": P(axes, None),
        "cols": P(axes, None, None),
        "counts": P(axes),
    }


def dist_store_shapes(mesh: Mesh, rows_per_tablet: int, n_fields: int, tablets_per_device: int = 1):
    """Abstract ShapeDtypeStructs for the dry-run (no allocation)."""
    t = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) * tablets_per_device
    return {
        "rev_ts": jax.ShapeDtypeStruct((t, rows_per_tablet), jnp.int32),
        "cols": jax.ShapeDtypeStruct((t, rows_per_tablet, n_fields), jnp.int32),
        "counts": jax.ShapeDtypeStruct((t,), jnp.int32),
    }


def from_event_store(
    store: EventStore,
    mesh: Mesh,
    capacity: Optional[int] = None,
    tablets_per_device: int = 1,
) -> DistStore:
    """Re-shard a host EventStore's event tables onto the mesh by row hash
    (the paper's uniform random sharding) — implemented as a bulk replay
    through the distributed ingest plane: the host rows stream through
    DistIngestPlane.ingest and the device-side compaction programs build
    the sorted tablets (the former host-side NumPy scatter loop is gone)."""
    from .dist_ingest import DistIngestPlane

    t = int(np.prod([mesh.shape[a] for a in mesh.axis_names])) * tablets_per_device
    rows_k, rows_c = [], []
    for tab in store.event_tablets:
        for run in tab.snapshot_runs():
            _, rts, h = keypack.unpack_event_key(run.keys)
            rows_k.append(np.stack([rts, h], 1))
            rows_c.append(run.cols)
    if rows_k:
        rk = np.concatenate(rows_k)
        rc = np.concatenate(rows_c)
    else:
        rk = np.zeros((0, 2), np.int64)
        rc = np.zeros((0, store.schema.n_fields), np.int32)
    assign = (rk[:, 1] % t).astype(np.int64)  # hash-uniform tablet choice
    cap = capacity or max(int(np.bincount(assign, minlength=t).max()), 1)
    # The plane's flush triggers are exact per tablet (host-side fill
    # mirror), so fixed per-tablet buffers suffice: a tablet majors every
    # max_runs * mem_rows of ITS OWN rows — run-slab memory stays
    # O(T * max_runs * mem_rows), independent of replay size.
    plane = DistIngestPlane(
        mesh,
        store.schema.n_fields,
        capacity=cap,
        tablets_per_device=tablets_per_device,
        mem_rows=8192,
        max_runs=8,
        append_rows=2048,
    )
    plane.ingest(rk[:, 0].astype(np.int32), rc, assign.astype(np.int32))
    dist = plane.publish()
    overflow = int(plane.telemetry()["overflow"].sum())
    if overflow:
        # An explicitly undersized capacity must fail loudly, exactly as
        # the pre-plane scatter implementation did.
        raise ValueError(f"tablet overflow: {overflow} rows over capacity {cap}")
    return dist


def _program_eval(cols, opcodes, arg0, arg1, codesets):
    """Postfix predicate program over (R, F) codes — identical semantics
    to kernels/filter_scan (jnp form, shard-local)."""
    from ..kernels.program_eval import program_eval_rows

    return program_eval_rows(cols, opcodes, arg0, arg1, codesets)


def build_scan_step(mesh: Mesh, n_fields: int, prog_len: int, set_shape: Tuple[int, int], top_k: int = 128):
    """Jitted distributed scan: (store, program, t-range) -> (global count,
    per-tablet top-k newest matches). One invocation per adaptive batch.
    Each device vmaps over its local tablets (tablets_per_device may
    exceed 1 — the ingest plane's W x T sweeps size T independently of
    the mesh), then psums across the mesh."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)

    def tablet_scan(rev_ts, cols, counts, opcodes, arg0, arg1, codesets, rts_lo, rts_hi):
        # Local slab: (Tl, R), (Tl, R, F), (Tl,) after shard_map slicing.
        r = rev_ts.shape[1]

        def one(rev_l, cols_l, n):
            # Range restriction on sorted rev_ts: [lo, hi) via searchsorted.
            a = jnp.searchsorted(rev_l, rts_lo, side="left")
            b = jnp.searchsorted(rev_l, rts_hi, side="left")
            idx = jnp.arange(r, dtype=jnp.int32)
            in_range = (idx >= a) & (idx < b) & (idx < n)
            hit = _program_eval(cols_l, opcodes, arg0, arg1, codesets) & in_range
            count = hit.sum(dtype=jnp.int32)
            # Top-k newest matches (smallest rev_ts == newest; rows sorted).
            rank = jnp.where(hit, idx, r)
            top = jnp.sort(rank)[:top_k]
            valid = top < r
            safe = jnp.clip(top, 0, r - 1)
            out_ts = jnp.where(valid, rev_l[safe], INVALID_TS)
            out_cols = jnp.where(valid[:, None], cols_l[safe], -1)
            return count, out_ts, out_cols

        count_l, out_ts, out_cols = jax.vmap(one)(rev_ts, cols, counts)
        total = jax.lax.psum(count_l.sum(dtype=jnp.int32), axes)
        return total, out_ts, out_cols

    smapped = shard_map(
        tablet_scan,
        mesh=mesh,
        in_specs=(
            specs["rev_ts"], specs["cols"], specs["counts"],
            P(None), P(None), P(None), P(None, None),  # program: replicated
            P(), P(),
        ),
        out_specs=(P(), P(axes, None), P(axes, None, None)),
        check_rep=False,
    )
    return jax.jit(smapped)


def build_aggregate_step(
    mesh: Mesh,
    fids: Tuple[int, ...],
    strides: Tuple[int, ...],
    n_groups: int,
    n_buckets: int,
    bucket_s: Optional[int],
    op: str,
    value_fid: Optional[int],
):
    """Jitted distributed scan-time aggregation: the iterator stack's
    terminal CombinerIterator lowered into the mesh program. Each tablet
    evaluates the fused filter + dense segment aggregation locally; the
    dense group-id space (mixed-radix codes x time buckets, see
    ResolvedGrouping) makes the cross-tablet merge a single psum (sum /
    count) or pmin/pmax — no gather of raw rows ever happens."""
    axes = tuple(mesh.axis_names)
    specs = tablet_specs(mesh)
    int32_max = jnp.iinfo(jnp.int32).max
    int32_min = jnp.iinfo(jnp.int32).min
    identity = {"count": 0, "sum": 0, "min": int32_max, "max": int32_min}[op]

    def tablet_agg(rev_ts, cols, counts, opcodes, arg0, arg1, codesets,
                   value_table, rts_lo, rts_hi, bucket_lo):
        r = rev_ts.shape[1]

        def one(rev_l, cols_l, n):
            a = jnp.searchsorted(rev_l, rts_lo, side="left")
            b = jnp.searchsorted(rev_l, rts_hi, side="left")
            idx = jnp.arange(r, dtype=jnp.int32)
            in_range = (idx >= a) & (idx < b) & (idx < n)
            hit = _program_eval(cols_l, opcodes, arg0, arg1, codesets) & in_range
            gid = jnp.zeros((r,), jnp.int32)
            for fid, stride in zip(fids, strides):
                gid = gid + cols_l[:, fid] * jnp.int32(stride)
            if bucket_s is not None:
                ts_l = jnp.int32(keypack.TS_MAX) - rev_l
                gid = gid + ts_l // jnp.int32(bucket_s) - bucket_lo
            # Padded/out-of-range rows can carry junk codes: clamp, their
            # contribution is masked to the identity anyway.
            gid = jnp.clip(gid, 0, n_groups - 1)
            if value_fid is not None:
                codes = jnp.clip(cols_l[:, value_fid], 0, value_table.shape[0] - 1)
                val = value_table[codes]
            else:
                val = jnp.ones((r,), jnp.int32)
            if op in ("count", "sum"):
                # Sums accumulate in int64, matching the host iterator
                # stack — a tablet of large int32 values must not wrap
                # before the psum (min/max are order statistics).
                contrib = jnp.where(hit, val.astype(jnp.int64), jnp.int64(identity))
                aggs = jax.ops.segment_sum(contrib, gid, num_segments=n_groups)
            elif op == "min":
                contrib = jnp.where(hit, val, jnp.int32(identity))
                aggs = jax.ops.segment_min(contrib, gid, num_segments=n_groups)
            else:
                contrib = jnp.where(hit, val, jnp.int32(identity))
                aggs = jax.ops.segment_max(contrib, gid, num_segments=n_groups)
            cnts = jax.ops.segment_sum(hit.astype(jnp.int64), gid, num_segments=n_groups)
            return aggs, cnts

        # Local tablets first (vmap + reduce), then one mesh collective.
        aggs_l, cnts_l = jax.vmap(one)(rev_ts, cols, counts)
        if op in ("count", "sum"):
            aggs = jax.lax.psum(aggs_l.sum(axis=0), axes)
        elif op == "min":
            aggs = jax.lax.pmin(aggs_l.min(axis=0), axes)
        else:
            aggs = jax.lax.pmax(aggs_l.max(axis=0), axes)
        cnts = jax.lax.psum(cnts_l.sum(axis=0), axes)
        return aggs, cnts

    smapped = shard_map(
        tablet_agg,
        mesh=mesh,
        in_specs=(
            specs["rev_ts"], specs["cols"], specs["counts"],
            P(None), P(None), P(None), P(None, None),  # program: replicated
            P(None),  # value table: replicated
            P(), P(), P(),
        ),
        out_specs=(P(None), P(None)),
        check_rep=False,
    )
    return jax.jit(smapped)


class DistQueryProcessor:
    """Adaptive-batched queries over the mesh — Algs 1-2 driving the
    distributed scan step.

    With `plane=` (a DistIngestPlane), every query first syncs to the
    plane's latest published base — rows written through DistBatchWriter
    become query-visible with no host round trip (publish is device-side
    compaction only, and a no-op when nothing was ingested)."""

    def __init__(
        self,
        store: EventStore,
        dist: Optional[DistStore] = None,
        top_k: int = 128,
        plane=None,
    ):
        if dist is None:
            if plane is None:
                raise ValueError("need dist= or plane=")
            dist = plane.publish()
        self.store = store
        self.dist = dist
        self.plane = plane
        self.top_k = top_k
        self._step_cache: Dict[Tuple[int, Tuple[int, int]], object] = {}

    def _sync(self) -> None:
        if self.plane is not None:
            self.dist = self.plane.publish()

    def _step(self, prog: FilterProgram):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = (len(opc), cs.shape)
        if key not in self._step_cache:
            self._step_cache[key] = build_scan_step(
                self.dist.mesh, self.store.schema.n_fields, len(opc), cs.shape, self.top_k
            )
        return self._step_cache[key], (opc, a0, a1, cs)

    def scan_range(self, tree, t0: int, t1: int):
        """One range scan across all tablets. Returns (global_count,
        top-k rows per tablet as (ts, cols) numpy arrays)."""
        self._sync()
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._step(prog)
        rts_lo = jnp.int32(keypack.rev_ts(t1))
        rts_hi = jnp.int32(keypack.rev_ts(t0) + 1)
        total, top_ts, top_cols = step(
            self.dist.rev_ts, self.dist.cols, self.dist.counts,
            jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
            rts_lo, rts_hi,
        )
        ts = np.asarray(top_ts)
        valid = ts != int(INVALID_TS)
        return int(total), keypack.unrev_ts(ts[valid]), np.asarray(top_cols)[valid]

    def _agg_step(self, prog: FilterProgram, grouping: ResolvedGrouping):
        from ..kernels.filter_scan.ops import pad_program

        opc, a0, a1, cs = pad_program(prog)
        key = (
            "agg", len(opc), cs.shape, grouping.fids, grouping.strides,
            grouping.size, grouping.n_buckets, grouping.spec.time_bucket_s,
            grouping.spec.op, grouping.value_fid,
        )
        if key not in self._step_cache:
            self._step_cache[key] = build_aggregate_step(
                self.dist.mesh,
                grouping.fids,
                grouping.strides,
                grouping.size,
                grouping.n_buckets,
                grouping.spec.time_bucket_s,
                grouping.spec.op,
                grouping.value_fid,
            )
        return self._step_cache[key], (opc, a0, a1, cs)

    def aggregate_range(
        self, spec: AggregateSpec, tree, t0: int, t1: int
    ) -> AggregateResult:
        """Scan-time aggregation across all tablets in ONE device program —
        the distributed lowering of QueryProcessor.aggregate(). Returns the
        already-merged (psum'd) per-group result; only groups with at least
        one matching row are materialized host-side."""
        self._sync()
        grouping = resolve_grouping(self.store, spec, t0, t1)
        prog = compile_tree(self.store, tree)
        step, (opc, a0, a1, cs) = self._agg_step(prog, grouping)
        vt = grouping.value_table
        if vt is None:
            vt = np.ones(1, np.int32)  # unused placeholder (count op)
        aggs, cnts = step(
            self.dist.rev_ts, self.dist.cols, self.dist.counts,
            jnp.asarray(opc), jnp.asarray(a0), jnp.asarray(a1), jnp.asarray(cs),
            jnp.asarray(vt),
            jnp.int32(keypack.rev_ts(t1)), jnp.int32(keypack.rev_ts(t0) + 1),
            jnp.int32(grouping.bucket_lo),
        )
        aggs = np.asarray(aggs).astype(np.int64)
        cnts = np.asarray(cnts)
        live = cnts > 0
        gids = np.flatnonzero(live).astype(np.int64)
        return AggregateResult(grouping, gids, aggs[live], cnts[live])

    def execute_batched(self, tree, t_start: int, t_stop: int, stats=None):
        """Algorithm 2 over the distributed scan."""
        from .batching import AdaptiveBatcher
        import time as _time

        batcher = AdaptiveBatcher(
            t_start=t_start, t_stop=t_stop, b0=self.store.rows_per_second() and 10.0 / self.store.rows_per_second()
        )
        results = []
        while not batcher.done:
            lo, hi = batcher.next_range()
            t0 = _time.perf_counter()
            count, ts, cols = self.scan_range(tree, int(lo), int(hi))
            batcher.update(_time.perf_counter() - t0, count)
            results.append((count, ts, cols))
            if stats is not None:
                stats.batches += 1
                stats.rows += count
        return results
