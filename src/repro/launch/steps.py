"""AOT step builders: (arch x shape x mesh) -> jitted-with-shardings step
function + abstract input ShapeDtypeStructs.

These are the functions the dry-run lowers and compiles for every assigned
cell, and the same builders the real train/serve launchers use — there is
exactly one definition of each step.

input_specs() follows the shannon/kernels pattern: weak-type-correct
ShapeDtypeStructs, shardable, zero device allocation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import disable_x64
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, ModelConfig, ShapeConfig
from ..distributed import ctx as dist_ctx
from ..distributed.sharding import (
    batch_specs,
    cache_specs,
    dp_axes,
    dp_size,
    param_specs,
    to_named,
    zero1_specs,
)
from ..models.model import (
    decode_step,
    forward_train,
    init_caches,
    init_params,
    prefill,
)
from ..training.optimizer import OptConfig, adamw_init, adamw_update

PyTree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _filter_tree(tree: Dict, keys) -> Dict:
    return {k: v for k, v in tree.items() if k in keys}


def param_shapes(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract input batch for one shape cell."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    out: Dict[str, Any] = {}
    if cfg.embed_input:
        out["inputs"] = _sds((b, s), jnp.int32)
    else:
        out["embeds"] = _sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    if shape.kind == "train":
        out["targets"] = _sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        out["vision_states"] = _sds((b, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    return jax.eval_shape(
        lambda: init_caches(
            None, cfg, shape.global_batch, shape.seq_len, n_img=cfg.n_image_tokens
        )
    )


class _CtxJit:
    """jax.jit is lazy — tracing happens at .lower()/first call, which may
    be far from where the step was built. This wrapper re-enters the
    sharding context at trace time so dist_ctx.constrain() hints are live.

    Tracing also runs with x64 disabled: the store layer enables x64
    globally, under which layer-scan loop counters lower to s64 while the
    SPMD partitioner's shard-offset arithmetic stays s32 — the transposed
    scan's dynamic_update_slice then fails HLO verification with a mixed
    s64/s32 compare. Every tensor in the model/optimizer step is explicitly
    32-bit (or bf16), so tracing x64-off only pins index dtypes to s32,
    making both compare operands a common dtype."""

    def __init__(self, fn, mesh, rules):
        self._fn = fn
        self._mesh = mesh
        self._rules = rules

    def lower(self, *args, **kw):
        with dist_ctx.sharding_context(self._mesh, self._rules), disable_x64():
            return self._fn.lower(*args, **kw)

    def __call__(self, *args, **kw):
        with dist_ctx.sharding_context(self._mesh, self._rules), disable_x64():
            return self._fn(*args, **kw)


@dataclass
class BuiltStep:
    fn: Callable  # jitted with shardings (ctx-wrapped)
    abstract_args: Tuple  # ShapeDtypeStructs to .lower() with
    in_shardings: PyTree
    out_shardings: PyTree
    rules: Dict


def opt_state_specs(cfg, mesh, pspecs, pshapes, opt_cfg: OptConfig, zero1: bool):
    mv = zero1_specs(pspecs, pshapes, mesh) if zero1 else pspecs
    st = {"step": P(), "m": mv, "v": mv}
    if opt_cfg.compress_grads:
        st["err"] = mv
    return st


def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: ShapeConfig,
    opt_cfg: Optional[OptConfig] = None,
    zero1: bool = True,
    remat: bool = True,
    loss_chunk: int = 512,
    seq_parallel: bool = False,
    accum_steps: int = 1,
) -> BuiltStep:
    """accum_steps > 1: gradient-accumulation microbatching — the global
    batch splits into accum_steps microbatches scanned sequentially with an
    f32 grad accumulator; activation footprints scale ~1/accum_steps at the
    cost of one accumulator tree (f32, model-sharded)."""
    opt_cfg = opt_cfg or OptConfig()
    pshapes = param_shapes(cfg)
    pspecs = param_specs(cfg, mesh)
    oshapes = jax.eval_shape(lambda: adamw_init(pshapes, opt_cfg))
    ospecs = opt_state_specs(cfg, mesh, pspecs, pshapes, opt_cfg, zero1)
    bshapes = batch_shapes(cfg, shape)
    bspecs = _filter_tree(batch_specs(cfg, mesh, shape.global_batch), bshapes.keys())
    rules = dist_ctx.default_rules(
        cfg, mesh, shape.global_batch, seq_parallel=seq_parallel, seq_len=shape.seq_len
    )
    assert shape.global_batch % accum_steps == 0

    def grad_fn(params, batch):
        def loss_fn(p):
            return forward_train(p, cfg, batch, remat=remat, loss_chunk=loss_chunk)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                g_acc, l_acc = acc
                # Keep each microbatch dp-sharded.
                mb = {k: dist_ctx.constrain("microbatch_" + ("3d" if v.ndim == 3 else "2d"), v)
                      for k, v in mb.items()}
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss, "aux_loss": jnp.float32(0.0), "tokens": jnp.float32(0.0)}
        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return new_params, new_opt, metrics

    in_sh = (to_named(pspecs, mesh), to_named(ospecs, mesh), to_named(bspecs, mesh))
    out_sh = (
        to_named(pspecs, mesh),
        to_named(ospecs, mesh),
        jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), {
            "loss": 0, "aux_loss": 0, "tokens": 0, "grad_norm": 0, "lr": 0, "total_loss": 0
        }),
    )
    fn = _CtxJit(jax.jit(step, in_shardings=in_sh, out_shardings=out_sh), mesh, rules)
    return BuiltStep(
        fn=fn,
        abstract_args=(pshapes, oshapes, bshapes),
        in_shardings=in_sh,
        out_shardings=out_sh,
        rules=rules,
    )


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> BuiltStep:
    pshapes = param_shapes(cfg)
    pspecs = param_specs(cfg, mesh)
    bshapes = batch_shapes(cfg, shape)
    bspecs = _filter_tree(batch_specs(cfg, mesh, shape.global_batch), bshapes.keys())
    cspecs = cache_specs(cfg, mesh, shape.global_batch)
    rules = dist_ctx.default_rules(cfg, mesh, shape.global_batch)
    dp = dp_axes(mesh)
    b_ax = dp if shape.global_batch % dp_size(mesh) == 0 else None
    vdiv = cfg.vocab_size % mesh.shape.get("model", 1) == 0

    def step(params, batch):
        logits, caches, last_pos = prefill(params, cfg, batch, cache_len=shape.seq_len)
        return logits, caches, last_pos

    in_sh = (to_named(pspecs, mesh), to_named(bspecs, mesh))
    out_sh = (
        NamedSharding(mesh, P(b_ax, "model" if vdiv else None)),
        to_named(cspecs, mesh),
        NamedSharding(mesh, P(b_ax)),
    )
    fn = _CtxJit(jax.jit(step, in_shardings=in_sh, out_shardings=out_sh), mesh, rules)
    return BuiltStep(fn, (pshapes, bshapes), in_sh, out_sh, rules)


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> BuiltStep:
    pshapes = param_shapes(cfg)
    pspecs = param_specs(cfg, mesh)
    bshapes = batch_shapes(cfg, shape)
    bspecs = _filter_tree(batch_specs(cfg, mesh, shape.global_batch), bshapes.keys())
    cshapes = cache_shapes(cfg, shape)
    cspecs = cache_specs(cfg, mesh, shape.global_batch)
    rules = dist_ctx.default_rules(cfg, mesh, shape.global_batch)
    dp = dp_axes(mesh)
    b_ax = dp if shape.global_batch % dp_size(mesh) == 0 else None
    vdiv = cfg.vocab_size % mesh.shape.get("model", 1) == 0
    pos_shape = _sds((shape.global_batch,), jnp.int32)

    def step(params, batch, caches, cur_pos):
        logits, new_caches = decode_step(params, cfg, batch, caches, cur_pos)
        return logits, new_caches

    in_sh = (
        to_named(pspecs, mesh),
        to_named(bspecs, mesh),
        to_named(cspecs, mesh),
        NamedSharding(mesh, P(b_ax)),
    )
    out_sh = (
        NamedSharding(mesh, P(b_ax, "model" if vdiv else None)),
        to_named(cspecs, mesh),
    )
    # Donate the caches: the updated cache aliases the input buffer instead
    # of doubling decode memory.
    fn = _CtxJit(
        jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(2,)),
        mesh,
        rules,
    )
    return BuiltStep(fn, (pshapes, bshapes, cshapes, pos_shape), in_sh, out_sh, rules)


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    return build_decode_step(cfg, mesh, shape)
