"""Launchers: production mesh builders, AOT step builders (train / prefill
/ decode), the multi-pod dry-run, HLO collective analysis, and roofline
derivation."""
